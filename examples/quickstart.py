"""Quickstart: the paper in miniature (~1 minute on CPU).

Builds a small synthetic SQuAD-2.0 testbed, generates the offline
action-sweep log, trains Argmax-CE under both SLO profiles, and prints
the cost/quality table — including the refusal-collapse failure mode.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.config import RouterConfig, TestbedConfig
from repro.core.experiment import run_experiment


def main():
    cfg = TestbedConfig(n_train=300, n_eval=100, n_paragraphs=300,
                        router=RouterConfig(n_epochs=15))
    res, extras, _ = run_experiment(cfg, verbose=True)
    print("\nAction distributions (Fig 1):")
    for k, d in extras["action_dists"].items():
        print(f"  {k:28s} {[round(x, 2) for x in d]}")
    ce_cheap = [r for r in res.rows
                if r["slo"] == "cheap" and r["method"] == "argmax_ce"][0]
    print(f"\nRefusal collapse under cheap SLO: refusal_rate="
          f"{ce_cheap['refuse']:.2f}, acc={ce_cheap['acc']:.2f}")


if __name__ == "__main__":
    main()
