"""Quickstart: the paper in miniature (~1 minute on CPU).

Builds a small synthetic SQuAD-2.0 testbed, generates the offline
action-sweep log, trains Argmax-CE under both SLO profiles, prints the
cost/quality table — including the refusal-collapse failure mode — and
then serves live traffic through the unified routing API:

    policy  = MLPPolicy.train(...)          # any RoutingPolicy
    gateway = Gateway(policy, SimulatorBackend(pipe), ...)
    stats   = gateway.serve(requests)       # route -> execute -> account

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.config import RouterConfig, TestbedConfig
from repro.core.experiment import run_experiment
from repro.routing import (Gateway, MLPPolicy, Request, SimulatorBackend,
                           get_slo_profile)


def main():
    cfg = TestbedConfig(n_train=300, n_eval=100, n_paragraphs=300,
                        router=RouterConfig(n_epochs=15))
    res, extras, (train_log, eval_log) = run_experiment(cfg, verbose=True)
    print("\nAction distributions (Fig 1):")
    for k, d in extras["action_dists"].items():
        print(f"  {k:28s} {[round(x, 2) for x in d]}")
    ce_cheap = [r for r in res.rows
                if r["slo"] == "cheap" and r["method"] == "argmax_ce"][0]
    print(f"\nRefusal collapse under cheap SLO: refusal_rate="
          f"{ce_cheap['refuse']:.2f}, acc={ce_cheap['acc']:.2f}")

    # --- live serving through the Gateway (the production entry point) ---
    data, index, pipe = extras["testbed"]
    policy = MLPPolicy.train(
        train_log, train_log.rewards(get_slo_profile("cheap")),
        cfg.router, objective="argmax_ce")
    gateway = Gateway(policy, SimulatorBackend(pipe),
                      router_cfg=cfg.router, index=index, max_batch=20,
                      adaptive_refusal=True, base_refusal_share=0.5)
    stats = gateway.serve([Request(qid=q.qid, question=q, slo="cheap")
                           for q in data.questions[-60:]])
    print(f"\nGateway served {stats.served} requests under cheap SLO: "
          f"avg reward {stats.avg_reward:+.4f}, "
          f"refusal share {gateway.refusal_share:.2f} "
          f"(budget back-pressure capped the collapse)")


if __name__ == "__main__":
    main()
