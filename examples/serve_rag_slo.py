"""End-to-end serving driver: batched requests through the full stack.

 request batch -> SLO router (trained Argmax-CE policy)
               -> BM25 retrieval at the routed depth
               -> a REAL JAX transformer backend (reduced qwen family)
                  generating answers token-by-token through the KV-cache
                  engine (prefill + decode)
               -> per-SLO metrics.

The generation quality of the tiny local model is irrelevant — the point
is the full serving path: routing, retrieval, batched prefill/decode,
cost accounting.

    PYTHONPATH=src python examples/serve_rag_slo.py --slo cheap
"""
import argparse
import time

import jax
import numpy as np

from repro.core.actions import ACTIONS, SLO_PROFILES, reward
from repro.core.config import TestbedConfig
from repro.core.offline_log import build_testbed
from repro.core.policy import policy_actions, train_policy
from repro.configs import get_config
from repro.data.tokenizer import HashTokenizer
from repro.generation.prompts import build_prompt
from repro.models import build_model
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slo", default="quality_first",
                    choices=list(SLO_PROFILES))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    args = ap.parse_args()
    profile = SLO_PROFILES[args.slo]

    print("# building testbed + routing policy ...")
    cfg = TestbedConfig(n_train=300, n_eval=100, n_paragraphs=200)
    data, index, pipe, train_log, eval_log = build_testbed(cfg)
    tr = train_policy(train_log, train_log.rewards(profile), cfg.router,
                      objective="argmax_ce")

    print("# loading local JAX generation backend (reduced qwen family)")
    mcfg = get_config("qwen1.5-32b", "smoke")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, max_len=512)
    tok = HashTokenizer(mcfg.vocab_size)

    queries = data.questions[-args.batch:]
    states = eval_log.states[-args.batch:]
    routed = policy_actions(tr.params, states, cfg.router)

    print(f"# serving {args.batch} requests under SLO={args.slo}\n")
    t0 = time.time()
    prompts, metas = [], []
    for q, a in zip(queries, routed):
        action = ACTIONS[a]
        if action.mode == "refuse":
            metas.append((q, action, None))
            continue
        passages = pipe.retrieve(q.text, action.k)
        prompt = build_prompt(action.mode, q.text, passages)
        prompts.append(tok.encode(prompt, bos=True, max_len=384))
        metas.append((q, action, len(prompts) - 1))

    result = engine.generate(prompts, max_new_tokens=args.max_new_tokens) \
        if prompts else None
    dt = time.time() - t0

    total_reward = 0.0
    for q, action, slot in metas:
        if slot is None:
            cost, status = 5, "REFUSED(pre)"
            r = reward(profile, correct=False, cost_tokens=cost,
                       hallucinated=False, refused=True,
                       answerable=q.answerable, pre_retrieval=True)
        else:
            cost = len(prompts[slot]) + result.tokens.shape[1]
            status = f"generated {result.tokens.shape[1]} toks"
            r = reward(profile, correct=False, cost_tokens=cost,
                       hallucinated=not q.answerable, refused=False,
                       answerable=q.answerable)
        total_reward += r
        print(f"  a{action.idx} (k={action.k:2d},{action.mode:7s}) "
              f"cost={cost:4d}  {status:18s}  q: {q.text[:44]}")
    print(f"\nbatch served in {dt:.1f}s; avg SLO reward "
          f"{total_reward / args.batch:+.4f}")


if __name__ == "__main__":
    main()
