"""End-to-end serving driver: batched requests through the full stack.

 request batch -> Gateway (unified routing API)
               -> RoutingPolicy (trained Argmax-CE MLP)
               -> action-bucketed BM25 retrieval at the routed depth
               -> a REAL JAX transformer backend (reduced qwen family)
                  generating answers token-by-token through the KV-cache
                  engine (prefill + decode), one batched call per bucket
               -> per-SLO reward + error-budget accounting.

The generation quality of the tiny local model is irrelevant — the point
is the full serving path: routing, retrieval, batched prefill/decode,
cost accounting, all through the one `repro.routing.Gateway` entry
point (no hand-rolled route→retrieve→generate loop).

    PYTHONPATH=src python examples/serve_rag_slo.py --slo cheap
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core.config import TestbedConfig
from repro.core.offline_log import build_testbed
from repro.data.tokenizer import HashTokenizer
from repro.models import build_model
from repro.routing import (ContinuousEngineBackend, EngineBackend, Gateway,
                           MLPPolicy, Request, get_slo_profile,
                           list_slo_profiles)
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slo", default="quality_first",
                    choices=list_slo_profiles())
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--engine", default="continuous",
                    choices=("continuous", "padded"),
                    help="continuous = slot-based shared decode stream; "
                         "padded = legacy serial per-bucket engine")
    ap.add_argument("--mesh", default=None, metavar="dp=N[,mp=M]",
                    help="shard the continuous engine over a device "
                         "mesh (dp=N slots-on-data, mp=M params "
                         "tensor-parallel on the model axis; pair with "
                         "XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N*M on a CPU host)")
    args = ap.parse_args()
    if args.mesh and args.engine != "continuous":
        ap.error("--mesh requires --engine continuous")
    profile = get_slo_profile(args.slo)

    print("# building testbed + routing policy ...")
    cfg = TestbedConfig(n_train=300, n_eval=100, n_paragraphs=200)
    data, index, pipe, train_log, eval_log = build_testbed(cfg)
    policy = MLPPolicy.train(train_log, train_log.rewards(profile),
                             cfg.router, objective="argmax_ce")

    print("# loading local JAX generation backend (reduced qwen family)")
    mcfg = get_config("qwen1.5-32b", "smoke")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = HashTokenizer(mcfg.vocab_size)
    # slot caches must hold the padded prompt plus the generation
    # budget; the backend pads every prompt to max_prompt_len
    max_prompt_len = 384
    max_len = max_prompt_len + args.max_new_tokens
    if args.engine == "continuous":
        mesh = None
        if args.mesh:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(args.mesh, model_cfg=mcfg)
            print(f"# sharded executor over mesh {args.mesh} "
                  f"({len(jax.devices())} devices; slots on data, "
                  f"params on model)")
        engine = ContinuousEngine(model, params, num_slots=args.batch,
                                  max_len=max_len,
                                  max_new_cap=args.max_new_tokens,
                                  prefill_batch=args.batch, mesh=mesh)
        backend_cls = ContinuousEngineBackend
    else:
        engine = Engine(model, params, max_len=max_len)
        backend_cls = EngineBackend

    def report(req, action, out, rew):
        status = "REFUSED(pre)" if out.refused else out.answer
        print(f"  a{action.idx} (k={action.k:2d},{action.mode:7s}) "
              f"cost={out.cost_tokens:6.0f}  {status:22s} "
              f"q: {req.question.text[:44]}")

    gateway = Gateway(
        policy,
        backend_cls(engine, tok, index, max_prompt_len=max_prompt_len,
                    max_new_tokens=args.max_new_tokens),
        router_cfg=cfg.router, index=index, max_batch=args.batch,
        adaptive_refusal=False, on_outcome=report)

    reqs = [Request(qid=q.qid, question=q, slo=args.slo)
            for q in data.questions[-args.batch:]]
    print(f"# serving {args.batch} requests under SLO={args.slo}\n")
    t0 = time.time()
    stats = gateway.serve(reqs)
    dt = time.time() - t0

    print(f"\nbatch served in {dt:.1f}s; avg SLO reward "
          f"{stats.avg_reward:+.4f}; actions {dict(stats.action_counts)}")


if __name__ == "__main__":
    main()
