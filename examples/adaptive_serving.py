"""Adaptive SLO serving: the Gateway + SRE error budgets (beyond paper).

Serves a stream of requests under the collapse-prone cheap SLO with a
routing policy trained by vanilla Argmax-CE.  Without back-pressure the
policy refuses ~80% of requests; the error-budget tracker detects the
wrong-refusal burn and tightens the refusal share per batch — collapse
mitigation applied at SERVING time through the unified Gateway, no
retraining.

    PYTHONPATH=src python examples/adaptive_serving.py
"""
from repro.core.config import RouterConfig, TestbedConfig
from repro.core.offline_log import build_testbed
from repro.routing import (Gateway, MLPPolicy, Request, SimulatorBackend,
                           get_slo_profile)


def main():
    cfg = TestbedConfig(n_train=300, n_eval=100, n_paragraphs=300,
                        router=RouterConfig(n_epochs=15))
    data, index, pipe, train_log, _ = build_testbed(cfg)
    policy = MLPPolicy.train(
        train_log, train_log.rewards(get_slo_profile("cheap")),
        cfg.router, objective="argmax_ce")
    reqs = [Request(qid=q.qid, question=q, slo="cheap")
            for q in data.questions[-100:]]

    for adaptive in (False, True):
        gw = Gateway(policy, SimulatorBackend(pipe),
                     router_cfg=cfg.router, index=index, max_batch=20,
                     adaptive_refusal=adaptive, base_refusal_share=0.5)
        stats = gw.serve(list(reqs))
        print(f"adaptive={str(adaptive):5s} served={stats.served} "
              f"refusal_share={gw.refusal_share:.2f} "
              f"avg_reward={stats.avg_reward:+.4f}")
        for name, rep in gw.budget.report().items():
            print(f"    budget {name:13s} violation={rep.violation_rate:.3f}"
                  f" consumed={rep.budget_consumed:5.2f}"
                  f" healthy={rep.healthy}")


if __name__ == "__main__":
    main()
