"""Train a reduced-family model end-to-end on CPU for a few hundred
steps (any of the 10 assigned architectures via --arch).

    PYTHONPATH=src python examples/train_tiny_lm.py --arch mamba2-130m \
        --steps 200
"""
import sys

from repro.launch import train


def main():
    if "--steps" not in " ".join(sys.argv):
        sys.argv += ["--steps", "200"]
    train.main()


if __name__ == "__main__":
    main()
