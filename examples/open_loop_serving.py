"""Open-loop serving & load shedding: AsyncGateway + the traffic
harness (beyond paper).

Replays seeded Poisson and bursty on-off arrival streams against the
AsyncGateway in virtual time and sweeps the offered load.  At
comfortable load everything is served within deadline; over-offered,
the SLO control loop starts actuating — shedding at the queue, forcing
refusals, clamping retrieval depth — and goodput-under-SLO (answers
within deadline per second) degrades gracefully instead of collapsing
into an unbounded queue.  Same seed, same numbers: the whole run is
deterministic.

Uses the simulator backend's synthetic service model for speed; swap
in ``ContinuousEngineBackend.create(..., clock=clock.now)`` for the
real engine (that path is exercised by the serving benchmark's
open-loop sweep and the loadtest suite).

The last sweep point runs with the telemetry plane attached: a
``Tracer`` + ``MetricsRegistry`` on the same virtual clock, a Chrome
trace-event JSON written to ``open_loop_trace.json`` (open in Perfetto
or chrome://tracing) and the Prometheus exposition to
``open_loop_metrics.prom`` — the same artifacts
``python -m repro.launch.serve --open-loop ... --trace-out ...
--metrics-out ...`` produces.

    PYTHONPATH=src python examples/open_loop_serving.py
"""
import numpy as np

from repro.core.config import RouterConfig, TestbedConfig
from repro.core.offline_log import build_testbed
from repro.obs import MetricsRegistry, Tracer
from repro.routing import (MLPPolicy, SimulatorBackend, get_slo_profile)
from repro.serving.streaming import AdmissionConfig, AsyncGateway
from repro.serving.traffic import (LoadGenerator, OnOffProcess,
                                   PoissonProcess, VirtualClock, build_trace)

DEADLINE_MS = 120.0
N_REQUESTS = 300
TRACE_OUT = "open_loop_trace.json"
METRICS_OUT = "open_loop_metrics.prom"


def run(policy, cfg, index, pipe, questions, process, label,
        telemetry=False):
    clock = VirtualClock()
    backend = SimulatorBackend(pipe, stream_slots=4, service_polls=2,
                               clock=clock.now)
    tracer = Tracer(clock.now) if telemetry else None
    metrics = MetricsRegistry(clock.now) if telemetry else None
    gw = AsyncGateway(policy, backend, router_cfg=cfg.router, index=index,
                      clock=clock.now, deadline_ms=DEADLINE_MS,
                      admission=AdmissionConfig(max_backlog=16),
                      tracer=tracer, metrics=metrics)
    trace = build_trace(questions, process, N_REQUESTS,
                        deadline_ms=DEADLINE_MS)
    rep = LoadGenerator(gw, trace).run_virtual(clock,
                                               service_quantum_s=0.005)
    st = gw.stats
    print(f"{label:26s} goodput={rep.goodput:7.1f}/s "
          f"({rep.goodput_fraction:5.1%})  shed={rep.shed:3d}  "
          f"forced={st.forced_refusals:3d}  clamped={st.depth_clamped:3d}  "
          f"p50={rep.latency.percentile(50):6.1f}ms "
          f"p99={rep.latency.percentile(99):6.1f}ms")
    if telemetry:
        with open(TRACE_OUT, "w") as f:
            f.write(tracer.chrome_trace_json(indent=1))
        with open(METRICS_OUT, "w") as f:
            f.write(metrics.exposition())
        attribution = gw.budget.report_dict().get("latency_attribution", {})
        print(f"# telemetry: {tracer.n_finished} traced requests, "
              f"{len(tracer.problems())} trace problems, dominant stage "
              f"= {attribution.get('dominant_stage', '?')}")
        for stage, pct in sorted(tracer.stage_percentiles().items()):
            print(f"#   {stage:11s} n={pct['n']:4d} "
                  f"p50={pct['p50_ms']:8.2f}ms p99={pct['p99_ms']:8.2f}ms")
        print(f"# wrote {TRACE_OUT} and {METRICS_OUT}")


def main():
    cfg = TestbedConfig(n_train=300, n_eval=100, n_paragraphs=300,
                        router=RouterConfig(n_epochs=15))
    data, index, pipe, train_log, _ = build_testbed(cfg)
    policy = MLPPolicy.train(
        train_log, train_log.rewards(get_slo_profile("quality_first")),
        cfg.router, objective="argmax_ce")
    qs = data.questions[-100:]

    print(f"# {N_REQUESTS} requests per trace, deadline {DEADLINE_MS}ms, "
          f"4 service slots (virtual time)")
    for rate in (50.0, 200.0, 800.0, 3200.0):
        run(policy, cfg, index, pipe, qs,
            PoissonProcess(rate, seed=0), f"poisson {rate:6.0f}/s")
    # same mean rate as poisson 200/s, but clumped into bursts — the
    # on-off stream sheds where smooth traffic wouldn't
    run(policy, cfg, index, pipe, qs,
        OnOffProcess(400.0, on_s=0.25, off_s=0.25, seed=0),
        "on-off  mean 200/s")
    # once more with the telemetry plane attached: per-request span
    # trees + metrics registry on the same virtual clock
    run(policy, cfg, index, pipe, qs, PoissonProcess(200.0, seed=0),
        "poisson 200/s (traced)", telemetry=True)


if __name__ == "__main__":
    main()
