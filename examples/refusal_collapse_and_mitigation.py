"""Failure-mode demo (paper §6.2/§7.1): refusal collapse under the cheap
SLO and the Lagrangian refusal-cap mitigation, through the routing API.

    PYTHONPATH=src python examples/refusal_collapse_and_mitigation.py
"""
from repro.core.config import RouterConfig, TestbedConfig
from repro.core.metrics import best_fixed_action, evaluate_actions
from repro.core.offline_log import build_testbed
from repro.routing import ConstrainedPolicy, MLPPolicy, get_slo_profile


def main():
    cfg = TestbedConfig(n_train=400, n_eval=150, n_paragraphs=300,
                        router=RouterConfig(n_epochs=20))
    _, _, _, train_log, eval_log = build_testbed(cfg)
    profile = get_slo_profile("cheap")
    rewards = train_log.rewards(profile)

    print("== cheap SLO: vanilla Argmax-CE (collapses) ==")
    policy = MLPPolicy.train(train_log, rewards, cfg.router,
                             objective="argmax_ce")
    rep = evaluate_actions(eval_log, policy.actions(eval_log.states),
                           profile, "argmax_ce")
    print(rep.row())

    print("\n== mitigation: Lagrangian refusal cap (0.45) ==")
    con = ConstrainedPolicy.train(train_log, rewards, cfg.router,
                                  refusal_cap=0.45)
    repc = evaluate_actions(eval_log, con.actions(eval_log.states),
                            profile, "constrained")
    print(repc.row())
    print(f"final lambda = {con.lagrange:.3f}")

    _, bf = best_fixed_action(eval_log, profile)
    print(f"\nbest fixed action reward: {bf.reward:+.4f}")
    print(f"collapsed policy reward:  {rep.reward:+.4f} "
          f"(refusal {rep.refusal_rate:.2f})")
    print(f"mitigated policy reward:  {repc.reward:+.4f} "
          f"(refusal {repc.refusal_rate:.2f})")


if __name__ == "__main__":
    main()
