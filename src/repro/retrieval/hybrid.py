"""The Retriever protocol, hybrid fusion, and the serving-side cache.

Everything that executes a routed action's retrieval step goes through
one protocol: ``topk(query, k) -> (ids, scores)`` plus
``passages(query, k) -> texts``.  ``RAGPipeline.retrieve`` and
``EngineBackend._retrieve`` both consume it (they used to duplicate the
BM25 topk→texts logic), and ``Action.retriever`` names which registered
retriever an action uses — retriever choice is a routing action, the
same cost/quality lever as depth ("Cost-Aware Query Routing in RAG").

* :class:`IndexRetriever` — adapts any index with ``topk`` + ``texts``
  (:class:`~repro.retrieval.bm25.BM25Index`,
  :class:`~repro.retrieval.dense.DenseIndex`);
* :class:`HybridRetriever` — weighted / reciprocal-rank fusion of two
  or more candidate sets, deterministic (ties break by doc id);
* :class:`RetrievalCache` + :class:`CachedRetriever` — a bounded LRU
  keyed by (query, retriever, k) in front of any retriever; repeated
  queries in a serving stream stop re-scoring the whole corpus, and
  hit counters surface in ``GatewayStats``;
* :class:`CircuitBreaker` + :class:`BreakerRetriever` — per-retriever
  closed → open → half-open breaker on a windowed failure rate, so a
  browning-out retriever is cut off instead of hammered, and
  :func:`retrieve_with_fallback` rewrites the lookup to a bm25
  fallback as a *degraded* outcome the gateway accounts separately.

Wrapping order (see :func:`resolve_retrievers`) is
``CachedRetriever(BreakerRetriever(ChaosRetriever(raw)))``: cache hits
bypass open breakers, failures propagate before ``cache.put`` so a
failed lookup is never cached, and fallback results are produced by a
*different* retriever so they land under the fallback's own cache key,
never the original (query, retriever, k) key.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import (Dict, List, Mapping, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from repro.core.errors import CircuitOpenError, TransientFaultError


@runtime_checkable
class Retriever(Protocol):
    """One named way to turn a query into ranked passages."""

    name: str

    def topk(self, query: str, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """(doc ids, scores), scores descending."""
        ...

    def passages(self, query: str, k: int) -> List[str]:
        """The top-k passage texts (what the prompt builder consumes)."""
        ...


class IndexRetriever:
    """Adapter over any index exposing ``topk(query, k)`` + ``texts``."""

    def __init__(self, name: str, index):
        self.name = name
        self.index = index

    def topk(self, query: str, k: int):
        return self.index.topk(query, k)

    def passages(self, query: str, k: int) -> List[str]:
        if k <= 0:
            return []
        idx, _ = self.index.topk(query, k)
        return [self.index.texts[i] for i in idx]


class HybridRetriever:
    """Fuse candidate sets from several retrievers into one ranking.

    Each sub-retriever contributes its top-``k * candidate_mult`` docs;
    fusion is either

    * ``rrf`` — reciprocal rank fusion, score(d) = Σ_r w_r / (c + rank)
      [Cormack et al. 2009]: rank-only, so BM25's unbounded scores and
      the dense retriever's cosines need no calibration; or
    * ``weighted`` — min-max normalize each candidate list's scores to
      [0, 1], then a weighted sum.

    Deterministic: fused ties break toward the lower doc id, and
    iteration order over sub-retrievers is fixed by construction.
    """

    def __init__(self, retrievers: Sequence[Retriever], texts: List[str],
                 *, name: str = "hybrid", method: str = "rrf",
                 weights: Optional[Sequence[float]] = None,
                 rrf_c: int = 60, candidate_mult: int = 2):
        if method not in ("rrf", "weighted"):
            raise ValueError(f"unknown fusion method {method!r}")
        self.name = name
        self.retrievers = list(retrievers)
        self.texts = texts
        self.method = method
        self.weights = (list(weights) if weights is not None
                        else [1.0] * len(self.retrievers))
        assert len(self.weights) == len(self.retrievers)
        self.rrf_c = rrf_c
        self.candidate_mult = candidate_mult

    def _fused(self, query: str, k: int) -> Dict[int, float]:
        depth = max(k * self.candidate_mult, k)
        fused: Dict[int, float] = {}
        for r, w in zip(self.retrievers, self.weights):
            ids, scores = r.topk(query, depth)
            if len(ids) == 0:
                continue
            if self.method == "rrf":
                contrib = [w / (self.rrf_c + rank + 1)
                           for rank in range(len(ids))]
            else:
                s = np.asarray(scores, np.float64)
                span = float(s.max() - s.min())
                norm = (s - s.min()) / span if span > 0 \
                    else np.ones_like(s)
                contrib = (w * norm).tolist()
            for d, c in zip(np.asarray(ids).tolist(), contrib):
                fused[int(d)] = fused.get(int(d), 0.0) + c
        return fused

    def topk(self, query: str, k: int):
        if k <= 0:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        fused = self._fused(query, k)
        # sort by fused score desc, then doc id asc (deterministic)
        order = sorted(fused.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
        ids = np.array([d for d, _ in order], np.int64)
        scores = np.array([s for _, s in order], np.float32)
        return ids, scores

    def passages(self, query: str, k: int) -> List[str]:
        idx, _ = self.topk(query, k)
        return [self.texts[i] for i in idx]


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


class RetrievalCache:
    """Bounded LRU over retrieval results, shared across retrievers.

    Keys are ``(query, retriever_name, k)``; values are whatever the
    wrapped call returned (passage lists / topk tuples are immutable in
    practice — treat them as frozen).  ``hits``/``lookups`` feed
    ``GatewayStats.retrieval_cache_{hits,lookups}``.
    """

    def __init__(self, maxsize: int = 1024):
        assert maxsize > 0, maxsize
        self.maxsize = maxsize
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.lookups = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key):
        self.lookups += 1
        if key in self._d:
            self.hits += 1
            self._d.move_to_end(key)
            return self._d[key]
        return None

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)


class CachedRetriever:
    """LRU front for any :class:`Retriever` (keyed query × name × k)."""

    def __init__(self, inner: Retriever, cache: RetrievalCache):
        self.inner = inner
        self.name = inner.name
        self.cache = cache

    def topk(self, query: str, k: int):
        key = (query, self.name, k, "topk")
        out = self.cache.get(key)
        if out is None:
            out = self.inner.topk(query, k)
            self.cache.put(key, out)
        return out

    def passages(self, query: str, k: int) -> List[str]:
        key = (query, self.name, k, "passages")
        out = self.cache.get(key)
        if out is None:
            out = self.inner.passages(query, k)
            self.cache.put(key, out)
        return out


# ---------------------------------------------------------------------------
# Circuit breakers
# ---------------------------------------------------------------------------


class CircuitBreaker:
    """Closed → open → half-open breaker on a windowed failure rate.

    Deterministic by default and clock-free: the window is the last
    ``window`` *calls* (a bounded deque, so old outcomes age out), and
    the open-state cooldown is counted in *denied calls* rather than
    wall time — the same call sequence always walks the same state
    path, which is what the chaos tests replay.

    Passing ``clock`` (a ``perf_counter``-style callable — the traffic
    harness's :class:`~repro.serving.traffic.VirtualClock` works) with
    ``cooldown_s`` switches the open→half-open transition to wall-clock
    pacing: a sparse caller no longer has to burn ``cooldown`` denied
    calls to reach a probe, and a hot caller cannot probe a still-down
    service early just by hammering it.  Runs stay replayable when the
    clock is virtual.

    * **closed** — calls flow; each outcome lands in the window.  When
      the window holds ≥ ``min_calls`` outcomes and the failure rate
      reaches ``failure_threshold``, the breaker trips open.
    * **open** — call-count mode: ``allow()`` refuses the next
      ``cooldown - 1`` calls; the ``cooldown``-th attempted call moves
      the breaker to half-open and becomes its first probe.  Clock
      mode: calls are refused until ``cooldown_s`` seconds after the
      trip; the first call at or past that instant is the probe.
    * **half-open** — up to ``half_open_probes`` trial calls pass; one
      success closes the breaker (window cleared — the service is
      deemed recovered), one failure reopens it.
    """

    def __init__(self, *, window: int = 32, failure_threshold: float = 0.5,
                 min_calls: int = 8, cooldown: int = 16,
                 half_open_probes: int = 1, clock=None,
                 cooldown_s: Optional[float] = None):
        assert window >= min_calls >= 1, (window, min_calls)
        assert 0.0 < failure_threshold <= 1.0, failure_threshold
        assert cooldown >= 1 and half_open_probes >= 1
        if (clock is None) != (cooldown_s is None):
            raise ValueError("clock and cooldown_s come together: both "
                             "set (wall-clock cooldown) or neither "
                             "(call-count cooldown)")
        if cooldown_s is not None and cooldown_s <= 0:
            raise ValueError(f"cooldown_s must be > 0, got {cooldown_s}")
        self.window = window
        self.failure_threshold = failure_threshold
        self.min_calls = min_calls
        self.cooldown = cooldown
        self.half_open_probes = half_open_probes
        self.clock = clock
        self.cooldown_s = cooldown_s
        self.state = "closed"
        self._events: deque = deque(maxlen=window)   # True = failure
        self._denied_since_open = 0
        self._opened_at = 0.0
        self._probes_out = 0
        self.n_trips = 0
        self.n_denied = 0

    def failure_rate(self) -> float:
        if not self._events:
            return 0.0
        return sum(self._events) / len(self._events)

    def allow(self) -> bool:
        """May a call proceed right now?  (Counts cooldown progress.)"""
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.clock is not None:
                cooled = (self.clock() - self._opened_at
                          >= self.cooldown_s)
            else:
                self._denied_since_open += 1
                cooled = self._denied_since_open >= self.cooldown
            if cooled:
                self.state = "half_open"
                self._probes_out = 0
            else:
                self.n_denied += 1
                return False
        # half-open: admit a bounded number of probes
        if self._probes_out < self.half_open_probes:
            self._probes_out += 1
            return True
        self.n_denied += 1
        return False

    def record_success(self) -> None:
        if self.state == "half_open":
            self.state = "closed"
            self._events.clear()
            self._probes_out = 0
        elif self.state == "closed":
            self._events.append(False)

    def record_failure(self) -> None:
        if self.state == "half_open":
            self._trip()
        elif self.state == "closed":
            self._events.append(True)
            if (len(self._events) >= self.min_calls
                    and self.failure_rate() >= self.failure_threshold):
                self._trip()

    def _trip(self) -> None:
        self.state = "open"
        self.n_trips += 1
        self._denied_since_open = 0
        self._opened_at = self.clock() if self.clock is not None else 0.0
        self._probes_out = 0

    def reset(self) -> None:
        self.state = "closed"
        self._events.clear()
        self._denied_since_open = 0
        self._probes_out = 0


class BreakerRetriever:
    """Per-retriever breaker seam: refuses calls while the breaker is
    open (:class:`~repro.core.errors.CircuitOpenError`) and records
    success/failure of every call that does pass."""

    def __init__(self, inner: Retriever,
                 breaker: Optional[CircuitBreaker] = None):
        self.inner = inner
        self.name = inner.name
        self.breaker = breaker if breaker is not None else CircuitBreaker()

    def _call(self, fn, *args):
        if not self.breaker.allow():
            raise CircuitOpenError(self.name)
        try:
            out = fn(*args)
        except Exception:
            self.breaker.record_failure()
            raise
        self.breaker.record_success()
        return out

    def topk(self, query: str, k: int):
        return self._call(self.inner.topk, query, k)

    def passages(self, query: str, k: int) -> List[str]:
        return self._call(self.inner.passages, query, k)


def collect_breakers(retrievers: Mapping[str, Retriever]
                     ) -> Dict[str, CircuitBreaker]:
    """Find the breaker for each named retriever by unwrapping the
    ``CachedRetriever(BreakerRetriever(...))`` chain (empty entries for
    retrievers without one)."""
    out: Dict[str, CircuitBreaker] = {}
    for name, r in retrievers.items():
        node = r
        while node is not None:
            brk = getattr(node, "breaker", None)
            if isinstance(brk, CircuitBreaker):
                out[name] = brk
                break
            node = getattr(node, "inner", None)
    return out


def retrieve_with_fallback(retrievers: Mapping[str, Retriever],
                           name: str, query: str, k: int, *,
                           fallback: str = "bm25", tracer=None
                           ) -> Tuple[List[str], bool]:
    """Fetch passages from ``name``, degrading to ``fallback`` when the
    primary fails (open breaker, injected fault, any exception).

    Returns ``(passages, degraded)``.  The fallback lookup goes through
    the fallback retriever's *own* wrapped entry, so its result is
    cached (if at all) under the fallback's key — never the primary's.
    If the primary *is* the fallback, or the fallback is missing or
    also fails, the original failure is re-raised wrapped as a
    :class:`~repro.core.errors.TransientFaultError` for the gateway's
    retry path.

    ``tracer`` (a :class:`repro.obs.Tracer`, or None/``NULL_TRACER``)
    records the lookup as an anonymous ``retrieval`` span — this layer
    doesn't know the request qid, so the gateway adopts the note onto
    the request it is submitting (see ``Tracer.note``/``adopt``).
    """
    primary = retrievers[name]
    t0 = tracer.now() if tracer is not None else 0.0
    try:
        passages = primary.passages(query, k)
    except Exception as exc:
        fb = retrievers.get(fallback)
        if fb is None or name == fallback:
            if tracer is not None:
                tracer.note("retrieval", t0, tracer.now(),
                            retriever=name, k=k, failed=True)
            if isinstance(exc, TransientFaultError):
                raise
            raise TransientFaultError(
                f"retriever {name!r} failed with no fallback: {exc}") from exc
        try:
            out = fb.passages(query, k), True
        except Exception as fb_exc:
            if tracer is not None:
                tracer.note("retrieval", t0, tracer.now(),
                            retriever=name, k=k, failed=True)
            raise TransientFaultError(
                f"retriever {name!r} and fallback {fallback!r} both "
                f"failed: {exc}; {fb_exc}") from fb_exc
        if tracer is not None:
            tracer.note("retrieval", t0, tracer.now(),
                        retriever=name, k=k, degraded=True,
                        fallback=fallback)
        return out
    if tracer is not None:
        tracer.note("retrieval", t0, tracer.now(), retriever=name, k=k)
    return passages, False


def bind_retrieval_metrics(registry, breakers: Mapping[str, CircuitBreaker],
                           cache: Optional[RetrievalCache]) -> None:
    """Register retrieval-plane stats (shared LRU hit counters, per-
    retriever breaker state/trips/denials) as scrape-time views over a
    :class:`repro.obs.MetricsRegistry`."""
    insts = {}
    if cache is not None:
        insts["hits"] = registry.counter(
            "retrieval_cache_hits_total", "shared retrieval LRU hits")
        insts["lookups"] = registry.counter(
            "retrieval_cache_lookups_total",
            "shared retrieval LRU lookups")
    for bname in sorted(breakers):
        insts[f"trips_{bname}"] = registry.counter(
            f"breaker_{bname}_trips_total",
            f"circuit-breaker trips for retriever {bname}")
        insts[f"denied_{bname}"] = registry.counter(
            f"breaker_{bname}_denied_total",
            f"calls denied by the {bname} breaker")
        insts[f"open_{bname}"] = registry.gauge(
            f"breaker_{bname}_open",
            f"1 when the {bname} breaker is not closed")

    def scrape() -> None:
        if cache is not None:
            insts["hits"].set_total(cache.hits)
            insts["lookups"].set_total(cache.lookups)
        for bname, brk in breakers.items():
            insts[f"trips_{bname}"].set_total(brk.n_trips)
            insts[f"denied_{bname}"].set_total(brk.n_denied)
            insts[f"open_{bname}"].set(0.0 if brk.state == "closed"
                                       else 1.0)

    registry.register_collector(scrape)


# ---------------------------------------------------------------------------
# Construction helpers (shared by RAGPipeline and the engine backends)
# ---------------------------------------------------------------------------


def build_retriever_suite(index, dense_index=None, *,
                          method: Optional[str] = None,
                          alpha: Optional[float] = None
                          ) -> Dict[str, Retriever]:
    """The standard named-retriever set over one corpus.

    ``bm25`` always; ``dense`` and ``hybrid`` (bm25 + dense fusion)
    when a :class:`~repro.retrieval.dense.DenseIndex` is given.  Fusion
    method/weights default from the index's ``RetrievalConfig``.
    """
    bm25 = IndexRetriever("bm25", index)
    suite: Dict[str, Retriever] = {"bm25": bm25}
    if dense_index is not None:
        dense = IndexRetriever("dense", dense_index)
        cfg = getattr(dense_index, "cfg", None)
        method = method or getattr(cfg, "hybrid_method", "rrf")
        a = alpha if alpha is not None else getattr(cfg, "hybrid_alpha", 0.5)
        suite["dense"] = dense
        suite["hybrid"] = HybridRetriever(
            [bm25, dense], dense_index.texts, method=method,
            weights=[a, 1.0 - a])
    return suite


def resolve_retrievers(retrievers: Optional[Mapping[str, Retriever]],
                       index, *, cache_size: int = 0,
                       breakers: bool = True,
                       breaker_kw: Optional[Dict] = None,
                       chaos=None
                       ) -> Tuple[Dict[str, Retriever],
                                  Optional[RetrievalCache]]:
    """Normalize an executor's retriever config.

    ``retrievers=None`` gives the bm25-only default over ``index`` (the
    seed behaviour, bit-for-bit); ``cache_size > 0`` wraps every
    retriever behind ONE shared bounded LRU and returns it so serving
    stats can report hit rates.  ``breakers`` (default on — a closed
    breaker is a pass-through, so healthy behaviour is unchanged) adds
    a per-retriever :class:`CircuitBreaker` (``breaker_kw`` forwarded
    to each); ``chaos`` (a :class:`~repro.serving.faults.ChaosInjector`)
    installs fault seams innermost, so injected failures trip breakers
    and never reach the cache.  Recover the breakers afterwards with
    :func:`collect_breakers`.
    """
    if retrievers is None:
        retrievers = {"bm25": IndexRetriever("bm25", index)}
    retrievers = dict(retrievers)
    if chaos is not None and getattr(chaos, "armed", False):
        from repro.serving.faults import chaos_wrap_retrievers
        retrievers = chaos_wrap_retrievers(retrievers, chaos)
    if breakers:
        retrievers = {
            name: BreakerRetriever(r, CircuitBreaker(**(breaker_kw or {})))
            for name, r in retrievers.items()}
    cache = None
    if cache_size > 0:
        cache = RetrievalCache(cache_size)
        retrievers = {name: CachedRetriever(r, cache)
                      for name, r in retrievers.items()}
    return retrievers, cache
