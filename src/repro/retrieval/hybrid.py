"""The Retriever protocol, hybrid fusion, and the serving-side cache.

Everything that executes a routed action's retrieval step goes through
one protocol: ``topk(query, k) -> (ids, scores)`` plus
``passages(query, k) -> texts``.  ``RAGPipeline.retrieve`` and
``EngineBackend._retrieve`` both consume it (they used to duplicate the
BM25 topk→texts logic), and ``Action.retriever`` names which registered
retriever an action uses — retriever choice is a routing action, the
same cost/quality lever as depth ("Cost-Aware Query Routing in RAG").

* :class:`IndexRetriever` — adapts any index with ``topk`` + ``texts``
  (:class:`~repro.retrieval.bm25.BM25Index`,
  :class:`~repro.retrieval.dense.DenseIndex`);
* :class:`HybridRetriever` — weighted / reciprocal-rank fusion of two
  or more candidate sets, deterministic (ties break by doc id);
* :class:`RetrievalCache` + :class:`CachedRetriever` — a bounded LRU
  keyed by (query, retriever, k) in front of any retriever; repeated
  queries in a serving stream stop re-scoring the whole corpus, and
  hit counters surface in ``GatewayStats``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import (Dict, List, Mapping, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np


@runtime_checkable
class Retriever(Protocol):
    """One named way to turn a query into ranked passages."""

    name: str

    def topk(self, query: str, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """(doc ids, scores), scores descending."""
        ...

    def passages(self, query: str, k: int) -> List[str]:
        """The top-k passage texts (what the prompt builder consumes)."""
        ...


class IndexRetriever:
    """Adapter over any index exposing ``topk(query, k)`` + ``texts``."""

    def __init__(self, name: str, index):
        self.name = name
        self.index = index

    def topk(self, query: str, k: int):
        return self.index.topk(query, k)

    def passages(self, query: str, k: int) -> List[str]:
        if k <= 0:
            return []
        idx, _ = self.index.topk(query, k)
        return [self.index.texts[i] for i in idx]


class HybridRetriever:
    """Fuse candidate sets from several retrievers into one ranking.

    Each sub-retriever contributes its top-``k * candidate_mult`` docs;
    fusion is either

    * ``rrf`` — reciprocal rank fusion, score(d) = Σ_r w_r / (c + rank)
      [Cormack et al. 2009]: rank-only, so BM25's unbounded scores and
      the dense retriever's cosines need no calibration; or
    * ``weighted`` — min-max normalize each candidate list's scores to
      [0, 1], then a weighted sum.

    Deterministic: fused ties break toward the lower doc id, and
    iteration order over sub-retrievers is fixed by construction.
    """

    def __init__(self, retrievers: Sequence[Retriever], texts: List[str],
                 *, name: str = "hybrid", method: str = "rrf",
                 weights: Optional[Sequence[float]] = None,
                 rrf_c: int = 60, candidate_mult: int = 2):
        if method not in ("rrf", "weighted"):
            raise ValueError(f"unknown fusion method {method!r}")
        self.name = name
        self.retrievers = list(retrievers)
        self.texts = texts
        self.method = method
        self.weights = (list(weights) if weights is not None
                        else [1.0] * len(self.retrievers))
        assert len(self.weights) == len(self.retrievers)
        self.rrf_c = rrf_c
        self.candidate_mult = candidate_mult

    def _fused(self, query: str, k: int) -> Dict[int, float]:
        depth = max(k * self.candidate_mult, k)
        fused: Dict[int, float] = {}
        for r, w in zip(self.retrievers, self.weights):
            ids, scores = r.topk(query, depth)
            if len(ids) == 0:
                continue
            if self.method == "rrf":
                contrib = [w / (self.rrf_c + rank + 1)
                           for rank in range(len(ids))]
            else:
                s = np.asarray(scores, np.float64)
                span = float(s.max() - s.min())
                norm = (s - s.min()) / span if span > 0 \
                    else np.ones_like(s)
                contrib = (w * norm).tolist()
            for d, c in zip(np.asarray(ids).tolist(), contrib):
                fused[int(d)] = fused.get(int(d), 0.0) + c
        return fused

    def topk(self, query: str, k: int):
        if k <= 0:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        fused = self._fused(query, k)
        # sort by fused score desc, then doc id asc (deterministic)
        order = sorted(fused.items(), key=lambda kv: (-kv[1], kv[0]))[:k]
        ids = np.array([d for d, _ in order], np.int64)
        scores = np.array([s for _, s in order], np.float32)
        return ids, scores

    def passages(self, query: str, k: int) -> List[str]:
        idx, _ = self.topk(query, k)
        return [self.texts[i] for i in idx]


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


class RetrievalCache:
    """Bounded LRU over retrieval results, shared across retrievers.

    Keys are ``(query, retriever_name, k)``; values are whatever the
    wrapped call returned (passage lists / topk tuples are immutable in
    practice — treat them as frozen).  ``hits``/``lookups`` feed
    ``GatewayStats.retrieval_cache_{hits,lookups}``.
    """

    def __init__(self, maxsize: int = 1024):
        assert maxsize > 0, maxsize
        self.maxsize = maxsize
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.lookups = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key):
        self.lookups += 1
        if key in self._d:
            self.hits += 1
            self._d.move_to_end(key)
            return self._d[key]
        return None

    def put(self, key, value) -> None:
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)


class CachedRetriever:
    """LRU front for any :class:`Retriever` (keyed query × name × k)."""

    def __init__(self, inner: Retriever, cache: RetrievalCache):
        self.inner = inner
        self.name = inner.name
        self.cache = cache

    def topk(self, query: str, k: int):
        key = (query, self.name, k, "topk")
        out = self.cache.get(key)
        if out is None:
            out = self.inner.topk(query, k)
            self.cache.put(key, out)
        return out

    def passages(self, query: str, k: int) -> List[str]:
        key = (query, self.name, k, "passages")
        out = self.cache.get(key)
        if out is None:
            out = self.inner.passages(query, k)
            self.cache.put(key, out)
        return out


# ---------------------------------------------------------------------------
# Construction helpers (shared by RAGPipeline and the engine backends)
# ---------------------------------------------------------------------------


def build_retriever_suite(index, dense_index=None, *,
                          method: Optional[str] = None,
                          alpha: Optional[float] = None
                          ) -> Dict[str, Retriever]:
    """The standard named-retriever set over one corpus.

    ``bm25`` always; ``dense`` and ``hybrid`` (bm25 + dense fusion)
    when a :class:`~repro.retrieval.dense.DenseIndex` is given.  Fusion
    method/weights default from the index's ``RetrievalConfig``.
    """
    bm25 = IndexRetriever("bm25", index)
    suite: Dict[str, Retriever] = {"bm25": bm25}
    if dense_index is not None:
        dense = IndexRetriever("dense", dense_index)
        cfg = getattr(dense_index, "cfg", None)
        method = method or getattr(cfg, "hybrid_method", "rrf")
        a = alpha if alpha is not None else getattr(cfg, "hybrid_alpha", 0.5)
        suite["dense"] = dense
        suite["hybrid"] = HybridRetriever(
            [bm25, dense], dense_index.texts, method=method,
            weights=[a, 1.0 - a])
    return suite


def resolve_retrievers(retrievers: Optional[Mapping[str, Retriever]],
                       index, *, cache_size: int = 0
                       ) -> Tuple[Dict[str, Retriever],
                                  Optional[RetrievalCache]]:
    """Normalize an executor's retriever config.

    ``retrievers=None`` gives the bm25-only default over ``index`` (the
    seed behaviour, bit-for-bit); ``cache_size > 0`` wraps every
    retriever behind ONE shared bounded LRU and returns it so serving
    stats can report hit rates.
    """
    if retrievers is None:
        retrievers = {"bm25": IndexRetriever("bm25", index)}
    retrievers = dict(retrievers)
    cache = None
    if cache_size > 0:
        cache = RetrievalCache(cache_size)
        retrievers = {name: CachedRetriever(r, cache)
                      for name, r in retrievers.items()}
    return retrievers, cache
