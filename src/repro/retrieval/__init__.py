from repro.retrieval.bm25 import BM25Index

__all__ = ["BM25Index"]
