"""Multi-method retrieval subsystem.

* ``bm25.py``        — sparse lexical BM25 over a hashed vocab;
* ``dense.py``       — dense retrieval over hashed n-gram embeddings
  (Pallas fused score+top-k kernel in ``repro.kernels.dense_topk``);
* ``hybrid.py``      — the :class:`Retriever` protocol, weighted/RRF
  fusion, and the bounded LRU retrieval cache;
* ``distributed.py`` — corpus sharded over the mesh's data axis, one
  local-top-k → all-gather → merge path shared by BM25 and dense.
"""
from repro.retrieval.bm25 import BM25Index
from repro.retrieval.dense import DenseIndex, embed_text
from repro.retrieval.distributed import (DistributedBM25,
                                         DistributedDenseIndex,
                                         distributed_bm25_topk,
                                         distributed_dense_topk,
                                         distributed_topk)
from repro.retrieval.hybrid import (BreakerRetriever, CachedRetriever,
                                    CircuitBreaker, CircuitOpenError,
                                    HybridRetriever, IndexRetriever,
                                    RetrievalCache, Retriever,
                                    build_retriever_suite, collect_breakers,
                                    resolve_retrievers,
                                    retrieve_with_fallback)

__all__ = [
    "BM25Index", "DenseIndex", "embed_text",
    "DistributedBM25", "DistributedDenseIndex", "distributed_topk",
    "distributed_bm25_topk", "distributed_dense_topk",
    "Retriever", "IndexRetriever", "HybridRetriever",
    "RetrievalCache", "CachedRetriever",
    "CircuitBreaker", "CircuitOpenError", "BreakerRetriever",
    "collect_breakers", "retrieve_with_fallback",
    "build_retriever_suite", "resolve_retrievers",
]
