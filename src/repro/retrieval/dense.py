"""Dense vector retrieval over deterministic hashed n-gram embeddings.

No external embedding model ships in this container (same gate that
makes BM25 use a hashed vocab), so the encoder is a signed feature-hash
of word uni+bigrams: each n-gram adds ±1 (±0.5 for bigrams) to a hashed
bucket, with the sign drawn from an independent hash bit so collisions
cancel in expectation [Weinberger et al. 2009].  Rows are L2-normalized,
making the doc-matrix contraction a cosine similarity.  The embedding
dim is 128-aligned (``RetrievalConfig.dense_embed_dim``) so the (D, E)
matrix feeds the MXU-blocked Pallas kernel directly.

Scoring paths, mirroring ``bm25.py``:

* ``scores_np`` / ``topk`` — numpy oracle for the host serving path;
* ``topk_batch`` — the fused Pallas score+top-k kernel
  (``repro.kernels.dense_topk``): blocked similarity with an online
  partial-top-k reduction, never materializing the (Q, D) matrix;
* sharding — ``repro.retrieval.distributed.DistributedDenseIndex``
  shards the doc matrix over the mesh's data axis and merges local
  top-k candidate sets.

The lexical (BM25) and dense views rank genuinely differently: BM25 is
driven by exact-term idf weighting, the dense encoder by signed n-gram
overlap incl. bigram order — which is what makes retriever choice a
real routing action (see ``retrieval/hybrid.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.config import RetrievalConfig
from repro.data.tokenizer import words, _h


def _signed(token: str, dim: int, v: np.ndarray, weight: float) -> None:
    # independent hash bit for the sign (salted so it does not correlate
    # with the bucket index)
    sign = 1.0 if _h(token + "#sgn", 2) else -1.0
    v[_h(token, dim)] += weight * sign


def embed_text(text: str, dim: int) -> np.ndarray:
    """Deterministic signed hashed uni+bigram embedding, L2-normalized."""
    v = np.zeros(dim, np.float32)
    ws = words(text)
    for i, w in enumerate(ws):
        _signed(w, dim, v, 1.0)
        if i + 1 < len(ws):
            _signed(w + "_" + ws[i + 1], dim, v, 0.5)
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


@dataclass
class DenseIndex:
    cfg: RetrievalConfig
    emb: np.ndarray          # (D, E) float32, rows L2-normalized
    texts: List[str]

    @classmethod
    def build(cls, docs: Sequence[str],
              cfg: RetrievalConfig = RetrievalConfig()) -> "DenseIndex":
        E = cfg.dense_embed_dim
        emb = np.stack([embed_text(doc, E) for doc in docs]) if docs \
            else np.zeros((0, E), np.float32)
        return cls(cfg, emb.astype(np.float32), list(docs))

    def encode(self, query: str) -> np.ndarray:
        return embed_text(query, self.cfg.dense_embed_dim)

    # ------------------------------------------------------------------
    # scoring
    # ------------------------------------------------------------------
    def scores_np(self, qe: np.ndarray) -> np.ndarray:
        """Reference numpy cosine scores for one query (E,) -> (D,)."""
        return self.emb @ qe

    def topk(self, query: str, k: int) -> Tuple[np.ndarray, np.ndarray]:
        """(indices, scores) of the top-k docs — numpy oracle path.

        Exact ``lax.top_k`` semantics including ties: a full
        (-score, doc id) lexsort, so exact-score ties break toward the
        lower doc id even when they straddle the k boundary (an
        argpartition would pick arbitrary tie members there and diverge
        from the kernel/distributed paths).  O(D log D) on the host is
        noise at serving corpus sizes.
        """
        if k <= 0:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        s = self.scores_np(self.encode(query))
        k = min(k, len(s))
        idx = np.lexsort((np.arange(len(s)), -s))[:k]
        return idx, s[idx]

    def topk_batch(self, queries: Sequence[str], k: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Batched top-k through the fused Pallas kernel.

        Returns (ids (Q, k) int64, scores (Q, k) float32).  The blocked
        kernel folds each score tile into a running per-query top-k in
        VMEM — the full (Q, D) similarity matrix never materializes.
        """
        from repro.kernels import dense_topk
        qe = np.stack([self.encode(q) for q in queries])
        s, i = dense_topk(qe, self.emb, k=min(k, len(self.texts)))
        return np.asarray(i, np.int64), np.asarray(s)
