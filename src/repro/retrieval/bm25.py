"""BM25 sparse lexical retrieval over a hashed vocabulary.

The paper's retriever is BM25-style bag-of-words scoring over raw SQuAD
paragraphs [Robertson & Zaragoza 2009].  TPU adaptation (DESIGN.md §4):
instead of a GPU-style sparse gather we keep a dense (docs × hashed
vocab) term-frequency matrix, 128-aligned, and score query batches as a
blocked dense contraction — see ``repro.kernels.bm25`` for the Pallas
kernel; this module holds the index build and the jnp scoring oracle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import RetrievalConfig
from repro.data.tokenizer import words, _h


def hash_term(w: str, dim: int) -> int:
    return _h(w, dim)


@dataclass
class BM25Index:
    cfg: RetrievalConfig
    tf: np.ndarray          # (D, V) float32 term frequencies
    doc_len: np.ndarray     # (D,)
    idf: np.ndarray         # (V,)
    texts: List[str]

    @classmethod
    def build(cls, docs: Sequence[str], cfg: RetrievalConfig = RetrievalConfig()):
        V, D = cfg.vocab_hash_dim, len(docs)
        tf = np.zeros((D, V), np.float32)
        for i, doc in enumerate(docs):
            for w in words(doc):
                tf[i, hash_term(w, V)] += 1.0
        doc_len = tf.sum(axis=1)
        df = (tf > 0).sum(axis=0)
        idf = np.log(1.0 + (D - df + 0.5) / (df + 0.5)).astype(np.float32)
        return cls(cfg, tf, doc_len, idf, list(docs))

    def query_vector(self, query: str) -> np.ndarray:
        v = np.zeros(self.cfg.vocab_hash_dim, np.float32)
        for w in words(query):
            v[hash_term(w, self.cfg.vocab_hash_dim)] += 1.0
        return v

    # ------------------------------------------------------------------
    # scoring
    # ------------------------------------------------------------------
    def scores_np(self, qv: np.ndarray) -> np.ndarray:
        """Reference numpy BM25 for one query vector (V,) -> (D,)."""
        k1, b = self.cfg.k1, self.cfg.b
        avg = self.doc_len.mean() + 1e-6
        norm = k1 * (1 - b + b * self.doc_len[:, None] / avg)
        sat = self.tf * (k1 + 1) / (self.tf + norm)
        return (sat * (self.idf * qv)[None, :]).sum(axis=1)

    def scores_batch(self, qvs: jnp.ndarray) -> jnp.ndarray:
        """jnp batched scoring: (Q, V) -> (Q, D).  jit-able oracle."""
        k1, b = self.cfg.k1, self.cfg.b
        tf = jnp.asarray(self.tf)
        dl = jnp.asarray(self.doc_len)
        avg = dl.mean() + 1e-6
        norm = k1 * (1 - b + b * dl[:, None] / avg)
        sat = tf * (k1 + 1) / (tf + norm)          # (D, V)
        w = qvs * jnp.asarray(self.idf)[None, :]   # (Q, V)
        return w @ sat.T

    def topk(self, query: str, k: int):
        """Returns (indices, scores) of the top-k docs for a query."""
        if k <= 0:
            return np.zeros(0, np.int64), np.zeros(0, np.float32)
        s = self.scores_np(self.query_vector(query))
        idx = np.argpartition(-s, min(k, len(s) - 1))[:k]
        idx = idx[np.argsort(-s[idx])]
        return idx, s[idx]

    def score_stats(self, query: str, k: int = 5) -> np.ndarray:
        """Uncertainty indicators from retrieval scores (paper §3.3)."""
        s = self.scores_np(self.query_vector(query))
        top = np.sort(s)[::-1][:k]
        gap = top[0] - top[1] if len(top) > 1 else 0.0
        return np.array([top[0], top.mean(), top.std(), gap], np.float32)

    def cooccurrence_stats(self, query: str, k: int = 5) -> np.ndarray:
        """Do the query's two highest-idf terms co-occur in any top doc?

        A cheap evidence-presence indicator (still purely a function of
        retrieval scores/term statistics — no oracle access): SQuAD-style
        unanswerables tend to lack any document containing both the
        entity and the asked attribute.
        """
        V = self.cfg.vocab_hash_dim
        qv = self.query_vector(query)
        terms = np.nonzero(qv)[0]
        if len(terms) == 0:
            return np.zeros(4, np.float32)
        by_idf = terms[np.argsort(-self.idf[terms])][:2]
        idx, _ = self.topk(query, k)
        present = (self.tf[idx][:, by_idf] > 0)          # (k, <=2)
        both = present.all(axis=1).astype(np.float32)
        return np.array([
            both.max(initial=0.0),
            both.mean() if len(both) else 0.0,
            present[:, 0].mean() if len(idx) else 0.0,
            present[:, -1].mean() if len(idx) else 0.0,
        ], np.float32)
