"""Distributed BM25 retrieval: corpus sharded over the mesh.

Production RAG serves corpora that don't fit one device.  The dense
(docs × hashed-vocab) TF matrix shards over the mesh's data axis; each
shard scores its local block (the Pallas bm25 kernel on TPU) and emits a
local top-k; a gather + final top-k merges candidates.  Communication
per query is O(shards × k) scores + ids — independent of corpus size.

Used by the retrieval dry-run (tests/test_distributed_retrieval.py runs
it on a real 8-device host mesh) and available to the serving pipeline
via ``DistributedBM25``.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.moe_parallel import shard_map


def _local_scores(tf_loc, qv, doc_len_loc, avg_len, k1, b):
    """BM25 over the local doc shard.  tf_loc: (D_loc, V); qv: (Q, V)."""
    norm = k1 * (1 - b + b * doc_len_loc[:, None] / avg_len)
    sat = tf_loc * (k1 + 1) / (tf_loc + norm)
    return qv @ sat.T                                   # (Q, D_loc)


def _shard_body(tf_loc, qv, dl_loc, *, avg_len, k, k1, b, axis):
    scores = _local_scores(tf_loc, qv, dl_loc, avg_len, k1, b)
    top_s, top_i = jax.lax.top_k(scores, k)             # local candidates
    # globalize ids: offset by shard index
    shard = jax.lax.axis_index(axis)
    top_i = top_i + shard * tf_loc.shape[0]
    # gather all shards' candidates -> (Q, shards*k), final top-k
    all_s = jax.lax.all_gather(top_s, axis, axis=1, tiled=True)
    all_i = jax.lax.all_gather(top_i, axis, axis=1, tiled=True)
    best_s, pos = jax.lax.top_k(all_s, k)
    best_i = jnp.take_along_axis(all_i, pos, axis=1)
    return best_s, best_i


def distributed_topk(mesh: Mesh, tf: jax.Array, doc_len: jax.Array,
                     qv: jax.Array, *, k: int = 10, k1: float = 1.2,
                     b: float = 0.75, axis: str = "data"
                     ) -> Tuple[jax.Array, jax.Array]:
    """Top-k over a corpus sharded on ``axis``.

    tf: (D, V) global TF matrix (sharded on docs); qv: (Q, V) replicated
    idf-weighted query vectors.  Returns (scores (Q,k), doc_ids (Q,k)).
    """
    avg_len = float(np.asarray(jnp.mean(doc_len))) + 1e-6
    n_axis = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    assert tf.shape[0] % n_axis == 0, (tf.shape, n_axis)

    fn = shard_map(
        partial(_shard_body, avg_len=avg_len, k=k, k1=k1, b=b, axis=axis),
        mesh,
        in_specs=(P(axis, None), P(None, None), P(axis)),
        out_specs=(P(None, None), P(None, None)),
    )
    return jax.jit(fn)(tf, qv, doc_len)


class DistributedBM25:
    """Drop-in scorer over a sharded corpus for the serving pipeline."""

    def __init__(self, mesh: Mesh, tf: np.ndarray, doc_len: np.ndarray,
                 idf: np.ndarray, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis
        ax_spec = NamedSharding(mesh, P(axis, None))
        self.tf = jax.device_put(jnp.asarray(tf), ax_spec)
        self.doc_len = jax.device_put(jnp.asarray(doc_len),
                                      NamedSharding(mesh, P(axis)))
        self.idf = jnp.asarray(idf)

    def topk(self, query_tf: np.ndarray, k: int = 10):
        qv = jnp.asarray(query_tf) * self.idf[None, :]
        with self.mesh:
            s, i = distributed_topk(self.mesh, self.tf, self.doc_len, qv,
                                    k=k, axis=self.axis)
        return np.asarray(s), np.asarray(i)
