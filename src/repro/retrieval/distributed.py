"""Distributed retrieval: corpus sharded over the mesh.

Production RAG serves corpora that don't fit one device.  The doc-major
matrix — the dense (docs × hashed-vocab) BM25 TF matrix, or the dense
retriever's (docs × embed) embedding matrix — shards over the mesh's
data axis; each shard scores its local block (the Pallas bm25 /
dense_topk kernels on TPU) and emits a local top-k; a gather + final
top-k merges candidates.  Both retrievers share ONE merge path
(:func:`distributed_topk` — score_fn is the only thing that differs),
so communication per query is O(shards × k) scores + ids for either,
independent of corpus size.

Used by the retrieval dry-run (tests/test_distributed_retrieval.py and
tests/test_dense_retrieval.py run it on a real 8-device host mesh) and
available to the serving pipeline via :class:`DistributedBM25` /
:class:`DistributedDenseIndex` (exported from ``repro.retrieval``).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.moe_parallel import shard_map


def _merge_local_topk(scores, *, k: int, axis: str, d_local: int):
    """Local top-k -> globalized ids -> all-gather -> final top-k.

    The shared merge tail of every sharded retriever: ``scores`` is the
    (Q, D_loc) block this shard scored; candidates gather in shard
    order, so exact-score ties resolve to the lowest global doc id —
    identical to ``lax.top_k`` over the unsharded score row.
    """
    top_s, top_i = jax.lax.top_k(scores, k)             # local candidates
    shard = jax.lax.axis_index(axis)
    top_i = top_i + shard * d_local
    all_s = jax.lax.all_gather(top_s, axis, axis=1, tiled=True)
    all_i = jax.lax.all_gather(top_i, axis, axis=1, tiled=True)
    best_s, pos = jax.lax.top_k(all_s, k)
    best_i = jnp.take_along_axis(all_i, pos, axis=1)
    return best_s, best_i


def distributed_topk(mesh: Mesh, score_fn: Callable, doc_arrays: Sequence,
                     qv: jax.Array, *, k: int = 10, axis: str = "data"
                     ) -> Tuple[jax.Array, jax.Array]:
    """Top-k over a corpus sharded on ``axis`` — any scoring function.

    ``doc_arrays`` are doc-major arrays (leading dim D, sharded over
    ``axis``); ``qv`` is the replicated (Q, F) query matrix;
    ``score_fn(*doc_arrays_local, qv) -> (Q, D_loc)`` scores one local
    shard.  Returns (scores (Q, k), global doc ids (Q, k)).
    """
    n_axis = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    D = doc_arrays[0].shape[0]
    assert D % n_axis == 0, (D, n_axis)
    d_local = D // n_axis

    def body(*args):
        *docs_loc, q = args
        scores = score_fn(*docs_loc, q)
        return _merge_local_topk(scores, k=k, axis=axis, d_local=d_local)

    fn = shard_map(
        body, mesh,
        in_specs=tuple(P(axis, *([None] * (a.ndim - 1)))
                       for a in doc_arrays) + (P(None, None),),
        out_specs=(P(None, None), P(None, None)),
    )
    return jax.jit(fn)(*doc_arrays, qv)


# ---------------------------------------------------------------------------
# BM25
# ---------------------------------------------------------------------------


def _bm25_local_scores(tf_loc, dl_loc, qv, *, avg_len, k1, b):
    """BM25 over the local doc shard.  tf_loc: (D_loc, V); qv: (Q, V)."""
    norm = k1 * (1 - b + b * dl_loc[:, None] / avg_len)
    sat = tf_loc * (k1 + 1) / (tf_loc + norm)
    return qv @ sat.T                                   # (Q, D_loc)


def distributed_bm25_topk(mesh: Mesh, tf: jax.Array, doc_len: jax.Array,
                          qv: jax.Array, *, k: int = 10, k1: float = 1.2,
                          b: float = 0.75, axis: str = "data"
                          ) -> Tuple[jax.Array, jax.Array]:
    """BM25 top-k over a corpus sharded on ``axis``.

    tf: (D, V) global TF matrix (sharded on docs); qv: (Q, V) replicated
    idf-weighted query vectors.  Returns (scores (Q,k), doc_ids (Q,k)).
    """
    avg_len = float(np.asarray(jnp.mean(doc_len))) + 1e-6
    return distributed_topk(
        mesh, partial(_bm25_local_scores, avg_len=avg_len, k1=k1, b=b),
        (tf, doc_len), qv, k=k, axis=axis)


def distributed_dense_topk(mesh: Mesh, emb: jax.Array, qe: jax.Array, *,
                           k: int = 10, axis: str = "data"
                           ) -> Tuple[jax.Array, jax.Array]:
    """Dense top-k over a doc-embedding matrix sharded on ``axis``.

    emb: (D, E) doc embeddings (sharded on docs); qe: (Q, E) replicated
    query embeddings.  Returns (scores (Q,k), doc_ids (Q,k)).
    """
    return distributed_topk(
        mesh, lambda emb_loc, q: q @ emb_loc.T, (emb,), qe, k=k, axis=axis)


class DistributedBM25:
    """Drop-in scorer over a sharded corpus for the serving pipeline.

    ``topk`` takes (Q, V) raw query term counts and returns
    ``(ids, scores)`` — the same order as every other scorer in the
    package (``BM25Index.topk``, ``DenseIndex.topk``, the
    :class:`~repro.retrieval.hybrid.Retriever` protocol), so swapping
    scorers behind an adapter cannot silently transpose the pair.
    """

    def __init__(self, mesh: Mesh, tf: np.ndarray, doc_len: np.ndarray,
                 idf: np.ndarray, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis
        ax_spec = NamedSharding(mesh, P(axis, None))
        self.tf = jax.device_put(jnp.asarray(tf), ax_spec)
        self.doc_len = jax.device_put(jnp.asarray(doc_len),
                                      NamedSharding(mesh, P(axis)))
        self.idf = jnp.asarray(idf)

    def topk(self, query_tf: np.ndarray, k: int = 10):
        """query_tf: (Q, V) query term counts -> (global ids, scores)."""
        qv = jnp.asarray(query_tf) * self.idf[None, :]
        with self.mesh:
            s, i = distributed_bm25_topk(self.mesh, self.tf, self.doc_len,
                                         qv, k=k, axis=self.axis)
        return np.asarray(i), np.asarray(s)


class DistributedDenseIndex:
    """Sharded dense retrieval: doc embeddings on the mesh's data axis.

    ``topk`` takes pre-encoded (Q, E) query embeddings and returns
    ``(ids, scores)``, the package-wide scorer order (see
    :class:`DistributedBM25`).
    """

    def __init__(self, mesh: Mesh, emb: np.ndarray, axis: str = "data"):
        self.mesh = mesh
        self.axis = axis
        self.emb = jax.device_put(jnp.asarray(emb),
                                  NamedSharding(mesh, P(axis, None)))

    def topk(self, query_emb: np.ndarray, k: int = 10):
        """query_emb: (Q, E) encoded queries -> (global ids, scores)."""
        with self.mesh:
            s, i = distributed_dense_topk(self.mesh, self.emb,
                                          jnp.asarray(query_emb), k=k,
                                          axis=self.axis)
        return np.asarray(i), np.asarray(s)
