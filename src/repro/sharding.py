"""Sharding resolution: logical axes -> PartitionSpecs.

Production rule set (see DESIGN.md §5):

* ``model`` axis: vocab > d_ff/d_ff_expert/d_inner > heads > kv_heads >
  kv_lora > head_dim — first candidate whose dim divides the axis size
  (**divisibility fallback**: e.g. 40 heads on a 16-way model axis fall
  back to head_dim; if nothing divides, the tensor is replicated over
  ``model`` and the event is recorded for the roofline report).
* ``data`` axis (weights): ZeRO/FSDP-style extra sharding of large
  tensors over the data axis, preferring the d_model dim.
* ``batch`` leaves (activations, KV caches) shard over ("pod","data")
  when divisible, else "data", else replicated (long_500k's batch=1).
* ``experts``: sharded over "data" in expert-parallel (EP) mode —
  the shard_map all-to-all path in ``repro.models.moe``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.schema import ParamSpec

MODEL_PRIORITY = ("vocab", "d_ff", "d_ff_expert", "d_inner", "heads",
                  "kv_heads", "kv_lora", "head_dim")
FSDP_MIN_SIZE = 1 << 18          # don't FSDP-shard small tensors

# fallback events (logical description) — read by the dry-run report
FALLBACK_LOG: List[str] = []


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_entry(dim: int, mesh: Mesh):
    ba = batch_axes(mesh)
    sizes = mesh_axis_sizes(mesh)
    prod = int(np.prod([sizes[a] for a in ba]))
    if dim % prod == 0:
        return ba if len(ba) > 1 else ba[0], set(ba)
    if "data" in sizes and dim % sizes["data"] == 0:
        return "data", {"data"}
    return None, set()


def resolve_spec(ps: ParamSpec, mesh: Mesh, *, fsdp: bool = True,
                 ep: bool = False, log_name: str = "") -> P:
    """Resolve one ParamSpec to a PartitionSpec."""
    sizes = mesh_axis_sizes(mesh)
    n = len(ps.shape)
    entries: List[Optional[object]] = [None] * n
    used: set = set()

    # --- batch (activation / cache tensors) — first batch dim only
    for i, (ax, dim) in enumerate(zip(ps.axes, ps.shape)):
        if ax == "batch":
            entry, u = _batch_entry(dim, mesh)
            if not (u & used):
                entries[i], used = entry, used | u
            break

    # --- expert parallelism
    if ep and "data" not in used and "data" in sizes:
        for i, (ax, dim) in enumerate(zip(ps.axes, ps.shape)):
            if ax == "experts" and dim % sizes["data"] == 0:
                entries[i] = "data"
                used.add("data")
                break

    # --- model axis by priority
    if "model" in sizes:
        placed = False
        for name in MODEL_PRIORITY:
            for i, (ax, dim) in enumerate(zip(ps.axes, ps.shape)):
                if ax == name and entries[i] is None and dim % sizes["model"] == 0:
                    entries[i] = "model"
                    used.add("model")
                    placed = True
                    break
            if placed:
                break
        if not placed and any(a in MODEL_PRIORITY for a in ps.axes):
            FALLBACK_LOG.append(
                f"{log_name or ps.axes}: no dim divisible by model={sizes['model']}"
                f" shape={ps.shape} axes={ps.axes} -> replicated")

    # --- FSDP over data axis for big weight tensors
    has_batch = "batch" in ps.axes
    if (fsdp and not has_batch and "data" not in used and "data" in sizes
            and int(np.prod(ps.shape)) >= FSDP_MIN_SIZE):
        # prefer d_model, else the largest remaining divisible dim
        order = sorted(range(n), key=lambda i: (ps.axes[i] != "d_model",
                                                -ps.shape[i]))
        for i in order:
            if entries[i] is None and ps.axes[i] != "layers" \
                    and ps.shape[i] % sizes["data"] == 0:
                entries[i] = "data"
                used.add("data")
                break

    return P(*entries)


def leaf_name(path) -> str:
    """'blocks/p0/attn/wq'-style name for a tree_map_with_path key path
    (shared by spec resolution, the fallback audit, and tests)."""
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def specs_for_schema(schema, mesh: Mesh, *, fsdp: bool = True,
                     ep: bool = False):
    """PartitionSpec tree matching a ParamSpec tree."""
    def f(path, ps):
        return resolve_spec(ps, mesh, fsdp=fsdp, ep=ep,
                            log_name=leaf_name(path))

    return jax.tree_util.tree_map_with_path(
        f, schema, is_leaf=lambda x: isinstance(x, ParamSpec))


def shardings_for_schema(schema, mesh: Mesh, **kw):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        specs_for_schema(schema, mesh, **kw),
        is_leaf=lambda x: isinstance(x, P))


def model_axis_fallbacks(schema, mesh: Mesh, *, fsdp: bool = False):
    """Audit the ``model``-axis coverage of a schema on a mesh.

    Returns ``(sharded, fallbacks)``: names of leaves that carry a
    MODEL_PRIORITY logical axis and resolve WITH / WITHOUT a ``model``
    entry on this mesh.  A non-empty ``fallbacks`` list on an ``mp>1``
    serve mesh means those tensors silently replicate over the model
    axis (the divisibility fallback) — surfaced by the serving-mesh
    validation and asserted empty in the dp×mp executor tests.
    """
    sharded: List[str] = []
    fallbacks: List[str] = []

    def f(path, ps):
        if not any(a in MODEL_PRIORITY for a in ps.axes):
            return ps
        name = leaf_name(path)
        spec = resolve_spec(ps, mesh, fsdp=fsdp, log_name=name)
        hit = any(e == "model" for e in spec)
        (sharded if hit else fallbacks).append(name)
        return ps

    jax.tree_util.tree_map_with_path(
        f, schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sharded, fallbacks


def input_sharding(mesh: Mesh, batch: int, rank: int) -> NamedSharding:
    """Batch-sharded activation input: (B, ...) with B maybe indivisible."""
    entry, _ = _batch_entry(batch, mesh)
    return NamedSharding(mesh, P(entry, *([None] * (rank - 1))))


def opt_state_spec_like(param_spec: P, ps: ParamSpec, mesh: Mesh) -> P:
    """ZeRO-1: optimizer moments shard like the param (already FSDP'd)."""
    return param_spec
