"""Prompt templates — verbatim from the paper's Appendix A."""
from __future__ import annotations

from typing import Sequence

GUARDED_TEMPLATE = """You are a careful question-answering assistant.
Use ONLY the information in CONTEXT to answer the QUESTION.
If the answer is not in CONTEXT, respond with: "I don't know."

CONTEXT:
{retrieved_passages}

QUESTION:
{question}

Answer (one short sentence):"""

AUTO_TEMPLATE = """Answer the QUESTION using the CONTEXT below.

CONTEXT: {retrieved_passages}

QUESTION: {question}

Answer:"""

REFUSAL_TEXT = "I cannot answer that."
DONT_KNOW_TEXT = "I don't know."


def build_prompt(mode: str, question: str, passages: Sequence[str]) -> str:
    ctx = "\n\n".join(passages)
    if mode == "guarded":
        return GUARDED_TEMPLATE.format(retrieved_passages=ctx,
                                       question=question)
    if mode == "auto":
        return AUTO_TEMPLATE.format(retrieved_passages=ctx,
                                    question=question)
    raise ValueError(mode)
