from repro.generation.simulator import SimulatedGenerator, GenOutput
from repro.generation.prompts import build_prompt, REFUSAL_TEXT

__all__ = ["SimulatedGenerator", "GenOutput", "build_prompt", "REFUSAL_TEXT"]
