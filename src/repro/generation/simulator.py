"""Calibrated generator simulator (stands in for gpt-4.1-nano).

The paper logs, per (question, action): correctness, token cost,
hallucination/refusal indicators.  With no OpenAI access in this
container (repro band 2/5 hardware gate), generation behaviour is a
calibrated stochastic model conditioned on the *actual retrieval
outcome* (hit/miss from our BM25 index) and the prompting mode, with
rates matched to Table 1's aggregates (accuracy ≈ 0.25–0.30, refusal
≈ 0.28 for guarded k=5, cost ≈ 244/609/1100 tokens for k=2/5/10).

Determinism: outcomes are a pure function of (seed, qid, action) via a
counter-based hash — the full action sweep is reproducible and
re-loggable, mirroring the paper's frozen offline log.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.data.tokenizer import HashTokenizer
from repro.generation.prompts import (DONT_KNOW_TEXT, REFUSAL_TEXT,
                                      build_prompt)


@dataclass
class GenOutput:
    answer: str
    refused: bool
    correct: bool
    hallucinated: bool
    prompt_tokens: int
    completion_tokens: int

    @property
    def cost_tokens(self) -> int:
        return self.prompt_tokens + self.completion_tokens


@dataclass(frozen=True)
class BehaviorRates:
    """P(correct / refuse / hallucinate) per (mode, answerable, hit)."""

    # guarded mode
    g_hit_correct: float = 0.78
    g_hit_refuse: float = 0.12
    g_miss_refuse: float = 0.55
    g_miss_correct: float = 0.04     # parametric knowledge
    g_unans_refuse: float = 0.48     # guarded still often answers wrongly
    # auto mode
    a_hit_correct: float = 0.72
    a_hit_refuse: float = 0.03
    a_miss_correct: float = 0.08
    a_miss_refuse: float = 0.05
    a_unans_refuse: float = 0.10


def _u(seed: int, qid: int, action: int, salt: int) -> float:
    h = hashlib.blake2s(f"{seed}|{qid}|{action}|{salt}".encode(),
                        digest_size=8).digest()
    return int.from_bytes(h, "little") / 2**64


class SimulatedGenerator:
    """Executes one action's generation step and scores it."""

    def __init__(self, tokenizer: HashTokenizer, seed: int = 0,
                 rates: BehaviorRates = BehaviorRates()):
        self.tok = tokenizer
        self.seed = seed
        self.rates = rates

    def refuse(self, qid: int, question: str) -> GenOutput:
        """Action 4: pre-retrieval abstention (paper §3.1)."""
        return GenOutput(REFUSAL_TEXT, True, False, False,
                         self.tok.n_tokens(question) + 2, 5)

    def generate(self, qid: int, action: int, mode: str, question: str,
                 passages: Sequence[str], *, answerable: bool,
                 gold_answer: Optional[str]) -> GenOutput:
        hit = bool(gold_answer) and any(gold_answer in p for p in passages)
        prompt = build_prompt(mode, question, passages)
        p_tok = self.tok.n_tokens(prompt) + 14  # template punctuation etc.
        r = self.rates
        u = _u(self.seed, qid, action, 0)

        if mode == "guarded":
            if answerable and hit:
                correct = u < r.g_hit_correct
                refused = (not correct) and u < r.g_hit_correct + r.g_hit_refuse
            elif answerable:
                refused = u < r.g_miss_refuse
                correct = (not refused) and u < r.g_miss_refuse + r.g_miss_correct
            else:
                refused = u < r.g_unans_refuse
                correct = False
        else:  # auto
            if answerable and hit:
                correct = u < r.a_hit_correct
                refused = (not correct) and u < r.a_hit_correct + r.a_hit_refuse
            elif answerable:
                correct = u < r.a_miss_correct
                refused = (not correct) and u < r.a_miss_correct + r.a_miss_refuse
            else:
                refused = u < r.a_unans_refuse
                correct = False

        answered = not refused
        hallucinated = answered and not correct
        if refused:
            answer, c_tok = DONT_KNOW_TEXT, 4
        elif correct:
            answer, c_tok = f"the answer is {gold_answer} .", 6
        else:
            answer, c_tok = f"the answer is val{int(u * 1e5):05d} .", 6
        return GenOutput(answer, refused, correct, hallucinated, p_tok, c_tok)
