from repro.data.tokenizer import HashTokenizer
from repro.data.synthetic_squad import SyntheticSquad, Paragraph, Question

__all__ = ["HashTokenizer", "SyntheticSquad", "Paragraph", "Question"]
