"""Sequence packing: fill fixed-length rows with multiple documents.

Padding wastes FLOPs ∝ (1 − occupancy); packing concatenates documents
(EOS-separated) into full rows and emits a segment-id mask so attention
can optionally be restricted per document.  The LM loss masks the token
after each EOS boundary (no cross-document prediction).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Sequence

import numpy as np

from repro.data.tokenizer import EOS, PAD


@dataclass
class PackedBatch:
    tokens: np.ndarray      # (B, S) int32
    labels: np.ndarray      # (B, S) int32, -1 where masked
    segments: np.ndarray    # (B, S) int32 document id per position

    @property
    def occupancy(self) -> float:
        return float((self.tokens != PAD).mean())


def pack_documents(docs: Sequence[List[int]], seq_len: int,
                   batch_size: int) -> Iterator[PackedBatch]:
    """Greedy first-fit packing of token lists into (B, S) rows."""
    rows: List[List[int]] = []
    segs: List[List[int]] = []
    cur: List[int] = []
    cur_seg: List[int] = []
    doc_id = 0
    for doc in docs:
        doc = list(doc) + [EOS]
        while doc:
            space = seq_len - len(cur)
            take, doc = doc[:space], doc[space:]
            cur.extend(take)
            cur_seg.extend([doc_id] * len(take))
            if len(cur) == seq_len:
                rows.append(cur)
                segs.append(cur_seg)
                cur, cur_seg = [], []
        doc_id += 1
    if cur:
        pad = seq_len - len(cur)
        rows.append(cur + [PAD] * pad)
        segs.append(cur_seg + [-1] * pad)

    for s0 in range(0, len(rows) - batch_size + 1, batch_size):
        toks = np.asarray(rows[s0: s0 + batch_size], np.int32)
        seg = np.asarray(segs[s0: s0 + batch_size], np.int32)
        labels = np.full_like(toks, -1)
        labels[:, :-1] = toks[:, 1:]
        # mask: no prediction across document boundaries or into padding
        same_doc = seg[:, :-1] == seg[:, 1:]
        valid = (toks[:, 1:] != PAD) & same_doc
        labels[:, :-1] = np.where(valid, labels[:, :-1], -1)
        labels[:, -1] = -1
        yield PackedBatch(toks, labels, seg)
