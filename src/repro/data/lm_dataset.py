"""LM training batches from the synthetic corpus (for train drivers).

Provides fixed-shape (tokens, labels) batches for any architecture,
including the modality stubs (random-but-deterministic frame/patch
embeddings standing in for the stubbed frontends).
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.core.config import ModelConfig
from repro.data.synthetic_squad import SyntheticSquad
from repro.data.tokenizer import HashTokenizer


class LMDataset:
    def __init__(self, cfg: ModelConfig, seq_len: int, seed: int = 0,
                 n_paragraphs: int = 200):
        self.cfg = cfg
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        tok = HashTokenizer(cfg.vocab_size)
        corpus = SyntheticSquad(n_paragraphs=n_paragraphs, n_questions=10,
                                seed=seed)
        ids = []
        for p in corpus.paragraphs:
            ids.extend(tok.encode(p.text, eos=True))
        self.stream = np.asarray(ids, np.int32)

    def batches(self, batch_size: int) -> Iterator[Dict[str, np.ndarray]]:
        cfg, S = self.cfg, self.seq_len
        s_txt = S - (cfg.n_modality_tokens if cfg.modality == "vision" else 0)
        n = len(self.stream) - s_txt - 1
        while True:
            starts = self.rng.integers(0, n, size=batch_size)
            toks = np.stack([self.stream[s: s + s_txt] for s in starts])
            labs = np.stack([self.stream[s + 1: s + 1 + s_txt] for s in starts])
            batch = {"tokens": toks, "labels": labs}
            if cfg.modality == "vision":
                batch["image_emb"] = self.rng.standard_normal(
                    (batch_size, cfg.n_modality_tokens,
                     cfg.modality_embed_dim)).astype(np.float32) * 0.02
            if cfg.modality == "audio":
                batch["audio_emb"] = self.rng.standard_normal(
                    (batch_size, cfg.encoder_seq_len,
                     cfg.d_model)).astype(np.float32) * 0.02
            yield batch
