"""Synthetic SQuAD-2.0-like corpus.

No dataset downloads in this container (repro band 2/5 data gate), so we
generate a corpus with the statistics the paper's metrics depend on:

* paragraphs of factual sentences "the <attr> of <subject> is <value>";
* answerable questions whose gold answer string appears verbatim in the
  gold paragraph (SQuAD is extractive — retrieval_hit_rate is defined as
  gold-answer-string containment);
* unanswerable questions about (subject, attr) pairs that exist nowhere
  in the corpus (SQuAD 2.0's adversarial unanswerables);
* lexical overlap between question and gold paragraph so BM25 retrieval
  works but is imperfect (distractor paragraphs share subjects/topics).

Everything is deterministic in ``seed``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

_TOPICS = ["river", "empire", "composer", "protocol", "mineral", "galaxy",
           "treaty", "enzyme", "cathedral", "glacier", "dynasty", "reactor",
           "archipelago", "manuscript", "observatory", "aqueduct"]
_ATTRS = ["length", "origin", "founder", "capital", "color", "height",
          "population", "discoverer", "age", "temperature", "successor",
          "architect", "purpose", "location", "composition", "name"]
_FILLER = ("historians note that records describe how scholars later "
           "established that during the period many sources agree the "
           "region was widely known for its significance").split()


@dataclass
class Paragraph:
    pid: int
    subject: str
    text: str


@dataclass
class Question:
    qid: int
    text: str
    answerable: bool
    gold_answer: Optional[str]
    gold_pid: Optional[int]


def _value(rng) -> str:
    return f"val{rng.integers(0, 99999):05d}"


@dataclass
class SyntheticSquad:
    n_paragraphs: int = 600
    n_questions: int = 1000
    answerable_frac: float = 0.5
    facts_per_paragraph: int = 7
    # Retrieval-difficulty knobs (calibrated so hit@2 < hit@5 < hit@10
    # lands near the paper's 0.68 / 0.76 / 0.79):
    subject_reuse: float = 4.0      # avg paragraphs sharing a subject
    attr_alias_prob: float = 0.30   # fact phrased with an alias of attr
    subject_alias_prob: float = 0.10  # whole paragraph names subject obliquely
    seed: int = 0

    paragraphs: List[Paragraph] = field(default_factory=list)
    questions: List[Question] = field(default_factory=list)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        facts: Dict[str, Dict[str, str]] = {}
        fact_loc: Dict[str, int] = {}

        pool_size = max(1, int(self.n_paragraphs / self.subject_reuse))
        pool = []
        for i in range(pool_size):
            topic = _TOPICS[rng.integers(0, len(_TOPICS))]
            pool.append(f"{topic}{i:04d}")

        for pid in range(self.n_paragraphs):
            subject = pool[rng.integers(0, pool_size)]
            # oblique paragraphs never name the subject lexically — their
            # facts are unreachable for BM25 (caps hit@10 below 1.0, like
            # SQuAD paraphrase failures)
            shown_subj = (f"{subject}x" if rng.random() < self.subject_alias_prob
                          else subject)
            sents = []
            facts.setdefault(subject, {})
            attrs = rng.choice(len(_ATTRS), size=self.facts_per_paragraph,
                               replace=False)
            for ai in attrs:
                attr = _ATTRS[ai]
                val = _value(rng)
                if attr not in facts[subject]:
                    # first sighting is gold; repeats become distractor
                    # claims with conflicting values (SQuAD-style noise)
                    facts[subject][attr] = val
                    fact_loc[f"{subject}|{attr}"] = pid
                # lexical mismatch: sometimes the paragraph phrases the
                # attribute with an alias the question won't use
                shown = f"{attr}form" if rng.random() < self.attr_alias_prob \
                    else attr
                filler = " ".join(rng.choice(_FILLER,
                                             size=rng.integers(5, 13)))
                sents.append(
                    f"the {shown} of {shown_subj} is {val} . {filler} .")
            rng.shuffle(sents)
            self.paragraphs.append(Paragraph(pid, subject, " ".join(sents)))

        subjects = list(facts)
        n_ans = int(self.n_questions * self.answerable_frac)
        for qid in range(self.n_questions):
            if qid < n_ans:
                while True:
                    subj = subjects[rng.integers(0, len(subjects))]
                    if facts[subj]:
                        break
                attrs = list(facts[subj])
                attr = attrs[rng.integers(0, len(attrs))]
                gold = facts[subj][attr]
                text = f"what is the {attr} of {subj} ?"
                self.questions.append(Question(
                    qid, text, True, gold, fact_loc[f"{subj}|{attr}"]))
            else:
                # unanswerable: existing subject, attribute it doesn't have
                while True:
                    subj = subjects[rng.integers(0, len(subjects))]
                    missing = [a for a in _ATTRS if a not in facts[subj]]
                    if missing:
                        break
                attr = missing[rng.integers(0, len(missing))]
                text = f"what is the {attr} of {subj} ?"
                self.questions.append(Question(qid, text, False, None, None))
        rng.shuffle(self.questions)  # mix answerable/unanswerable
        for i, q in enumerate(self.questions):
            q.qid = i

    def split(self, n_eval: int):
        """(train, eval) question lists — eval is the paper's N=200 dev."""
        return self.questions[:-n_eval], self.questions[-n_eval:]
