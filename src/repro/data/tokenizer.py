"""Deterministic hashed tokenizer.

No external vocab files in this container, so token ids come from a
stable blake2 hash of the word — enough for BM25 lexical retrieval and
for feeding the local JAX generation backends.  Ids 0..3 are reserved
(PAD/BOS/EOS/UNK).
"""
from __future__ import annotations

import hashlib
import re
from typing import List

_WORD_RE = re.compile(r"[a-z0-9]+")

PAD, BOS, EOS, UNK = 0, 1, 2, 3
N_RESERVED = 4


def words(text: str) -> List[str]:
    return _WORD_RE.findall(text.lower())


def trim_at_eos(tokens) -> List[int]:
    """Token list truncated at the first EOS (inclusive) — the shared
    definition of a generation's useful tokens (parity tests, serving
    benchmarks)."""
    out: List[int] = []
    for t in tokens:
        out.append(int(t))
        if out[-1] == EOS:
            break
    return out


def _h(word: str, mod: int) -> int:
    d = hashlib.blake2s(word.encode(), digest_size=8).digest()
    return int.from_bytes(d, "little") % mod


class HashTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size > N_RESERVED
        self.vocab_size = vocab_size

    def encode_word(self, w: str) -> int:
        return N_RESERVED + _h(w, self.vocab_size - N_RESERVED)

    def encode(self, text: str, *, bos: bool = False, eos: bool = False,
               max_len: int | None = None) -> List[int]:
        ids = [self.encode_word(w) for w in words(text)]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        if max_len is not None:
            ids = ids[:max_len] + [PAD] * max(0, max_len - len(ids))
        return ids

    def n_tokens(self, text: str) -> int:
        return len(words(text))
