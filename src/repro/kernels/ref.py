"""Pure-jnp oracles for the Pallas kernels (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def bm25_ref(wq, tf, norm, k1: float = 1.2):
    """wq: (Q, V); tf: (D, V); norm: (D, 1) -> (Q, D) float32."""
    sat = tf * (k1 + 1.0) / (tf + norm)
    return (wq.astype(jnp.float32) @ sat.astype(jnp.float32).T)


def dense_topk_ref(q, docs, k: int):
    """Dense retrieval oracle: full (Q, D) similarity + top-k.

    q: (Q, E); docs: (D, E) -> (scores (Q, k) float32, ids (Q, k)
    int32), scores descending, ties broken toward the lower doc id
    (``lax.top_k`` semantics — the kernel's merge preserves them).
    Materializes the full score matrix; the kernel must not.
    """
    s = q.astype(jnp.float32) @ docs.astype(jnp.float32).T
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, top_i.astype(jnp.int32)


def flash_attention_ref(q, k, v, causal: bool = True):
    """q: (BH, Sq, D); k/v: (BH, Skv, D[v])."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def flash_decode_ref(q, k, v, lengths):
    """Dense single-query attention over a slotted cache.

    q: (BH, D); k/v: (BH, L, D[v]); lengths: (BH,) valid kv entries per
    row.  The oracle for the flash-decode kernel and the semantics of the
    continuous engine's decode step: positions >= lengths[b] are masked.
    """
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bd,bkd->bk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    L = k.shape[1]
    mask = jnp.arange(L)[None, :] < lengths[:, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def paged_flash_decode_ref(q, k_pages, v_pages, table, lengths):
    """Oracle for the paged decode kernel: gather the table's pages into
    a contiguous cache row, then run the dense decode oracle.

    q: (B, H, D); k/v_pages: (num_pages, page_size, Hkv, D[v]) — the
    executor's page-pool layout; table: (B, max_blocks) int32;
    lengths: (B,).  Returns (B, H, Dv).  The gather materializes the
    (B, max_blocks * page_size) row the kernel must not.
    """
    B, H, D = q.shape
    Hkv = k_pages.shape[2]
    Dv = v_pages.shape[-1]
    G = H // Hkv
    k = k_pages[table].reshape(B, -1, Hkv, D)
    v = v_pages[table].reshape(B, -1, Hkv, Dv)
    kx = (jnp.repeat(k, G, axis=2) if G > 1 else k)
    vx = (jnp.repeat(v, G, axis=2) if G > 1 else v)
    kf = kx.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vf = vx.transpose(0, 2, 1, 3).reshape(B * H, -1, Dv)
    lens = jnp.repeat(lengths, H)
    return flash_decode_ref(q.reshape(B * H, D), kf, vf,
                            lens).reshape(B, H, Dv)


def ssd_scan_ref(xdt, B_, C_, da):
    """Sequential SSD recurrence — the semantic ground truth.

    state_s = exp(da_s) * state_{s-1} + B_s ⊗ xdt_s ;  y_s = C_s · state_s
    (note xdt already carries dt, da = dt*a).
    """
    BH, S, hd = xdt.shape
    N = B_.shape[2]

    def step(state, inp):
        x_s, b_s, c_s, da_s = inp
        state = jnp.exp(da_s)[:, None, None] * state + \
            jnp.einsum("bd,bn->bdn", x_s, b_s)
        y = jnp.einsum("bn,bdn->bd", c_s, state)
        return state, y

    init = jnp.zeros((BH, hd, N), jnp.float32)
    xs = (xdt.swapaxes(0, 1).astype(jnp.float32),
          B_.swapaxes(0, 1).astype(jnp.float32),
          C_.swapaxes(0, 1).astype(jnp.float32),
          da.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, init, xs)
    return ys.swapaxes(0, 1).astype(xdt.dtype)
