"""Flash-decode Pallas kernel: single-query attention over a slotted KV
cache with per-slot length masking.

This is the decode-side companion of ``flash_attention.py``.  The grid
is (slots, q_heads, kv_blocks) with the kv axis innermost; the running
max / denominator / accumulator in VMEM scratch implement a split-KV
online-softmax reduction — kv blocks are reduced sequentially on TPU
without ever materializing the full (1, L) score row in one tile.  GQA
is handled in the k/v BlockSpec index map (``q_head // group_size``
selects the kv head), so the grouped cache is read in place — no
repeated/expanded copy of the cache is ever materialized.

The continuous-batching engine keeps every slot's cache at full
``max_len`` and tracks a per-slot valid length (``pos + 1``); the kernel
masks kv positions ``>= length[slot]`` so freed/stale slot tails never
contribute.  Because positions 0..length-1 are always populated
(length >= 1), the first kv block contains at least one unmasked entry
and the online softmax never sees an all-masked running state.

Q tiles are (1, head_dim) — decode has a single query per slot — so on
TPU the sublane dimension is under-utilized; production would batch 8
heads per tile.  The tests run the kernel in interpret mode (CPU
container) against the dense oracle in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                         m_scr, l_scr, acc_scr,
                         *, scale: float, block_kv: int, n_kv: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)           # (bk, dv)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (1, bk)

    kv_pos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_kv), 1)
    s = jnp.where(kv_pos < len_ref[0, 0], s, NEG_INF)

    m_prev = m_scr[...]                            # (1, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def _paged_flash_decode_kernel(tab_ref, q_ref, k_ref, v_ref, len_ref,
                               o_ref, m_scr, l_scr, acc_scr, *,
                               scale: float, block_kv: int, n_kv: int):
    # tab_ref is the scalar-prefetched block table — already consumed by
    # the k/v index maps (they gather the page for grid step ki), so the
    # body is exactly the dense online-softmax reduction over one page.
    del tab_ref
    _flash_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                         m_scr, l_scr, acc_scr, scale=scale,
                         block_kv=block_kv, n_kv=n_kv)


def paged_flash_decode_pallas(q, k_pages, v_pages, table, lengths, *,
                              interpret: bool = False):
    """Split-KV decode attention through a per-slot block table.

    q: (B, H, D); k/v_pages: (num_pages, Hkv, page_size, D[v]) —
    kv-head-major page pools; table: (B, max_blocks) int32 page ids
    (entries past the slot's allocation may point anywhere valid — the
    length mask kills them); lengths: (B,) valid kv length (>= 1).

    The grid is (B, H, max_blocks) with the page axis innermost; the
    table rides as a scalar-prefetch operand so the k/v BlockSpec index
    maps resolve ``table[b, ki]`` *before* the tile fetch — the kernel
    gathers pages straight out of the pool, never materializing a
    contiguous (B, L) cache row.  GQA stays in the index map
    (``h // G``), masking/online-softmax are identical to the dense
    kernel.  Returns (B, H, Dv).
    """
    B, H, D = q.shape
    Hkv, ps = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[3]
    G = H // Hkv
    n_kv = table.shape[1]
    grid = (B, H, n_kv)
    scale = 1.0 / (D ** 0.5)
    lens = lengths.reshape(B, 1).astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_paged_flash_decode_kernel, scale=scale,
                          block_kv=ps, n_kv=n_kv),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, D), lambda b, h, ki, tab: (b, h, 0)),
                pl.BlockSpec((1, 1, ps, D),
                             lambda b, h, ki, tab: (tab[b, ki], h // G,
                                                    0, 0)),
                pl.BlockSpec((1, 1, ps, Dv),
                             lambda b, h, ki, tab: (tab[b, ki], h // G,
                                                    0, 0)),
                pl.BlockSpec((1, 1), lambda b, h, ki, tab: (b, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, Dv),
                                   lambda b, h, ki, tab: (b, h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),      # running max
                pltpu.VMEM((1, 1), jnp.float32),      # running denom
                pltpu.VMEM((1, Dv), jnp.float32),     # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Dv), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), q, k_pages, v_pages, lens)


def flash_decode_pallas(q, k, v, lengths, *, block_kv: int = 128,
                        interpret: bool = False):
    """q: (B, H, D); k/v: (B, Hkv, L, D[v]) — kv-head-major so a q head
    reads kv head ``h // (H // Hkv)`` in place; lengths: (B,) int32
    valid kv length per slot (must be >= 1).  Returns (B, H, Dv)."""
    B, H, D = q.shape
    Hkv, L = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // Hkv
    block_kv = min(block_kv, L)
    assert L % block_kv == 0, (L, block_kv)
    n_kv = L // block_kv
    grid = (B, H, n_kv)
    scale = 1.0 / (D ** 0.5)
    lens = lengths.reshape(B, 1).astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_flash_decode_kernel, scale=scale,
                          block_kv=block_kv, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, ki: (b, h, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, Dv),
                         lambda b, h, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1), lambda b, h, ki: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Dv), lambda b, h, ki: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),      # running max
            pltpu.VMEM((1, 1), jnp.float32),      # running denom
            pltpu.VMEM((1, Dv), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v, lens)
