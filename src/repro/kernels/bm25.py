"""Blocked BM25 scoring kernel.

Scores a batch of hashed-vocab query vectors against the dense corpus
term-frequency matrix:

    score[q, d] = sum_v  wq[q, v] * tf[d, v]*(k1+1) / (tf[d, v] + norm[d])

where ``wq = query_tf * idf`` and ``norm[d] = k1*(1-b+b*len_d/avg)`` are
precomputed (cheap, O(Q·V + D)).  The kernel tiles (queries × docs ×
vocab) into VMEM blocks; the vocab axis is the contraction and is
accumulated across the innermost grid dimension.  On GPU this is
typically a sparse gather over an inverted index; the TPU-native
formulation keeps a 128-aligned dense block resident and feeds the MXU
(DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bm25_kernel(wq_ref, tf_ref, norm_ref, out_ref, *, k1: float):
    vi = pl.program_id(2)
    tf = tf_ref[...]                       # (bd, bv)
    norm = norm_ref[...]                   # (bd, 1)
    sat = tf * (k1 + 1.0) / (tf + norm)    # BM25 saturation
    part = jnp.dot(wq_ref[...], sat.T,
                   preferred_element_type=jnp.float32)  # (bq, bd)

    @pl.when(vi == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += part


def bm25_pallas(wq, tf, norm, *, k1: float = 1.2, block_q: int = 8,
                block_d: int = 128, block_v: int = 512,
                interpret: bool = False):
    """wq: (Q, V) idf-weighted query tf; tf: (D, V); norm: (D, 1)."""
    Q, V = wq.shape
    D = tf.shape[0]
    assert Q % block_q == 0 and D % block_d == 0 and V % block_v == 0, \
        (Q, D, V, block_q, block_d, block_v)
    grid = (Q // block_q, D // block_d, V // block_v)
    return pl.pallas_call(
        functools.partial(_bm25_kernel, k1=k1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_v), lambda qi, di, vi: (qi, vi)),
            pl.BlockSpec((block_d, block_v), lambda qi, di, vi: (di, vi)),
            pl.BlockSpec((block_d, 1), lambda qi, di, vi: (di, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_d), lambda qi, di, vi: (qi, di)),
        out_shape=jax.ShapeDtypeStruct((Q, D), jnp.float32),
        interpret=interpret,
    )(wq, tf, norm)
