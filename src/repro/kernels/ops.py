"""Public jit'd wrappers for the Pallas kernels.

On CPU containers the kernels execute in interpret mode (the kernel body
runs as traced jnp on host); on TPU they compile to Mosaic.  Block sizes
default to MXU-aligned tiles and shrink to fit small inputs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bm25 import bm25_pallas
from repro.kernels.dense_topk import _dense_topk_padded
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_decode import (flash_decode_pallas,
                                        paged_flash_decode_pallas)
from repro.kernels.ssd_scan import ssd_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("k1", "b"))
def bm25_scores(query_tf, tf, doc_len, idf, *, k1: float = 1.2,
                b: float = 0.75):
    """BM25 scores: (Q, V) query term-counts -> (Q, D).

    Thin host-side prep + the blocked Pallas contraction.
    """
    avg = doc_len.mean() + 1e-6
    norm = (k1 * (1 - b + b * doc_len / avg))[:, None].astype(jnp.float32)
    wq = (query_tf * idf[None, :]).astype(jnp.float32)
    Q, V = wq.shape
    D = tf.shape[0]
    bq = 8 if Q % 8 == 0 else 1
    bd = 128 if D % 128 == 0 else (64 if D % 64 == 0 else D)
    bv = 512 if V % 512 == 0 else V
    return bm25_pallas(wq, tf.astype(jnp.float32), norm, k1=k1,
                       block_q=bq, block_d=bd, block_v=bv,
                       interpret=_interpret())


@partial(jax.jit, static_argnames=("k", "block_q", "block_d"))
def dense_topk(q, docs, *, k: int = 10, block_q: int = 8,
               block_d: int = 128):
    """Fused dense retrieval: (Q, E) queries × (D, E) docs -> top-k.

    Returns (scores (Q, k) float32 descending, doc ids (Q, k) int32).
    Both axes pad to block multiples — zero query rows just produce
    discarded output rows, and the kernel masks the padded doc tail to
    -inf — so any (Q, D) tiles with full-width blocks; the full (Q, D)
    score matrix is never materialized.
    """
    Q, E = q.shape
    D = docs.shape[0]
    # align edge cases with the numpy oracle (DenseIndex.topk): empty
    # corpus / non-positive k return empty candidate rows, and k clamps
    # to the corpus size, instead of tripping kernel asserts
    if k <= 0 or D == 0:
        return (jnp.zeros((Q, 0), jnp.float32),
                jnp.zeros((Q, 0), jnp.int32))
    k = min(k, D)
    bd = min(block_d, D)
    pad_d = -D % bd
    if pad_d:
        docs = jnp.pad(docs, ((0, pad_d), (0, 0)))
    pad_q = -Q % block_q
    if pad_q:
        q = jnp.pad(q, ((0, pad_q), (0, 0)))
    s, i = _dense_topk_padded(q.astype(jnp.float32),
                              docs.astype(jnp.float32), k=k, n_docs=D,
                              block_q=block_q, block_d=bd,
                              interpret=_interpret())
    return (s[:Q], i[:Q]) if pad_q else (s, i)


@partial(jax.jit, static_argnames=("causal", "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128):
    """GQA flash attention: q (B, Sq, H, D), k/v (B, Skv, Hkv, D[v])."""
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    kx = jnp.repeat(k, G, axis=2) if G > 1 else k
    vx = jnp.repeat(v, G, axis=2) if G > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = kx.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    vf = vx.transpose(0, 2, 1, 3).reshape(B * H, Skv, Dv)
    out = flash_attention_pallas(qf, kf, vf, causal=causal,
                                 block_q=block_q, block_kv=block_kv,
                                 interpret=_interpret())
    return out.reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_kv",))
def flash_decode(q, k, v, lengths, *, block_kv: int = 128):
    """Single-query GQA attention over a slotted KV cache.

    q: (B, H, D) — one query per slot; k/v: (B, L, Hkv, D[v]) — the
    full-length slot cache; lengths: (B,) valid kv length per slot
    (>= 1).  Returns (B, H, Dv).  The GQA head->kv-head mapping happens
    inside the kernel's BlockSpec index map, so the grouped cache is
    only transposed to kv-head-major — never expanded; block_kv shrinks
    to the largest divisor of L so ragged cache lengths still tile.
    """
    B, H, D = q.shape
    L = k.shape[1]
    Dv = v.shape[-1]
    kf = k.transpose(0, 2, 1, 3)                  # (B, Hkv, L, D)
    vf = v.transpose(0, 2, 1, 3)
    bk = min(block_kv, L)
    pad = -L % bk
    if pad:
        # keep full-width kv blocks for any cache length; the padded
        # tail is masked by the kernel's length check
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return flash_decode_pallas(q, kf, vf, jnp.maximum(lengths, 1),
                               block_kv=bk, interpret=_interpret())


@jax.jit
def paged_flash_decode(q, k_pages, v_pages, table, lengths):
    """Single-query GQA attention through a paged KV cache.

    q: (B, H, D) — one query per slot; k/v_pages: (num_pages,
    page_size, Hkv, D[v]) — the executor's global page pools; table:
    (B, max_blocks) int32 page ids per slot; lengths: (B,) valid kv
    length (>= 1).  Returns (B, H, Dv).  Pools transpose to
    kv-head-major (page-local — never gathered to a contiguous row on
    the host side); table entries clamp into range so unallocated tail
    blocks read a valid page and are masked by the length check.
    """
    NP = k_pages.shape[0]
    kf = k_pages.transpose(0, 2, 1, 3)            # (NP, Hkv, ps, D)
    vf = v_pages.transpose(0, 2, 1, 3)
    tab = jnp.clip(table.astype(jnp.int32), 0, NP - 1)
    return paged_flash_decode_pallas(q, kf, vf, tab,
                                     jnp.maximum(lengths, 1),
                                     interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_chunk_scan(x, B_, C_, dt, A_log, *, chunk: int = 128):
    """Mamba2 SSD: x (B, S, H, hd), B_/C_ (B, S, G, N), dt (B, S, H).

    Returns y (B, S, H, hd).  Groups are expanded to heads and heads
    folded into the grid batch dim before the kernel.
    """
    Bsz, S, H, hd = x.shape
    N = B_.shape[-1]
    G = B_.shape[2]
    a = -jnp.exp(A_log.astype(jnp.float32))
    da = (dt.astype(jnp.float32) * a).transpose(0, 2, 1)        # (B, H, S)
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    xdt = xdt.transpose(0, 2, 1, 3).reshape(Bsz * H, S, hd)
    rep = H // G
    Bx = jnp.repeat(B_, rep, axis=2) if rep > 1 else B_
    Cx = jnp.repeat(C_, rep, axis=2) if rep > 1 else C_
    Bf = Bx.transpose(0, 2, 1, 3).reshape(Bsz * H, S, N).astype(jnp.float32)
    Cf = Cx.transpose(0, 2, 1, 3).reshape(Bsz * H, S, N).astype(jnp.float32)
    daf = da.reshape(Bsz * H, S)
    y = ssd_scan_pallas(xdt, Bf, Cf, daf, chunk=chunk,
                        interpret=_interpret())
    return y.reshape(Bsz, H, S, hd).transpose(0, 2, 1, 3).astype(x.dtype)
