"""Fused dense-retrieval score + top-k Pallas kernel.

Dense retrieval scores a batch of query embeddings against the corpus
embedding matrix and keeps only the k best docs per query.  The naive
formulation materializes the full (Q, D) similarity matrix and sorts it
— at production corpus sizes that matrix dwarfs the candidate set by
4-5 orders of magnitude and the HBM round-trip dominates.

This kernel applies the flash-decode split-KV pattern to retrieval: the
doc axis is the innermost grid dimension and each (block_q, block_d)
score tile is folded into a running per-query partial top-k held in
VMEM scratch — (block_q, k) scores + doc ids — so no tile ever outlives
its grid step and the (Q, D) matrix never exists.  The merge is k
rounds of masked argmax over the (k + block_d) candidate row (k is a
small static int; sort networks are overkill and ``lax.top_k`` does not
lower to Mosaic), which keeps every op VPU-friendly.

Docs are padded to a block multiple by the wrapper (``ops.dense_topk``);
the kernel masks padded doc positions to -inf via the same
``broadcasted_iota`` length check the flash-decode kernel uses, so
non-divisible corpus sizes tile cleanly.  Tests run interpret-mode
shape/block sweeps against the ``ref.dense_topk_ref`` oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _merge_topk(best_s, best_i, cand_s, cand_i, k: int):
    """Fold (bq, c) candidates into the running (bq, k) top-k.

    k rounds of argmax-select-and-mask over the concatenated candidate
    row.  The running entries come FIRST in the concatenation, so on
    exact score ties the earlier (lower doc id) candidate wins — the
    same tie order as ``lax.top_k`` over the full score row.
    """
    s = jnp.concatenate([best_s, cand_s], axis=1)      # (bq, k + c)
    i = jnp.concatenate([best_i, cand_i], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    new_s, new_i = [], []
    for _ in range(k):
        am = jnp.argmax(s, axis=1)                     # (bq,)
        sel = cols == am[:, None]
        new_s.append(jnp.max(s, axis=1, keepdims=True))
        new_i.append(jnp.sum(jnp.where(sel, i, 0), axis=1, keepdims=True))
        s = jnp.where(sel, NEG_INF, s)
    return (jnp.concatenate(new_s, axis=1),
            jnp.concatenate(new_i, axis=1))


def _dense_topk_kernel(q_ref, d_ref, o_s_ref, o_i_ref, s_scr, i_scr,
                       *, k: int, block_d: int, n_docs: int, n_d: int):
    di = pl.program_id(1)

    @pl.when(di == 0)
    def _init():
        s_scr[...] = jnp.full_like(s_scr, NEG_INF)
        i_scr[...] = jnp.zeros_like(i_scr)

    q = q_ref[...].astype(jnp.float32)                 # (bq, e)
    d = d_ref[...].astype(jnp.float32)                 # (bd, e)
    s = jnp.dot(q, d.T, preferred_element_type=jnp.float32)  # (bq, bd)

    # mask the padded doc tail (wrapper pads D up to a block multiple)
    doc_pos = di * block_d + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(doc_pos < n_docs, s, NEG_INF)

    s_scr[...], i_scr[...] = _merge_topk(
        s_scr[...], i_scr[...], s, doc_pos, k)

    @pl.when(di == n_d - 1)
    def _finish():
        o_s_ref[...] = s_scr[...]
        o_i_ref[...] = i_scr[...]


def dense_topk_pallas(q, docs, *, k: int, block_q: int = 8,
                      block_d: int = 128, interpret: bool = False):
    """q: (Q, E) query embeddings; docs: (D_pad, E) doc embeddings with
    rows >= n_docs zero-padded to a ``block_d`` multiple.  Returns
    (scores (Q, k) float32, doc ids (Q, k) int32), scores descending.

    ``n_docs`` (the true corpus size) is taken from ``docs`` unless the
    caller padded — use :func:`repro.kernels.ops.dense_topk`, which
    pads and passes the true size.
    """
    return _dense_topk_padded(q, docs, k=k, n_docs=docs.shape[0],
                              block_q=block_q, block_d=block_d,
                              interpret=interpret)


def _dense_topk_padded(q, docs, *, k: int, n_docs: int, block_q: int,
                       block_d: int, interpret: bool):
    Q, E = q.shape
    D_pad = docs.shape[0]
    assert Q % block_q == 0 and D_pad % block_d == 0, \
        (Q, D_pad, block_q, block_d)
    assert 1 <= k <= n_docs <= D_pad, (k, n_docs, D_pad)
    n_d = D_pad // block_d
    grid = (Q // block_q, n_d)
    out_s, out_i = pl.pallas_call(
        functools.partial(_dense_topk_kernel, k=k, block_d=block_d,
                          n_docs=n_docs, n_d=n_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, E), lambda qi, di: (qi, 0)),
            pl.BlockSpec((block_d, E), lambda qi, di: (di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, di: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, di: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),   # running top-k scores
            pltpu.VMEM((block_q, k), jnp.int32),     # running top-k doc ids
        ],
        interpret=interpret,
    )(q, docs)
    return out_s, out_i
