"""Mamba2 SSD chunk-scan Pallas kernel.

One grid step = one (sequence-chunk, batch*head) tile: the intra-chunk
quadratic term runs as two MXU matmuls; the recurrent state
(head_dim × d_state) lives in VMEM scratch and is carried across the
chunk axis (innermost grid dim, sequential on TPU).  GPU implementations
use warp-level scans for the inter-chunk recurrence; on TPU the chunk IS
the tile and the carry is free (DESIGN.md §4).

Inputs are pre-arranged by ops.py:
    xdt (BH, S, hd)  = x * dt          (dt folded in)
    B_  (BH, S, N), C_ (BH, S, N)      (groups pre-expanded to heads)
    da  (BH, S)      = dt * a          (per-step log-decay, <= 0)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, b_ref, c_ref, da_ref, y_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        state_scr[...] = jnp.zeros_like(state_scr)

    xdt = xdt_ref[0].astype(jnp.float32)        # (c, hd)
    B = b_ref[0].astype(jnp.float32)            # (c, N)
    C = c_ref[0].astype(jnp.float32)            # (c, N)
    da = da_ref[0].astype(jnp.float32)          # (c,)
    cum = jnp.cumsum(da)                        # (c,)

    # intra-chunk quadratic term: L[t,s] = exp(cum_t - cum_s) for s<=t
    att = jnp.dot(C, B.T, preferred_element_type=jnp.float32)   # (c, c)
    L = jnp.exp(cum[:, None] - cum[None, :])
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    w = jnp.where(tri, att * L, 0.0)
    y = jnp.dot(w, xdt, preferred_element_type=jnp.float32)     # (c, hd)

    # inter-chunk contribution from the carried state
    y += jnp.exp(cum)[:, None] * jnp.dot(
        C, state_scr[...].T, preferred_element_type=jnp.float32)

    # state update: state' = e^{cum_end} * state + sum_s e^{cum_end-cum_s} B_s xdt_s^T
    decay_to_end = jnp.exp(cum[-1] - cum)                        # (c,)
    state_scr[...] = (jnp.exp(cum[-1]) * state_scr[...]
                      + jnp.dot((xdt * decay_to_end[:, None]).T, B,
                                preferred_element_type=jnp.float32))

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan_pallas(xdt, B_, C_, da, *, chunk: int = 128,
                    interpret: bool = False):
    """xdt: (BH, S, hd); B_/C_: (BH, S, N); da: (BH, S) -> y (BH, S, hd)."""
    BH, S, hd = xdt.shape
    N = B_.shape[2]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (BH, S // chunk)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk), lambda b, ci: (b, ci)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=interpret,
    )(xdt, B_, C_, da)
