"""Flash attention (online-softmax blocked attention) Pallas kernel.

Grid: (batch*heads, q_blocks, kv_blocks) with the kv axis innermost; the
running max / denominator / accumulator live in VMEM scratch and persist
across kv steps (TPU grid execution is sequential along the minor axis).
Causal masking is positional, so the same kernel serves prefill and
training.  Q/K/V tiles are MXU-aligned (block sizes multiples of 128 on
the model dims at production shapes; the tests sweep smaller shapes in
interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, block_q: int,
                  block_kv: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0].astype(jnp.float32)              # (bk, dv)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        kv_pos = ki * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        s = jnp.where(kv_pos <= q_pos, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False):
    """q: (BH, Sq, D), k/v: (BH, Skv, D[v]).  Heads pre-folded into BH
    (GQA expansion happens in ops.py)."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    Dv = v.shape[2]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    n_kv = Skv // block_kv
    grid = (BH, Sq // block_q, n_kv)
    scale = 1.0 / (D ** 0.5)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_kv=block_kv, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, Dv), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
            pltpu.VMEM((block_q, Dv), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
