"""Pallas TPU kernels for the framework's compute hot-spots.

* ``bm25.py``            — blocked BM25 retrieval scoring;
* ``dense_topk.py``      — fused dense similarity + online partial top-k;
* ``flash_attention.py`` — online-softmax blocked attention (prefill);
* ``flash_decode.py``    — split-KV single-query attention (decode);
* ``ssd_scan.py``        — Mamba2 SSD chunk scan;
* ``ops.py``             — jit'd public wrappers (interpret=True on CPU);
* ``ref.py``             — pure-jnp oracles for the allclose sweeps.
"""
from repro.kernels.ops import (bm25_scores, dense_topk, flash_attention,
                               flash_decode, paged_flash_decode,
                               ssd_chunk_scan)

__all__ = ["bm25_scores", "dense_topk", "flash_attention", "flash_decode",
           "paged_flash_decode", "ssd_chunk_scan"]
