"""Real-model generation backends: BM25 retrieval + the JAX KV-cache
engines behind the :class:`~repro.routing.backends.GenerationBackend`
protocol.

Two execution models:

* :class:`EngineBackend` — the padded-bucket
  :class:`~repro.serving.engine.Engine`: the Gateway buckets requests by
  routed action and each non-refuse bucket becomes ONE batched
  prefill+decode call (serial across buckets).
* :class:`ContinuousEngineBackend` — the slot-based
  :class:`~repro.serving.continuous.ContinuousEngine`: implements
  ``execute_mixed`` so ALL routed buckets of a micro-batch feed one
  shared in-flight decode stream.  Retrieval depth only changes the
  prompt; generation is unified, so deep-k and shallow-k requests decode
  in the same jitted step and finished slots admit queued requests
  mid-stream.

The tiny local model has no answer scorer, so outcomes carry
token-accounting truth (cost, refusal) and conservative quality
indicators (``correct=False``; unanswerable queries that get an answer
anyway count as hallucinations), exactly as the old serve driver did.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.data.synthetic_squad import Question
from repro.data.tokenizer import HashTokenizer
from repro.generation.prompts import REFUSAL_TEXT, build_prompt
from repro.obs import NULL_TRACER
from repro.retrieval.bm25 import BM25Index
from repro.retrieval.hybrid import (Retriever, bind_retrieval_metrics,
                                    collect_breakers, resolve_retrievers,
                                    retrieve_with_fallback)
from repro.routing.backends import StreamCompletion
from repro.routing.registry import Action
from repro.serving.engine import Engine
from repro.serving.pipeline import ActionOutcome

# Matches the pre-retrieval refusal accounting of the old serve driver.
REFUSE_COST_TOKENS = 5.0


class EngineBackend:
    """Batched retrieval + real JAX generation for one action bucket."""

    # telemetry: the Gateway installs its tracer here so retrieval and
    # engine spans land in the same trace (no-op by default)
    tracer = NULL_TRACER

    def __init__(self, engine: Engine, tokenizer: HashTokenizer,
                 index: BM25Index, *, max_prompt_len: int = 384,
                 max_new_tokens: int = 8,
                 retrievers: Optional[Mapping[str, Retriever]] = None,
                 retrieval_cache_size: int = 0, chaos=None,
                 breaker_kw: Optional[dict] = None):
        self.engine = engine
        self.tok = tokenizer
        self.index = index
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens
        # the same named-retriever protocol the simulator pipeline uses
        # (None = bm25-only over `index`, the seed behaviour); a shared
        # bounded LRU fronts them when retrieval_cache_size > 0, and a
        # per-retriever circuit breaker sits under the cache (chaos
        # seams, when armed, innermost)
        self.retrievers, self.retrieval_cache = resolve_retrievers(
            retrievers, index, cache_size=retrieval_cache_size,
            chaos=chaos, breaker_kw=breaker_kw)
        self.breakers = collect_breakers(self.retrievers)

    def install_tracer(self, tracer) -> None:
        """Adopt the Gateway's tracer (called once at Gateway
        construction); the engine shares it when it can carry one."""
        self.tracer = tracer
        if hasattr(self.engine, "tracer"):
            self.engine.tracer = tracer

    def bind_metrics(self, registry) -> None:
        """Register this backend's stat sources (retrieval cache,
        breakers, engine counters) as views over ``registry``."""
        bind_retrieval_metrics(registry, self.breakers,
                               self.retrieval_cache)
        bind = getattr(self.engine, "bind_metrics", None)
        if bind is not None:
            bind(registry)

    def _retrieve(self, question: str, k: int,
                  retriever: str = "bm25") -> List[str]:
        if k <= 0:
            return []
        try:
            r = self.retrievers[retriever]
        except KeyError:
            raise KeyError(
                f"action retriever {retriever!r} not configured; "
                f"available: {sorted(self.retrievers)}") from None
        return r.passages(question, k)

    def _prep(self, q: Question, action: Action
              ) -> Tuple[List[int], bool, bool]:
        """Retrieve with the action's retriever at its depth and build
        the prompt tokens.  Returns (token ids padded to
        max_prompt_len, retrieval hit, degraded).  ``degraded`` means
        the action's retriever failed (open breaker / fault) and the
        lookup was rewritten to the bm25 fallback; a transient fault
        with no working fallback raises ``TransientFaultError`` for the
        gateway's retry path."""
        degraded = False
        if action.k <= 0:
            passages: List[str] = []
        else:
            if action.retriever not in self.retrievers:
                raise KeyError(
                    f"action retriever {action.retriever!r} not "
                    f"configured; available: {sorted(self.retrievers)}")
            passages, degraded = retrieve_with_fallback(
                self.retrievers, action.retriever, q.text, action.k,
                tracer=self.tracer)
        hit = bool(q.gold_answer) and any(
            q.gold_answer in p for p in passages)
        prompt = build_prompt(action.mode, q.text, passages)
        return self.tok.encode(prompt, bos=True,
                               max_len=self.max_prompt_len), hit, degraded

    @staticmethod
    def _refusal_outcome(q: Question, action: Action) -> ActionOutcome:
        return ActionOutcome(
            qid=q.qid, action=action.idx, correct=False, refused=True,
            hallucinated=False, cost_tokens=REFUSE_COST_TOKENS,
            hit=False, answerable=q.answerable, answer=REFUSAL_TEXT)

    @staticmethod
    def _rejected_outcome(q: Question, action: Action,
                          reason: str) -> ActionOutcome:
        """An engine-rejected request (e.g. over-length prompt):
        surfaced as a refused outcome so Gateway accounting — reward,
        error budgets, on_outcome — sees it like any served request
        and the rest of the stream keeps flowing.  ``rejected=True``
        marks it as a capacity rejection, not a policy refusal;
        burning the refusal error budget is intentional (the user
        didn't get an answer), but Gateway stats count the two apart.
        """
        return ActionOutcome(
            qid=q.qid, action=action.idx, correct=False, refused=True,
            hallucinated=False, cost_tokens=REFUSE_COST_TOKENS,
            hit=False, answerable=q.answerable,
            answer=f"<rejected: {reason}>", rejected=True)

    @staticmethod
    def _transient_outcome(q: Question, action: Action,
                           reason: str) -> ActionOutcome:
        """A retryable fault (quarantined slot, executor fault, dead
        retrieval path): refused for reward/budget purposes, but
        ``transient=True`` lets the gateway retry it within the
        request's deadline before accounting."""
        return ActionOutcome(
            qid=q.qid, action=action.idx, correct=False, refused=True,
            hallucinated=False, cost_tokens=REFUSE_COST_TOKENS,
            hit=False, answerable=q.answerable,
            answer=f"<transient fault: {reason}>", transient=True)

    @staticmethod
    def _timeout_outcome(q: Question, action: Action) -> ActionOutcome:
        """Cancelled mid-stream past its deadline — an SLO violation
        (refused burns the budget), never retried."""
        return ActionOutcome(
            qid=q.qid, action=action.idx, correct=False, refused=True,
            hallucinated=False, cost_tokens=REFUSE_COST_TOKENS,
            hit=False, answerable=q.answerable,
            answer="<deadline exceeded>", timed_out=True)

    @classmethod
    def _failed_outcome(cls, q: Question, action: Action,
                        gen) -> ActionOutcome:
        """Map a failed :class:`CompletedGeneration` to its outcome."""
        if gen.timed_out:
            return cls._timeout_outcome(q, action)
        if gen.transient:
            return cls._transient_outcome(q, action, gen.failed)
        return cls._rejected_outcome(q, action, gen.failed)

    @staticmethod
    def _generated_outcome(q: Question, action: Action, prompt_len: int,
                           n_out: int, hit: bool,
                           degraded: bool = False) -> ActionOutcome:
        return ActionOutcome(
            qid=q.qid, action=action.idx, correct=False, refused=False,
            hallucinated=not q.answerable,
            cost_tokens=float(prompt_len + n_out), hit=hit,
            answerable=q.answerable,
            answer=f"<{n_out} generated tokens>", degraded=degraded)

    def execute_batch(self, questions: Sequence[Question],
                      action: Action) -> List[ActionOutcome]:
        if action.mode == "refuse":
            return [self._refusal_outcome(q, action) for q in questions]
        prompts, hits, degr = [], [], []
        for q in questions:
            toks, hit, degraded = self._prep(q, action)
            prompts.append(toks)
            hits.append(hit)
            degr.append(degraded)
        result = self.engine.generate(prompts,
                                      max_new_tokens=self.max_new_tokens)
        n_out = result.tokens.shape[1]
        return [self._generated_outcome(q, action, len(prompts[i]), n_out,
                                        hits[i], degr[i])
                for i, q in enumerate(questions)]


class ContinuousEngineBackend(EngineBackend):
    """Cross-bucket in-flight serving over the continuous engine.

    ``execute_mixed`` takes the whole routed micro-batch — one action
    per request — and submits every non-refuse request into the shared
    slot pool before a single ``run()`` drains them together.  The
    Gateway prefers this entry point when the backend provides it, so
    action buckets never execute serially.  Construction is inherited
    from :class:`EngineBackend`; ``engine`` must be a
    :class:`~repro.serving.continuous.ContinuousEngine` whose
    ``max_len`` >= ``max_prompt_len + max_new_tokens``.  Use
    :meth:`create` to build engine+backend together with a mesh or an
    explicit executor choice (single-device vs slot-sharded).
    """

    @classmethod
    def create(cls, model, params, tokenizer: HashTokenizer,
               index: BM25Index, *, mesh=None, executor=None,
               num_slots: int = 8, max_prompt_len: int = 384,
               max_new_tokens: int = 8, sync_every: int = 4,
               prefill_batch: Optional[int] = None,
               retrievers: Optional[Mapping[str, Retriever]] = None,
               retrieval_cache_size: int = 0, chaos=None,
               breaker_kw: Optional[dict] = None,
               **engine_kw) -> "ContinuousEngineBackend":
        """Build a :class:`~repro.serving.continuous.ContinuousEngine`
        sized for this backend's prompts and wrap it.

        ``mesh=None`` gives the single-device executor; passing a
        ``jax.sharding.Mesh`` shards the slot dimension over its data
        axis and the params over its model axis when ``mp > 1``
        (``ShardedExecutor`` — dp×mp tensor-parallel decode); an
        explicit ``executor`` overrides both.  Slot caches hold the
        padded prompt plus the generation budget
        (``max_prompt_len + max_new_tokens``).
        """
        from repro.serving.continuous import ContinuousEngine
        engine = ContinuousEngine(
            model, params, num_slots=num_slots,
            max_len=max_prompt_len + max_new_tokens,
            max_new_cap=max_new_tokens, sync_every=sync_every,
            prefill_batch=(num_slots if prefill_batch is None
                           else prefill_batch),
            mesh=mesh, executor=executor, chaos=chaos, **engine_kw)
        return cls(engine, tokenizer, index, max_prompt_len=max_prompt_len,
                   max_new_tokens=max_new_tokens, retrievers=retrievers,
                   retrieval_cache_size=retrieval_cache_size, chaos=chaos,
                   breaker_kw=breaker_kw)

    def execute_mixed(self, questions: Sequence[Question],
                      actions: Sequence[Action]) -> List[ActionOutcome]:
        from repro.core.errors import TransientFaultError
        outcomes: List[ActionOutcome] = [None] * len(questions)
        submitted = {}   # rid -> (position, question, action, hit, plen,
        #                          degraded)
        for i, (q, action) in enumerate(zip(questions, actions)):
            if action.mode == "refuse":
                outcomes[i] = self._refusal_outcome(q, action)
                continue
            try:
                toks, hit, degraded = self._prep(q, action)
            except TransientFaultError as exc:
                # dead retrieval path for THIS request only — the rest
                # of the micro-batch still serves
                outcomes[i] = self._transient_outcome(q, action, str(exc))
                continue
            rid = self.engine.reserve_rid()
            # non-strict: an over-length prompt is rejected per-request
            # (failed CompletedGeneration) instead of raising and
            # killing the micro-batch with other slots still resident
            self.engine.submit(rid, toks, self.max_new_tokens,
                               strict=False)
            submitted[rid] = (i, q, action, hit, len(toks), degraded)
        if submitted:
            done = self.engine.run()
            for rid, (i, q, action, hit, plen, degraded) in \
                    submitted.items():
                gen = done[rid]
                if gen.failed:
                    outcomes[i] = self._failed_outcome(q, action, gen)
                else:
                    outcomes[i] = self._generated_outcome(
                        q, action, plen, gen.n_steps, hit, degraded)
                # engine-clock stamps: the Gateway slices its dispatch
                # window into prefill/decode spans with these
                outcomes[i].admitted_at = gen.admitted_at
                outcomes[i].finished_at = gen.finished_at
        return outcomes

    def execute_batch(self, questions: Sequence[Question],
                      action: Action) -> List[ActionOutcome]:
        # single-bucket fallback routes through the same shared stream
        return self.execute_mixed(questions, [action] * len(questions))

    # -- streaming protocol (AsyncGateway) -----------------------------

    @property
    def _stream_pending(self) -> Dict[int, tuple]:
        # lazily created so the closed-loop construction paths (and
        # pickling in subprocess probes) stay untouched
        try:
            return self._stream_pending_map
        except AttributeError:
            self._stream_pending_map: Dict[int, tuple] = {}
            return self._stream_pending_map

    @property
    def stream_backlog(self) -> int:
        """Requests submitted into the engine but not yet completed —
        the queue-depth signal admission control sheds on."""
        return len(self._stream_pending)

    def stream_submit(self, question: Question, action: Action, *,
                      deadline_at: float = 0.0
                      ) -> Tuple[Optional[int], Optional[ActionOutcome]]:
        """Submit ONE routed request into the shared slot pool without
        blocking.  Refusals complete immediately (``(None, outcome)``);
        everything else returns ``(rid, None)`` and resolves through
        :meth:`stream_poll`.  Over-length prompts reject per-request
        inside the engine and surface at the next poll.  A nonzero
        ``deadline_at`` (engine-clock instant) is enforced mid-stream:
        the engine cancels the request past it.  A dead retrieval path
        raises ``TransientFaultError`` — the AsyncGateway catches it
        and schedules a bounded retry."""
        if action.mode == "refuse":
            return None, self._refusal_outcome(question, action)
        toks, hit, degraded = self._prep(question, action)
        rid = self.engine.reserve_rid()
        self.engine.submit(rid, toks, self.max_new_tokens, strict=False,
                           deadline_at=deadline_at)
        self._stream_pending[rid] = (question, action, hit, len(toks),
                                     degraded)
        return rid, None

    def stream_poll(self) -> List[StreamCompletion]:
        """One engine scheduling step (decode chunk / admissions /
        harvest); returns completions since the last poll.  Non-
        blocking with respect to the stream: in-flight requests keep
        decoding across successive polls."""
        done: List[StreamCompletion] = []
        for rid, gen in self.engine.poll().items():
            meta = self._stream_pending.pop(rid, None)
            if meta is None:
                continue     # a closed-loop rid (modes must not mix)
            q, action, hit, plen, degraded = meta
            if gen.failed:
                out = self._failed_outcome(q, action, gen)
            else:
                out = self._generated_outcome(q, action, plen,
                                              gen.n_steps, hit, degraded)
            done.append(StreamCompletion(
                rid=rid, outcome=out, admitted_at=gen.admitted_at,
                finished_at=gen.finished_at))
        return done
