"""Real-model generation backend: BM25 retrieval + the JAX KV-cache
:class:`~repro.serving.engine.Engine` behind the
:class:`~repro.routing.backends.GenerationBackend` protocol.

Replaces the hand-rolled route→retrieve→prefill/decode loop that used
to live in ``examples/serve_rag_slo.py``: the Gateway buckets requests
by routed action, and each non-refuse bucket becomes ONE batched
prefill+decode call.  The tiny local model has no answer scorer, so
outcomes carry token-accounting truth (cost, refusal) and conservative
quality indicators (``correct=False``; unanswerable queries that get an
answer anyway count as hallucinations), exactly as the old driver did.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.data.synthetic_squad import Question
from repro.data.tokenizer import HashTokenizer
from repro.generation.prompts import REFUSAL_TEXT, build_prompt
from repro.retrieval.bm25 import BM25Index
from repro.routing.registry import Action
from repro.serving.engine import Engine
from repro.serving.pipeline import ActionOutcome

# Matches the pre-retrieval refusal accounting of the old serve driver.
REFUSE_COST_TOKENS = 5.0


class EngineBackend:
    """Batched retrieval + real JAX generation for one action bucket."""

    def __init__(self, engine: Engine, tokenizer: HashTokenizer,
                 index: BM25Index, *, max_prompt_len: int = 384,
                 max_new_tokens: int = 8):
        self.engine = engine
        self.tok = tokenizer
        self.index = index
        self.max_prompt_len = max_prompt_len
        self.max_new_tokens = max_new_tokens

    def _retrieve(self, question: str, k: int) -> List[str]:
        if k <= 0:
            return []
        idx, _ = self.index.topk(question, k)
        return [self.index.texts[i] for i in idx]

    def execute_batch(self, questions: Sequence[Question],
                      action: Action) -> List[ActionOutcome]:
        if action.mode == "refuse":
            return [ActionOutcome(
                qid=q.qid, action=action.idx, correct=False, refused=True,
                hallucinated=False, cost_tokens=REFUSE_COST_TOKENS,
                hit=False, answerable=q.answerable, answer=REFUSAL_TEXT)
                for q in questions]

        prompts, hits = [], []
        for q in questions:
            passages = self._retrieve(q.text, action.k)
            hits.append(bool(q.gold_answer) and any(
                q.gold_answer in p for p in passages))
            prompt = build_prompt(action.mode, q.text, passages)
            prompts.append(self.tok.encode(prompt, bos=True,
                                           max_len=self.max_prompt_len))
        result = self.engine.generate(prompts,
                                      max_new_tokens=self.max_new_tokens)
        n_out = result.tokens.shape[1]
        return [ActionOutcome(
            qid=q.qid, action=action.idx, correct=False, refused=False,
            hallucinated=not q.answerable,
            cost_tokens=float(len(prompts[i]) + n_out), hit=hits[i],
            answerable=q.answerable, answer=f"<{n_out} generated tokens>")
            for i, q in enumerate(questions)]
