"""The :class:`RoutingPolicy` protocol and its adapters.

One interface for every way the system can pick an action per request:

* :class:`FixedPolicy` — the paper's fixed baselines (always a_i);
* :class:`MLPPolicy` — the trained routing MLP (any objective from
  ``core/policy.py``: argmax_ce, argmax_ce_wt, soft_reward);
* :class:`ConstrainedPolicy` — the Lagrangian refusal-capped variant;
* :class:`ConditionedPolicy` — the SLO-conditioned single policy from
  ``core/conditioned.py`` (profile weights appended to the state).

``route(states, slo, context) -> RoutingDecision`` is vectorized over
the batch; MLP forward passes run jitted through ``policy_logits``.
Inference-time constraints (the adaptive refusal cap the Gateway
derives from error-budget burn) are applied inside ``route`` via
:func:`apply_refusal_cap` and recorded on the decision, so callers can
audit exactly what the policy did and why.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Protocol, Sequence, Union, runtime_checkable

import numpy as np

from repro.core.config import RouterConfig, SLOProfile
from repro.routing.registry import (ActionSpace, get_action_space,
                                    get_slo_profile)

SLOLike = Union[None, str, SLOProfile, Sequence[Union[str, SLOProfile]]]


@dataclass(frozen=True)
class RoutingContext:
    """Per-call serving context the Gateway threads into ``route``."""

    refusal_cap: Optional[float] = None   # max refuse share of this batch
    action_space: Optional[ActionSpace] = None


@dataclass
class RoutingDecision:
    """What the policy decided for one batch, and why."""

    actions: np.ndarray                 # (B,) int64
    logits: Optional[np.ndarray] = None  # (B, A) raw policy scores
    confidences: Optional[np.ndarray] = None  # (B,) p(chosen action)
    constraints: Dict[str, float] = field(default_factory=dict)
    policy: str = ""

    @property
    def n(self) -> int:
        return len(self.actions)


@runtime_checkable
class RoutingPolicy(Protocol):
    """Anything that can route a batch of request states to actions."""

    name: str

    def route(self, states: np.ndarray, slo: SLOLike = None,
              context: Optional[RoutingContext] = None) -> RoutingDecision:
        ...


def apply_refusal_cap(logits: np.ndarray, acts: np.ndarray, cap: float,
                      refuse_action: int) -> int:
    """Demote the least-confident refusals until ≤ ``cap`` of the batch
    refuses; returns the number of demotions.  Mutates ``acts``.

    This is the serving-time collapse mitigation (paper §7.1 made
    adaptive): each demoted request falls back to its runner-up action.
    """
    is_ref = acts == refuse_action
    n_allowed = int(cap * len(acts))
    n_demote = int(is_ref.sum()) - n_allowed
    if n_demote <= 0:
        return 0
    margin = logits[:, refuse_action] - np.partition(logits, -2, axis=1)[:, -2]
    order = np.argsort(np.where(is_ref, margin, np.inf))
    for i in order[:n_demote]:
        runner = np.argsort(logits[i])[-2]
        acts[i] = runner
    return n_demote


def _decision_from_logits(logits: np.ndarray, name: str,
                          context: Optional[RoutingContext]) -> RoutingDecision:
    """argmax + optional refusal-cap constraint + confidences."""
    logits = np.asarray(logits)
    acts = logits.argmax(axis=-1).astype(np.int64)
    constraints: Dict[str, float] = {}
    cap = context.refusal_cap if context else None
    if cap is not None:
        space = (context.action_space if context and context.action_space
                 else get_action_space())
        ref = space.refuse_action
        if ref is not None:
            n_demoted = apply_refusal_cap(logits, acts, cap, ref)
            constraints["refusal_cap"] = float(cap)
            constraints["n_demoted"] = float(n_demoted)
    z = logits - logits.max(axis=-1, keepdims=True)
    p = np.exp(z) / np.exp(z).sum(axis=-1, keepdims=True)
    conf = p[np.arange(len(acts)), acts]
    return RoutingDecision(actions=acts, logits=logits, confidences=conf,
                           constraints=constraints, policy=name)


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------


class FixedPolicy:
    """The paper's fixed baselines: always action ``a``."""

    def __init__(self, action: int, *, name: Optional[str] = None):
        self.action = int(action)
        self.name = name or f"fixed(a{action})"

    def route(self, states, slo=None, context=None) -> RoutingDecision:
        n = len(states)
        acts = np.full(n, self.action, np.int64)
        return RoutingDecision(actions=acts,
                               confidences=np.ones(n, np.float32),
                               policy=self.name)


class MLPPolicy:
    """Adapter around the trained routing MLP (``core/policy.py``)."""

    def __init__(self, params, cfg: RouterConfig, *, name: str = "mlp",
                 train_result=None):
        self.params = params
        self.cfg = cfg
        self.name = name
        self.train_result = train_result

    @classmethod
    def train(cls, log, rewards, cfg: RouterConfig, *,
              objective: Optional[str] = None, refusal_cap: float = 1.0,
              dual_lr: float = 8.0, seed: Optional[int] = None,
              name: Optional[str] = None) -> "MLPPolicy":
        from repro.core.policy import train_policy
        tr = train_policy(log, rewards, cfg, objective=objective,
                          refusal_cap=refusal_cap, dual_lr=dual_lr, seed=seed)
        return cls(tr.params, cfg, name=name or (objective or cfg.objective),
                   train_result=tr)

    def logits(self, states: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp
        from repro.core.policy import policy_logits
        return np.asarray(policy_logits(self.params, jnp.asarray(states),
                                        self.cfg))

    def actions(self, states: np.ndarray) -> np.ndarray:
        return self.route(states).actions

    def route(self, states, slo=None, context=None) -> RoutingDecision:
        return _decision_from_logits(self.logits(np.asarray(states)),
                                     self.name, context)


class ConstrainedPolicy(MLPPolicy):
    """Lagrangian refusal-capped MLP (the trained §7.1 mitigation)."""

    def __init__(self, params, cfg: RouterConfig, *,
                 trained_refusal_cap: float = 1.0, lagrange: float = 0.0,
                 name: str = "constrained", train_result=None):
        super().__init__(params, cfg, name=name, train_result=train_result)
        self.trained_refusal_cap = trained_refusal_cap
        self.lagrange = lagrange

    @classmethod
    def train(cls, log, rewards, cfg: RouterConfig, *,
              objective: str = "constrained", refusal_cap: float = 0.45,
              dual_lr: float = 8.0, seed: Optional[int] = None,
              name: str = "constrained") -> "ConstrainedPolicy":
        if objective != "constrained":
            raise ValueError(
                f"ConstrainedPolicy trains the 'constrained' objective, "
                f"got {objective!r}; use MLPPolicy.train for other objectives")
        from repro.core.policy import train_policy
        tr = train_policy(log, rewards, cfg, objective="constrained",
                          refusal_cap=refusal_cap, dual_lr=dual_lr, seed=seed)
        return cls(tr.params, cfg, trained_refusal_cap=refusal_cap,
                   lagrange=tr.lagrange, name=name, train_result=tr)

    def route(self, states, slo=None, context=None) -> RoutingDecision:
        d = super().route(states, slo, context)
        d.constraints.setdefault("trained_refusal_cap",
                                 float(self.trained_refusal_cap))
        d.constraints.setdefault("lagrange", float(self.lagrange))
        return d


class ConditionedPolicy(MLPPolicy):
    """One policy for every SLO: profile weights appended to the state
    (``core/conditioned.py``).  ``slo`` is required and may be a single
    profile/name or one per request."""

    def __init__(self, params, ccfg: RouterConfig, *,
                 name: str = "conditioned", train_result=None):
        super().__init__(params, ccfg, name=name, train_result=train_result)

    @classmethod
    def train(cls, log, profiles: Sequence[SLOProfile], cfg: RouterConfig, *,
              objective: str = "argmax_ce", n_interp: int = 3,
              name: str = "conditioned") -> "ConditionedPolicy":
        from repro.core.conditioned import train_conditioned
        tr, ccfg = train_conditioned(log, profiles, cfg,
                                     objective=objective, n_interp=n_interp)
        return cls(tr.params, ccfg, name=name, train_result=tr)

    def _condition(self, states: np.ndarray, slo: SLOLike) -> np.ndarray:
        from repro.core.conditioned import profile_vector
        if slo is None:
            raise ValueError("ConditionedPolicy.route requires an SLO")
        states = np.asarray(states)
        if isinstance(slo, (str, SLOProfile)):
            v = profile_vector(get_slo_profile(slo))
            cond = np.tile(v[None], (len(states), 1))
        else:
            if len(slo) != len(states):
                raise ValueError(
                    f"{len(slo)} SLOs for {len(states)} states")
            cond = np.stack([profile_vector(get_slo_profile(s)) for s in slo])
        return np.concatenate([states, cond], axis=1)

    def route(self, states, slo=None, context=None) -> RoutingDecision:
        return _decision_from_logits(self.logits(self._condition(states, slo)),
                                     self.name, context)
