"""Generation backends behind one protocol.

The Gateway executes a routed action bucket through a
:class:`GenerationBackend`; the simulator pipeline and the real JAX
KV-cache engine are interchangeable behind ``execute_batch``.  The
heavy JAX backend lives in ``engine_backend.py`` so the simulator path
stays import-light.
"""
from __future__ import annotations

from typing import List, Protocol, Sequence, runtime_checkable

from repro.data.synthetic_squad import Question
from repro.routing.registry import Action
from repro.serving.pipeline import ActionOutcome, RAGPipeline


@runtime_checkable
class GenerationBackend(Protocol):
    """Executes one action for a bucket of requests.

    Backends may additionally provide ``execute_mixed(questions,
    actions)`` taking one action per request; the Gateway prefers it
    when present so the whole routed micro-batch — all action buckets —
    executes as one shared in-flight stream (see
    :class:`~repro.routing.engine_backend.ContinuousEngineBackend`).
    """

    def execute_batch(self, questions: Sequence[Question],
                      action: Action) -> List[ActionOutcome]:
        ...


class SimulatorBackend:
    """The calibrated simulator pipeline as a generation backend."""

    def __init__(self, pipeline: RAGPipeline):
        self.pipeline = pipeline

    @property
    def index(self):
        return self.pipeline.index

    @property
    def retrieval_cache(self):
        """The pipeline's shared retrieval LRU (None when uncached) —
        the Gateway mirrors its hit counters into GatewayStats."""
        return self.pipeline.retrieval_cache

    def execute_batch(self, questions: Sequence[Question],
                      action: Action) -> List[ActionOutcome]:
        return [self.pipeline.execute(q, action) for q in questions]


def as_backend(backend_or_pipeline) -> GenerationBackend:
    """Accept either a backend or a raw :class:`RAGPipeline`."""
    if isinstance(backend_or_pipeline, RAGPipeline):
        return SimulatorBackend(backend_or_pipeline)
    return backend_or_pipeline
