"""Generation backends behind one protocol.

The Gateway executes a routed action bucket through a
:class:`GenerationBackend`; the simulator pipeline and the real JAX
KV-cache engine are interchangeable behind ``execute_batch``.  The
heavy JAX backend lives in ``engine_backend.py`` so the simulator path
stays import-light.

**Streaming protocol** (optional, for the open-loop
:class:`~repro.serving.streaming.AsyncGateway`): backends that can hold
requests in flight additionally provide

    stream_submit(question, action, *, deadline_at=0.0)
            -> (rid, immediate_outcome)
        enqueue ONE routed request without blocking.  Exactly one of
        the pair is non-None: immediate outcomes (refusals) never enter
        the service stream.  ``deadline_at`` (backend-clock instant,
        0 = none) lets deadline-enforcing backends cancel the request
        mid-stream; the simulator accepts but ignores it (its service
        model has no mid-service cancellation).  A transient fault at
        submit raises :class:`~repro.core.errors.TransientFaultError`,
        which the AsyncGateway turns into a bounded deadline-aware
        retry.
    stream_poll() -> List[StreamCompletion]
        advance the backend by one scheduling step and return every
        request completed since the last poll.
    stream_backlog -> int
        requests submitted but not yet completed (the queue-depth
        signal admission control sheds on).

:class:`~repro.routing.engine_backend.ContinuousEngineBackend`
implements it over the real slot engine;
:class:`SimulatorBackend` over a deterministic synthetic service model
(bounded concurrency, fixed polls-per-request) so admission-control
behaviour is testable without JAX in the loop.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Protocol, Sequence, Tuple, \
    runtime_checkable

from repro.data.synthetic_squad import Question
from repro.routing.registry import Action
from repro.serving.pipeline import ActionOutcome, RAGPipeline


@dataclass(frozen=True)
class StreamCompletion:
    """One finished in-flight request: its outcome plus the backend
    clock stamps open-loop latency accounting needs (``admitted_at`` is
    when the first token was produced — prefill dispatch)."""

    rid: int
    outcome: ActionOutcome
    admitted_at: float
    finished_at: float


@runtime_checkable
class GenerationBackend(Protocol):
    """Executes one action for a bucket of requests.

    Backends may additionally provide ``execute_mixed(questions,
    actions)`` taking one action per request; the Gateway prefers it
    when present so the whole routed micro-batch — all action buckets —
    executes as one shared in-flight stream (see
    :class:`~repro.routing.engine_backend.ContinuousEngineBackend`).
    """

    def execute_batch(self, questions: Sequence[Question],
                      action: Action) -> List[ActionOutcome]:
        ...


class SimulatorBackend:
    """The calibrated simulator pipeline as a generation backend.

    Streaming runs the pipeline's (instant) outcome through a synthetic
    service model: at most ``stream_slots`` requests in service, each
    occupying its slot for ``service_polls`` ``stream_poll`` calls,
    FIFO admission from a waiting queue.  Entirely deterministic.
    """

    def __init__(self, pipeline: RAGPipeline, *, stream_slots: int = 4,
                 service_polls: int = 2, clock=None):
        self.pipeline = pipeline
        self.stream_slots = max(1, stream_slots)
        self.service_polls = max(1, service_polls)
        self._clock = clock if clock is not None else time.perf_counter
        self._next_rid = 0
        # waiting: (rid, outcome); in service: [rid, outcome, polls_left,
        # admitted_at]
        self._waiting: Deque[Tuple[int, ActionOutcome]] = deque()
        self._in_service: List[list] = []

    @property
    def index(self):
        return self.pipeline.index

    @property
    def retrieval_cache(self):
        """The pipeline's shared retrieval LRU (None when uncached) —
        the Gateway mirrors its hit counters into GatewayStats."""
        return self.pipeline.retrieval_cache

    def install_tracer(self, tracer) -> None:
        """Adopt the Gateway's tracer: the pipeline notes retrieval
        spans that the gateway adopts per submitted request."""
        self.pipeline.tracer = tracer

    def bind_metrics(self, registry) -> None:
        from repro.retrieval.hybrid import bind_retrieval_metrics
        bind_retrieval_metrics(registry, {}, self.pipeline.retrieval_cache)

    def execute_batch(self, questions: Sequence[Question],
                      action: Action) -> List[ActionOutcome]:
        return [self.pipeline.execute(q, action) for q in questions]

    # -- streaming protocol -------------------------------------------

    @property
    def stream_backlog(self) -> int:
        return len(self._waiting) + len(self._in_service)

    def stream_submit(self, question: Question, action: Action, *,
                      deadline_at: float = 0.0
                      ) -> Tuple[Optional[int], Optional[ActionOutcome]]:
        # deadline_at accepted for protocol parity; the synthetic
        # service model never cancels mid-service (the AsyncGateway's
        # goodput accounting still marks late completions as misses)
        out = self.pipeline.execute(question, action)
        if action.mode == "refuse":
            return None, out          # refusals complete at the gate
        rid = self._next_rid
        self._next_rid += 1
        self._waiting.append((rid, out))
        return rid, None

    def _fill_slots(self) -> None:
        now = self._clock()
        while self._waiting and len(self._in_service) < self.stream_slots:
            rid, out = self._waiting.popleft()
            self._in_service.append([rid, out, self.service_polls, now])

    def stream_poll(self) -> List[StreamCompletion]:
        self._fill_slots()
        done: List[StreamCompletion] = []
        keep: List[list] = []
        now = self._clock()
        for entry in self._in_service:
            entry[2] -= 1
            if entry[2] <= 0:
                done.append(StreamCompletion(
                    rid=entry[0], outcome=entry[1],
                    admitted_at=entry[3], finished_at=now))
            else:
                keep.append(entry)
        self._in_service = keep
        self._fill_slots()
        return done


def as_backend(backend_or_pipeline) -> GenerationBackend:
    """Accept either a backend or a raw :class:`RAGPipeline`."""
    if isinstance(backend_or_pipeline, RAGPipeline):
        return SimulatorBackend(backend_or_pipeline)
    return backend_or_pipeline
