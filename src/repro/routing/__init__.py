"""Unified Router API: pluggable policies, action-space registry, and
the serving Gateway.

    from repro.routing import (Gateway, Request, MLPPolicy, FixedPolicy,
                               get_action_space, get_slo_profile)

Registry symbols import eagerly (they are dependency-light and
``repro.core.actions`` re-exports them); policy/gateway/backend symbols
load lazily via module ``__getattr__`` so that importing
``repro.core.actions`` — which pulls ``repro.routing.registry`` — never
drags in the policy/serving stack mid-import.
"""
from __future__ import annotations

import importlib

from repro.routing.registry import (Action, ActionSpace, DEFAULT_SPACE,
                                    HYBRID9_SPACE, PAPER_ACTION_SPACE,
                                    SPACE_DEFAULT_PROFILES,
                                    get_action_space, get_slo_profile,
                                    list_action_spaces, list_slo_profiles,
                                    register_action_space,
                                    register_slo_profile,
                                    slo_profile_from_config)

_LAZY = {
    # policy layer
    "RoutingPolicy": "repro.routing.policy",
    "RoutingDecision": "repro.routing.policy",
    "RoutingContext": "repro.routing.policy",
    "FixedPolicy": "repro.routing.policy",
    "MLPPolicy": "repro.routing.policy",
    "ConstrainedPolicy": "repro.routing.policy",
    "ConditionedPolicy": "repro.routing.policy",
    "apply_refusal_cap": "repro.routing.policy",
    # backends
    "GenerationBackend": "repro.routing.backends",
    "SimulatorBackend": "repro.routing.backends",
    "as_backend": "repro.routing.backends",
    "EngineBackend": "repro.routing.engine_backend",
    "ContinuousEngineBackend": "repro.routing.engine_backend",
    # gateway
    "Gateway": "repro.routing.gateway",
    "GatewayStats": "repro.routing.gateway",
    "Request": "repro.routing.gateway",
}

__all__ = ["Action", "ActionSpace", "DEFAULT_SPACE", "HYBRID9_SPACE",
           "PAPER_ACTION_SPACE", "SPACE_DEFAULT_PROFILES",
           "get_action_space", "get_slo_profile", "list_action_spaces",
           "list_slo_profiles", "register_action_space",
           "register_slo_profile", "slo_profile_from_config",
           *sorted(_LAZY)]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
