"""The serving Gateway: one entry point for SLO-routed RAG serving.

  submit -> micro-batch -> RoutingPolicy.route (per-request SLO,
  budget-derived refusal cap) -> action-bucketed batched execution on a
  GenerationBackend (simulator pipeline or real JAX engine) -> reward +
  error-budget accounting.

This facade subsumes the old ``Scheduler`` (now a thin wrapper kept for
backward compatibility) and the hand-rolled serve loop that used to
live in ``examples/serve_rag_slo.py``.  Anything that implements
:class:`~repro.routing.policy.RoutingPolicy` plugs in — fixed
baselines, trained MLPs, the Lagrangian-constrained variant, the
SLO-conditioned single policy — and sharded/async serving work lands
here rather than in N copies of the loop.  The execution side is
equally pluggable: a :class:`~repro.routing.engine_backend.ContinuousEngineBackend`
built with ``mesh=...`` serves the same mixed-action stream through the
slot-sharded multi-device executor, with no Gateway change — see
:attr:`Gateway.engine_stats` for the engine-side counters (decode
chunks, prefills, concurrency) drivers report alongside routing stats.
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core.actions import reward
from repro.core.config import RouterConfig
from repro.core.features import state_vector
from repro.core.serving_types import RequestOutcome
from repro.data.synthetic_squad import Question
from repro.obs import NULL_TRACER, MetricsRegistry, Tracer
from repro.routing.backends import GenerationBackend, as_backend
from repro.routing.policy import RoutingContext, RoutingDecision, RoutingPolicy
from repro.routing.registry import (ActionSpace, get_action_space,
                                    get_slo_profile)
from repro.serving.slo_budget import (DEFAULT_TARGETS, LatencyReservoir,
                                      SLOBudgetTracker)


@dataclass
class Request:
    qid: int
    question: Question
    slo: str = "quality_first"
    arrival_ms: float = 0.0
    # per-request completion-latency SLO (0 = none): stamped at arrival
    # by the open-loop AsyncGateway, measured at first token and
    # completion, and consulted by admission control (a request whose
    # deadline already passed while queued is shed, not served)
    deadline_ms: float = 0.0


@dataclass
class GatewayStats:
    served: int = 0
    # engine capacity rejections (ActionOutcome.rejected) — counted
    # apart from policy refusals so a misconfigured engine doesn't
    # masquerade as deliberate refusal behaviour
    rejected: int = 0
    # SLO-actuated admission-control counters (AsyncGateway) — each
    # actuation is tallied separately from policy refusals so the
    # control loop's interventions are auditable:
    #   shed            — rejected at the queue, never routed/served
    #   forced_refusals — policy chose to answer, burn forced refuse
    #   depth_clamped   — routed retrieval depth clamped shallower
    shed: int = 0
    forced_refusals: int = 0
    depth_clamped: int = 0
    # fault-tolerance counters — zero on a healthy run:
    #   degraded  — served, but the action's retriever was rewritten to
    #               the bm25 fallback (open breaker / retriever fault);
    #               counted apart from sheds and forced refusals so load
    #               degradation and fault degradation stay auditable
    #   timed_out — cancelled mid-stream past the request deadline
    #   retries   — transient-fault resubmissions (bounded, never past
    #               the deadline)
    #   faulted   — requests that still failed transiently after the
    #               retry budget (or with retries disabled)
    degraded: int = 0
    timed_out: int = 0
    retries: int = 0
    faulted: int = 0
    # serving-thread deaths / failed shutdown drains (AsyncGateway) —
    # the gateway has already failed by then, but the death itself must
    # be visible on a dashboard, not only as a dead thread
    fatal_errors: int = 0
    total_reward: float = 0.0
    # mirrors of the backend's shared retrieval LRU counters (0/0 when
    # the backend serves uncached) — repeated queries in a stream stop
    # re-scoring the corpus, and the hit rate shows up here
    retrieval_cache_hits: int = 0
    retrieval_cache_lookups: int = 0
    action_counts: Dict[int, int] = field(default_factory=lambda: defaultdict(int))
    refusal_cap_history: List[float] = field(default_factory=list)
    # bounded ring of recent decisions (O(1) trim in long runs)
    decisions: Deque[RoutingDecision] = field(
        default_factory=lambda: deque(maxlen=256))
    # bounded reservoir of per-request completion latencies — the one
    # home for serving percentiles (p50/p95/p99), O(capacity) forever
    latency: LatencyReservoir = field(default_factory=LatencyReservoir)

    @property
    def avg_reward(self) -> float:
        return self.total_reward / max(self.served, 1)

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p95/p99 (+ mean/max) over the recorded latencies."""
        return self.latency.percentiles()


class Gateway:
    """Queue → route → execute → account, for any policy × backend."""

    def __init__(self, policy: RoutingPolicy, backend: GenerationBackend, *,
                 router_cfg: Optional[RouterConfig] = None, index=None,
                 state_fn: Optional[Callable[[Sequence[Question]], np.ndarray]] = None,
                 action_space: Optional[ActionSpace] = None,
                 max_batch: int = 16, adaptive_refusal: bool = True,
                 base_refusal_share: float = 0.6, budget_targets=None,
                 on_outcome: Optional[Callable] = None, retry=None,
                 sleep: Optional[Callable[[float], None]] = None,
                 clock: Optional[Callable[[], float]] = None,
                 tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.policy = policy
        # injectable clock for per-request latency spans (perf_counter
        # default: monotonic, immune to NTP steps); the AsyncGateway
        # passes its virtual/real clock through here so closed- and
        # open-loop timing share one domain
        self.clock = clock if clock is not None else time.perf_counter
        # telemetry plane: a no-op tracer keeps the hot path branchless
        # and allocation-free when tracing is off (see repro.obs.trace)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # bounded deadline-aware resubmission of transient-fault
        # outcomes (a repro.serving.faults.RetryPolicy; None disables —
        # the closed-loop default, keeping pre-fault behaviour
        # bit-identical).  `sleep` is the backoff sleeper (injectable
        # for virtual-time tests).
        self.retry = retry
        self._sleep = sleep if sleep is not None else time.sleep
        self.backend = as_backend(backend)
        self.space = action_space or get_action_space()
        if state_fn is None:
            index = index if index is not None else getattr(self.backend,
                                                            "index", None)
            if index is None or router_cfg is None:
                raise ValueError(
                    "Gateway needs state_fn, or index+router_cfg to build "
                    "the default state_vector featurizer")
            state_fn = lambda qs: np.stack(
                [state_vector(q.text, index, router_cfg) for q in qs])
        self.state_fn = state_fn
        self.max_batch = max_batch
        self.adaptive = adaptive_refusal
        self.base_share = base_refusal_share
        self.budget = SLOBudgetTracker(budget_targets or DEFAULT_TARGETS)
        # observability hook: called with (request, action, outcome, reward)
        # after every served request — replaces hand-rolled serve loops in
        # examples/drivers that only wanted per-request reporting
        self.on_outcome = on_outcome
        self.stats = GatewayStats()
        self.queue: List[Request] = []
        # hand the tracer to layers below the gateway (backend retrieval
        # spans, engine prefill/decode-chunk spans)
        install = getattr(self.backend, "install_tracer", None)
        if install is not None and self.tracer.enabled:
            install(self.tracer)
        self.metrics = metrics
        self._lat_hist = None
        if metrics is not None:
            self._bind_metrics(metrics)

    def _bind_metrics(self, reg: MetricsRegistry) -> None:
        """Register this gateway's stat blocks as scrape-time views over
        one shared registry (GatewayStats, engine stats, page pool,
        breakers, retrieval cache)."""
        self._lat_hist = reg.histogram(
            "gateway_request_latency_ms",
            "end-to-end per-request latency (ms)")
        fields = ("served", "rejected", "shed", "forced_refusals",
                  "depth_clamped", "degraded", "timed_out", "retries",
                  "faulted", "fatal_errors")
        counters = {f: reg.counter(f"gateway_{f}_total") for f in fields}
        reward_g = reg.gauge("gateway_avg_reward",
                             "mean reward over served requests")
        cap_g = reg.gauge("gateway_refusal_cap",
                          "latest budget-actuated refusal cap")
        queue_g = reg.gauge("gateway_queue_depth",
                            "requests waiting in the submit queue")

        def scrape() -> None:
            st = self.stats
            for f, inst in counters.items():
                inst.set_total(getattr(st, f))
            reward_g.set(st.avg_reward)
            if st.refusal_cap_history:
                cap_g.set(st.refusal_cap_history[-1])
            queue_g.set(len(self.queue))

        reg.register_collector(scrape)
        bind = getattr(self.backend, "bind_metrics", None)
        if bind is not None:
            bind(reg)

    # ------------------------------------------------------------------
    def submit(self, reqs: Sequence[Request]) -> None:
        self.queue.extend(reqs)

    def _route(self, batch: List[Request]):
        states = self.state_fn([r.question for r in batch])
        cap = None
        if self.adaptive:
            cap = self.budget.refusal_cap_adjustment(self.base_share)
        ctx = RoutingContext(refusal_cap=cap, action_space=self.space)
        slos = [r.slo for r in batch]
        return self.policy.route(states, slos, ctx), cap

    def _account(self, r: Request, a: int, out, lat_ms: float) -> None:
        """Reward + error-budget bookkeeping for one served request."""
        action = self.space[a]
        profile = get_slo_profile(r.slo)
        rew = reward(profile, correct=out.correct,
                     cost_tokens=out.cost_tokens,
                     hallucinated=out.hallucinated,
                     refused=out.refused,
                     answerable=out.answerable,
                     pre_retrieval=(a == self.space.refuse_action))
        outcome = RequestOutcome(
            qid=r.qid, action=a, correct=out.correct,
            refused=out.refused, hallucinated=out.hallucinated,
            cost_tokens=out.cost_tokens,
            answerable=out.answerable, latency_ms=lat_ms)
        self.budget.record(outcome)
        self.stats.served += 1
        self.stats.latency.record(lat_ms)
        if self._lat_hist is not None:
            self._lat_hist.observe(lat_ms)
        if getattr(out, "rejected", False):
            self.stats.rejected += 1
        if getattr(out, "degraded", False):
            self.stats.degraded += 1
        if getattr(out, "timed_out", False):
            self.stats.timed_out += 1
        elif getattr(out, "transient", False):
            self.stats.faulted += 1
        self.stats.total_reward += rew
        self.stats.action_counts[a] += 1
        if self.on_outcome is not None:
            self.on_outcome(r, action, out, rew)

    def _sync_cache_stats(self) -> None:
        cache = getattr(self.backend, "retrieval_cache", None)
        if cache is not None:
            self.stats.retrieval_cache_hits = cache.hits
            self.stats.retrieval_cache_lookups = cache.lookups

    def _retry_transients(self, batch: List[Request], acts: List[int],
                          outs: List, execute) -> List:
        """Closed-loop bounded retries: re-execute the transient-fault
        subset of a served micro-batch (with backoff), never past a
        request's ``deadline_ms`` budget.  ``execute(questions,
        actions)`` runs the subset; healthy outcomes are kept as-is."""
        if self.retry is None or self.retry.max_retries <= 0:
            return outs
        t0 = time.perf_counter()
        for attempt in range(self.retry.max_retries):
            idxs = [i for i, o in enumerate(outs)
                    if getattr(o, "transient", False)
                    and not getattr(o, "timed_out", False)]
            if not idxs:
                break
            wait = self.retry.backoff(attempt)
            elig = []
            for i in idxs:
                dl = batch[i].deadline_ms
                if dl > 0 and (time.perf_counter() - t0 + wait) * 1e3 >= dl:
                    continue     # cannot finish inside the deadline
                elig.append(i)
            if not elig:
                break
            if wait > 0:
                self._sleep(wait)
            self.stats.retries += len(elig)
            redo = execute([batch[i].question for i in elig],
                           [self.space[acts[i]] for i in elig])
            for i, o in zip(elig, redo):
                outs[i] = o
        return outs

    def _finish_trace(self, r: Request, out, t_disp: float,
                      t_done: float) -> None:
        """Mark engine-stamped stages + close one request's span tree.
        ``admitted_at``/``finished_at`` are engine-clock stamps; when
        the engine shares the gateway clock (the default) they slice
        dispatch→done into prefill/decode/harvest, otherwise they are
        clamped into the dispatch window rather than trusted."""
        tr = self.tracer
        fin = getattr(out, "finished_at", 0.0)
        adm = getattr(out, "admitted_at", 0.0)
        fin = fin if t_disp < fin <= t_done else t_done
        adm = min(max(adm, t_disp), fin)
        tr.mark(r.qid, "prefill", t_disp, adm)
        tr.mark(r.qid, "decode", adm, fin)
        tr.mark(r.qid, "harvest", fin, t_done)
        if getattr(out, "timed_out", False):
            kind = "timed_out"
        elif getattr(out, "transient", False):
            kind = "faulted"
        else:
            kind = "completed"
        tr.finish_request(r.qid, kind, t=t_done,
                          cost_tokens=out.cost_tokens)

    def step(self) -> Optional[GatewayStats]:
        """Serve one micro-batch off the queue."""
        if not self.queue:
            return None
        batch, self.queue = self.queue[: self.max_batch], \
            self.queue[self.max_batch:]
        tr = self.tracer
        t_pop = tr.now()
        decision, cap = self._route(batch)
        # only log the cap when the policy actually enforced it — a
        # logit-less policy (e.g. FixedPolicy) cannot demote refusals,
        # and the history must not claim back-pressure that was a no-op
        if cap is not None and "refusal_cap" in decision.constraints:
            self.stats.refusal_cap_history.append(cap)
        self.stats.decisions.append(decision)

        if hasattr(self.backend, "execute_mixed"):
            # continuous backend: the whole routed micro-batch — every
            # action bucket — feeds one shared in-flight decode stream.
            acts = [int(a) for a in decision.actions]
            # self.clock defaults to perf_counter: monotonic — wall
            # clock can step backwards under NTP adjustment and produce
            # negative latency_ms
            t_disp = self.clock()
            if tr.enabled:
                for r in batch:
                    tr.begin_request(r.qid, t_pop)
                    tr.mark(r.qid, "queue_wait", t_pop, t_pop)
                    tr.mark(r.qid, "admission", t_pop, t_disp)
            outs = self.backend.execute_mixed(
                [r.question for r in batch],
                [self.space[a] for a in acts])
            outs = self._retry_transients(batch, acts, outs,
                                          self.backend.execute_mixed)
            t_done = self.clock()
            # retrieval notes from batched _prep calls interleave across
            # the micro-batch and cannot be attributed per-request here
            # (the streaming path adopts them per submit)
            tr.discard_pending()
            wall_ms = (t_done - t_disp) * 1e3
            for r, a, out in zip(batch, acts, outs):
                # true per-request completion span when the engine
                # stamped one (dispatch → finished_at); full batch wall
                # otherwise — never the old wall/len smear, which under-
                # reported every request in a slow micro-batch
                fin = getattr(out, "finished_at", 0.0)
                lat_ms = ((fin - t_disp) * 1e3
                          if t_disp < fin <= t_done else wall_ms)
                if tr.enabled:
                    self._finish_trace(r, out, t_disp, t_done)
                self._account(r, a, out, lat_ms)
            self._sync_cache_stats()
            return self.stats

        # bucket by action so each retrieval depth / generation mode
        # runs as one batched backend call (serial across buckets)
        buckets: Dict[int, List[int]] = defaultdict(list)
        for i, a in enumerate(decision.actions):
            buckets[int(a)].append(i)

        for a, idxs in sorted(buckets.items()):
            action = self.space[a]
            t_disp = self.clock()
            outs = self.backend.execute_batch(
                [batch[i].question for i in idxs], action)
            if self.retry is not None:
                outs = self._retry_transients(
                    [batch[i] for i in idxs], [a] * len(idxs), outs,
                    lambda qs, actions: self.backend.execute_batch(
                        qs, actions[0]))
            t_done = self.clock()
            tr.discard_pending()
            # each request in the bucket experienced the full bucket
            # call, so it gets the full wall — not wall/len
            wall_ms = (t_done - t_disp) * 1e3
            for i, out in zip(idxs, outs):
                r = batch[i]
                if tr.enabled:
                    tr.begin_request(r.qid, t_pop)
                    tr.mark(r.qid, "queue_wait", t_pop, t_pop)
                    tr.mark(r.qid, "admission", t_pop, t_disp)
                    self._finish_trace(r, out, t_disp, t_done)
                self._account(r, a, out, wall_ms)
        self._sync_cache_stats()
        return self.stats

    def drain(self) -> GatewayStats:
        while self.queue:
            self.step()
        return self.stats

    def serve(self, reqs: Sequence[Request]) -> GatewayStats:
        """Convenience: submit + drain."""
        self.submit(reqs)
        return self.drain()

    @property
    def engine_stats(self):
        """The backend engine's serving counters (or None for backends
        without an engine, e.g. the simulator) — decode chunks,
        prefills, slot concurrency; what serve drivers print alongside
        routing stats."""
        engine = getattr(self.backend, "engine", None)
        return getattr(engine, "stats", None)

    @property
    def refusal_share(self) -> float:
        ref = self.space.refuse_action
        if ref is None:
            return 0.0
        return self.stats.action_counts.get(ref, 0) / max(self.stats.served, 1)
