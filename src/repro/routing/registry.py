"""Action-space and SLO-profile registries — the routing control surface.

The paper fixes a 5-action space (§3.1) and two SLO profiles (§3.2);
production serving needs both to be *data*, not hardcoded tuples:
retrieval depths differ per corpus, SLO profiles arrive from request
headers or config files, and new named spaces must not fork the router.

This module owns:

* :class:`Action` / :class:`ActionSpace` — an immutable, validated,
  named action space (retrieval depth + prompting mode per action);
* a named action-space registry, seeded with the paper's 5-action
  space under the name ``"paper5"`` so every paper number reproduces
  bit-for-bit through the registry path;
* a named SLO-profile registry, seeded with the paper's
  ``quality_first`` / ``cheap`` profiles, extensible from plain dicts
  (:func:`slo_profile_from_config`).

``repro.core.actions`` re-exports the defaults (``ACTIONS``,
``SLO_PROFILES``…) for backward compatibility; new code should import
from ``repro.routing``.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Iterator, List, Mapping, Optional, Tuple

from repro.core.config import SLOProfile

VALID_MODES = ("guarded", "auto", "refuse")


@dataclass(frozen=True)
class Action:
    idx: int
    k: int            # retrieval depth (0 = no retrieval)
    mode: str         # guarded | auto | refuse
    # which registered retriever serves this action's depth-k lookup
    # (the second big cost/quality lever after depth; "bm25" keeps the
    # paper's single-retriever space bit-for-bit)
    retriever: str = "bm25"


@dataclass(frozen=True)
class ActionSpace:
    """A named, ordered action space.

    Invariants: action indices equal their position, modes are valid,
    refuse actions retrieve nothing.
    """

    name: str
    actions: Tuple[Action, ...]

    def __post_init__(self):
        if not self.actions:
            raise ValueError(f"action space {self.name!r} is empty")
        for pos, a in enumerate(self.actions):
            if a.idx != pos:
                raise ValueError(
                    f"{self.name!r}: action at position {pos} has idx {a.idx}")
            if a.mode not in VALID_MODES:
                raise ValueError(f"{self.name!r}: invalid mode {a.mode!r}")
            if a.mode == "refuse" and a.k != 0:
                raise ValueError(
                    f"{self.name!r}: refuse action {pos} must have k=0")
            if not a.retriever:
                raise ValueError(
                    f"{self.name!r}: action {pos} has empty retriever")

    @property
    def n_actions(self) -> int:
        return len(self.actions)

    @property
    def refuse_action(self) -> Optional[int]:
        """Index of the (first) refuse action, or None."""
        for a in self.actions:
            if a.mode == "refuse":
                return a.idx
        return None

    def __len__(self) -> int:
        return len(self.actions)

    def __iter__(self) -> Iterator[Action]:
        return iter(self.actions)

    def __getitem__(self, idx: int) -> Action:
        return self.actions[idx]

    def to_config(self) -> dict:
        return {"name": self.name,
                "actions": [asdict(a) for a in self.actions]}

    @classmethod
    def from_config(cls, cfg: Mapping) -> "ActionSpace":
        """Build from a plain dict, e.g. parsed JSON/YAML.

        ``{"name": ..., "actions": [{"k": 5, "mode": "guarded",
        "retriever": "dense"}, ...]}`` (``idx`` defaults to the list
        position, ``retriever`` to ``"bm25"``).
        """
        actions = tuple(
            Action(int(a.get("idx", i)), int(a["k"]), str(a["mode"]),
                   str(a.get("retriever", "bm25")))
            for i, a in enumerate(cfg["actions"]))
        return cls(str(cfg["name"]), actions)

    @property
    def retriever_names(self) -> Tuple[str, ...]:
        """Retrievers this space's non-refuse actions reference (the
        set an executor must be able to resolve), in first-use order."""
        seen = []
        for a in self.actions:
            if a.mode != "refuse" and a.retriever not in seen:
                seen.append(a.retriever)
        return tuple(seen)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

_ACTION_SPACES: Dict[str, ActionSpace] = {}
# The live profile registry.  repro.core.actions re-exports this SAME
# dict as SLO_PROFILES, so profiles registered here are visible through
# the legacy import too.
SLO_PROFILES: Dict[str, SLOProfile] = {}
_SLO_PROFILES = SLO_PROFILES

DEFAULT_SPACE = "paper5"


def register_action_space(space: ActionSpace, *,
                          overwrite: bool = False) -> ActionSpace:
    if space.name in _ACTION_SPACES and not overwrite:
        raise ValueError(f"action space {space.name!r} already registered")
    _ACTION_SPACES[space.name] = space
    return space


def get_action_space(name: str = DEFAULT_SPACE) -> ActionSpace:
    try:
        return _ACTION_SPACES[name]
    except KeyError:
        raise KeyError(f"unknown action space {name!r}; "
                       f"registered: {sorted(_ACTION_SPACES)}") from None


def list_action_spaces() -> List[str]:
    return sorted(_ACTION_SPACES)


def register_slo_profile(profile: SLOProfile, *,
                         overwrite: bool = False) -> SLOProfile:
    if profile.name in _SLO_PROFILES and not overwrite:
        raise ValueError(f"SLO profile {profile.name!r} already registered")
    _SLO_PROFILES[profile.name] = profile
    return profile


def get_slo_profile(name_or_profile) -> SLOProfile:
    """Resolve a profile name (or pass a profile through)."""
    if isinstance(name_or_profile, SLOProfile):
        return name_or_profile
    try:
        return _SLO_PROFILES[name_or_profile]
    except KeyError:
        raise KeyError(f"unknown SLO profile {name_or_profile!r}; "
                       f"registered: {sorted(_SLO_PROFILES)}") from None


def list_slo_profiles() -> List[str]:
    return sorted(_SLO_PROFILES)


def slo_profile_from_config(cfg: Mapping) -> SLOProfile:
    """Build (and optionally register) a profile from a plain dict."""
    return SLOProfile(**dict(cfg))


# ---------------------------------------------------------------------------
# Paper defaults (§3.1, §3.2) — registered at import so the default
# registry entries reproduce every paper number bit-for-bit.
# ---------------------------------------------------------------------------

PAPER_ACTION_SPACE = register_action_space(ActionSpace(
    DEFAULT_SPACE,
    (Action(0, 2, "guarded"),
     Action(1, 5, "guarded"),
     Action(2, 10, "guarded"),
     Action(3, 5, "auto"),
     Action(4, 0, "refuse"))))

register_slo_profile(SLOProfile(
    name="quality_first",
    w_acc=1.0, w_cost=0.1, w_hall=0.25, w_ref=0.1, w_ref_wrong=0.15))
register_slo_profile(SLOProfile(
    name="cheap",
    w_acc=0.3, w_cost=0.8, w_hall=0.3, w_ref=0.35, w_ref_wrong=1.0))


# ---------------------------------------------------------------------------
# hybrid9: retriever choice as a routing action (beyond paper).
#
# The paper varies only DEPTH over one BM25 index; hybrid9 adds the
# other big cost/quality lever — WHICH retriever — crossing
# {bm25, dense, hybrid} × depth × {guarded, auto} (+ refuse).  The
# refuse action stays last, so the constrained objective's Lagrangian
# and the Gateway's cap logic carry over via ``space.refuse_action``.
#
# NOTE: the profile registry is deliberately NOT extended here — every
# registered profile feeds run_experiment's grid, and adding entries at
# import would silently change the paper tables.  hybrid9 serves under
# the paper's own profiles (SPACE_DEFAULT_PROFILES below); register
# bespoke profiles explicitly from config where needed.
# ---------------------------------------------------------------------------

HYBRID9_SPACE = register_action_space(ActionSpace(
    "hybrid9",
    (Action(0, 2, "guarded", "bm25"),
     Action(1, 5, "guarded", "bm25"),
     Action(2, 2, "guarded", "dense"),
     Action(3, 5, "guarded", "dense"),
     Action(4, 2, "guarded", "hybrid"),
     Action(5, 5, "guarded", "hybrid"),
     Action(6, 5, "auto", "bm25"),
     Action(7, 5, "auto", "hybrid"),
     Action(8, 0, "refuse"))))

# the SLO profiles each registered space is evaluated/served under by
# default (benchmarks' objective-ablation grids iterate these)
SPACE_DEFAULT_PROFILES: Dict[str, Tuple[str, ...]] = {
    "paper5": ("quality_first", "cheap"),
    "hybrid9": ("quality_first", "cheap"),
}
