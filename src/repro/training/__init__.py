from repro.training.optimizer import OptConfig, adamw_init_schema, adamw_update
from repro.training.steps import make_train_step, make_eval_step

__all__ = ["OptConfig", "adamw_init_schema", "adamw_update",
           "make_train_step", "make_eval_step"]
