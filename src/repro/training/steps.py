"""Train / eval steps with optional microbatch gradient accumulation."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.models.transformer import forward_train_loss, loss_fn
from repro.training.optimizer import OptConfig, adamw_update


def _split_microbatches(batch, n_mb: int):
    def f(x):
        b = x.shape[0]
        assert b % n_mb == 0, (b, n_mb)
        return x.reshape(n_mb, b // n_mb, *x.shape[1:])
    return jax.tree_util.tree_map(f, batch)


def make_train_step(model: Model, opt_cfg: OptConfig,
                    *, moe_fn: Optional[Callable] = None,
                    microbatches: int = 1, fused_loss: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch`` is a dict with "tokens", "labels" (+ modality inputs).
    ``microbatches > 1`` runs scan-based gradient accumulation — the
    production lever that bounds saved-activation memory at train_4k.
    ``fused_loss`` computes CE chunk-wise without materializing the
    (B, S, V) logits tensor (required at 100k+ vocabularies).
    """

    def loss_for(params, mb):
        if fused_loss:
            return forward_train_loss(params, model.cfg, mb, moe_fn=moe_fn)
        inputs = {k: v for k, v in mb.items() if k != "labels"}
        logits, extras = model.train_logits(params, inputs, moe_fn=moe_fn)
        return loss_fn(logits, mb["labels"], extras=extras)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_for)(params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc, lsum = carry
                l, g = jax.value_and_grad(loss_for)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, lsum + l), None

            (grads, lsum), _ = jax.lax.scan(body, (zero, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = lsum / microbatches

        params, opt_state, metrics = adamw_update(grads, opt_state, params,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model, *, moe_fn: Optional[Callable] = None):
    def eval_step(params, batch):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, extras = model.train_logits(params, inputs, moe_fn=moe_fn)
        return loss_fn(logits, batch["labels"], extras=extras)
    return eval_step
