"""AdamW with linear-warmup cosine decay and global-norm clipping.

Self-contained (no optax in this environment).  Moments are fp32 and
carry the same logical axes as their parameters, so the sharding
resolver gives them the same (FSDP) layout — ZeRO-1 semantics under
pjit.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.schema import ParamSpec


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def _moment_spec(ps: ParamSpec) -> ParamSpec:
    return dataclasses.replace(ps, dtype="float32", init="zeros")


def adamw_init_schema(param_schema) -> Dict[str, Any]:
    is_spec = lambda x: isinstance(x, ParamSpec)
    return {
        "m": jax.tree_util.tree_map(_moment_spec, param_schema, is_leaf=is_spec),
        "v": jax.tree_util.tree_map(_moment_spec, param_schema, is_leaf=is_spec),
        "step": ParamSpec((), (), "int32", "zeros"),
    }


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / bc1, v / bc2
        u = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 1:  # decoupled weight decay (skip scalars/norms-ish)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
