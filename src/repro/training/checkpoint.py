"""Checkpointing: flat-key npz with pytree structure manifest.

No orbax in this container; .npz + a JSON treedef is enough for
single-host examples and keeps restore deterministic.  Sharded arrays
are gathered before save (fine at example scale; a production TPU
deployment would swap in orbax behind the same interface).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = jax.device_get(leaf)
        if str(arr.dtype) == "bfloat16":  # numpy can't serialize bf16
            arr = np.asarray(arr, np.float32)
        flat[key] = np.asarray(arr)
    return flat


def save_checkpoint(path: str | Path, step: int, params, opt_state=None,
                    extra: dict | None = None):
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(path / f"params_{step}.npz", **_flatten(params))
    if opt_state is not None:
        np.savez_compressed(path / f"opt_{step}.npz", **_flatten(opt_state))
    meta = {"step": step, "extra": extra or {}}
    (path / "latest.json").write_text(json.dumps(meta))
    return path / f"params_{step}.npz"


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves)


def load_checkpoint(path: str | Path, template_params,
                    template_opt=None) -> Tuple[int, Any, Any]:
    path = Path(path)
    meta = json.loads((path / "latest.json").read_text())
    step = meta["step"]
    z = np.load(path / f"params_{step}.npz")
    params = _unflatten_into(template_params, dict(z))
    opt = None
    if template_opt is not None and (path / f"opt_{step}.npz").exists():
        zo = np.load(path / f"opt_{step}.npz")
        opt = _unflatten_into(template_opt, dict(zo))
    return step, params, opt
