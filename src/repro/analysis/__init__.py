"""Static analysis for the serving stack.

Two tools live here:

* ``reprolint`` (:mod:`repro.analysis.base`, :mod:`repro.analysis.walker`,
  :mod:`repro.analysis.rules`) — an AST-based invariant linter run as
  ``python -m repro.analysis src/``.  Eight PRs of growth encoded
  load-bearing invariants only by convention (injectable clocks, jit
  donation + ``out_shardings``, Pallas VMEM budgets and masked tails,
  the typed error taxonomy, lock discipline in the streaming gateway);
  the linter makes them machine-checked.  CI enforces zero unsuppressed
  findings.  The linter is stdlib-only — it never imports jax — so it
  runs anywhere the source tree does.
* ``roofline`` (:mod:`repro.analysis.roofline`) — the three-term
  roofline model over dry-run artifacts (imports the heavy config
  machinery; deliberately NOT re-exported here).
"""
from repro.analysis.base import Finding, LintResult, Rule, all_rules
from repro.analysis.lintconfig import DEFAULT_CONFIG, LintConfig, RuleConfig
from repro.analysis.walker import ModuleContext, run_lint

__all__ = [
    "Finding", "LintResult", "Rule", "all_rules",
    "LintConfig", "RuleConfig", "DEFAULT_CONFIG",
    "ModuleContext", "run_lint",
]
