"""reprolint CLI: ``python -m repro.analysis src/ [options]``.

Exit codes: 0 clean (or findings present but ``--fail-on-findings`` not
given — useful for survey runs), 1 unsuppressed findings with the flag,
2 usage errors.  Suppressed findings never affect the exit code but are
always reported (human: a separate section; json: the ``suppressed``
list) so the allow-list stays auditable.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.base import all_rules
from repro.analysis.lintconfig import LintConfig, make_default_config
from repro.analysis.walker import run_lint


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: AST invariant checks for the "
                    "JAX/Pallas serving stack")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to lint (default: src)")
    p.add_argument("--format", choices=("human", "json"),
                   default="human", help="output format")
    p.add_argument("--fail-on-findings", action="store_true",
                   help="exit 1 if any unsuppressed finding remains")
    p.add_argument("--config", metavar="JSON",
                   help="JSON config file overlaying the defaults")
    p.add_argument("--rules", metavar="IDS",
                   help="comma-separated rule ids to run (others off)")
    p.add_argument("--budget-mib", type=float, metavar="MIB",
                   help="override the RPL004 VMEM budget, in MiB")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    return p


def _list_rules() -> None:
    for rid, cls in all_rules().items():
        print(f"{rid}  {cls.name:<22} {cls.summary}")


def _human(result) -> None:
    for f in result.findings:
        print(f"{f.location()}: {f.rule} {f.message}")
    if result.suppressed:
        print(f"-- {len(result.suppressed)} suppressed "
              f"(allow[] with reason) --")
        for f in result.suppressed:
            print(f"{f.location()}: {f.rule} [allowed: "
                  f"{f.suppress_reason}]")
    counts = ", ".join(f"{k}={v}" for k, v in result.counts.items())
    print(f"{result.n_files} files, {len(result.findings)} findings"
          + (f" ({counts})" if counts else "")
          + f", {len(result.suppressed)} suppressed")


def build_config(args) -> LintConfig:
    cfg = (LintConfig.from_file(args.config) if args.config
           else make_default_config())
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",") if r.strip()}
        unknown = wanted - set(all_rules())
        if unknown:
            raise SystemExit(
                f"unknown rule id(s): {', '.join(sorted(unknown))}")
        for rid in all_rules():
            cfg.rule(rid).enabled = rid in wanted
    if args.budget_mib is not None:
        cfg.rule("RPL004").options["budget_bytes"] = int(
            args.budget_mib * 2 ** 20)
    return cfg


def main(argv: Optional[List[str]] = None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        _list_rules()
        return 0
    result = run_lint(args.paths, config=build_config(args))
    if args.format == "json":
        print(json.dumps(result.to_dict(), indent=2))
    else:
        _human(result)
    if args.fail_on_findings and result.findings:
        return 1
    return 0


if __name__ == "__main__":   # pragma: no cover
    sys.exit(main())
