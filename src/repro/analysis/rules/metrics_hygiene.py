"""RPL007 metric-hygiene: telemetry names and clock injection.

The telemetry plane (:mod:`repro.obs`) has three invariants the
runtime enforces late (at registration / construction) that are much
cheaper to catch at lint time:

* **Names are ``lowercase_snake``.**  Prometheus exposition mangles
  anything else, and mixed-case metric families fragment dashboards.
  Checked on every literal first argument of a
  ``.counter(...)`` / ``.gauge(...)`` / ``.histogram(...)`` call.
  f-string names (``f"breaker_{name}_trips_total"``) are validated at
  runtime by the registry instead — the static rule skips them.
* **A name registers exactly once per registry.**  Two literal
  registrations of the same name on the same receiver in one scope
  would raise at runtime on the SECOND call — after the first already
  mutated the registry; the linter flags it before anything runs.
* **Every ``Tracer``/``MetricsRegistry`` construction injects a
  clock.**  A zero-arg construction would either crash (both raise
  TypeError) or — were the default ever relaxed — silently fall back
  to wall time and break virtual-time replay (the RPL001 invariant).
  ``NullTracer()`` is exempt: the no-op tracer never reads a clock.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Tuple

from repro.analysis.base import Finding, Rule
from repro.analysis.walker import root_name, walk_scope

_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")
_BINDERS = ("counter", "gauge", "histogram")
_CLOCKED = ("Tracer", "MetricsRegistry")


def _literal_metric_call(node: ast.Call):
    """(receiver_root, name) when ``node`` is ``<recv>.counter("x", ...)``
    (or gauge/histogram) with a literal string name, else None."""
    fn = node.func
    if not (isinstance(fn, ast.Attribute) and fn.attr in _BINDERS):
        return None
    if not node.args:
        return None
    arg = node.args[0]
    if not (isinstance(arg, ast.Constant) and isinstance(arg.value, str)):
        return None
    return (root_name(fn.value) or "?", arg.value)


class MetricsHygieneRule(Rule):
    id = "RPL007"
    name = "metric-hygiene"
    summary = ("metric name not lowercase_snake, duplicate registration "
               "on one registry, or Tracer/MetricsRegistry built "
               "without an injected clock")

    def check(self, ctx) -> Iterator[Finding]:
        scopes: List[ast.AST] = [ctx.tree] + [
            n for n in ast.walk(ctx.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for scope in scopes:
            # (receiver root, name) -> first registration node, per
            # scope: different scopes usually mean different registries
            seen: Dict[Tuple[str, str], ast.AST] = {}
            for node in walk_scope(scope):
                if not isinstance(node, ast.Call):
                    continue
                lit = _literal_metric_call(node)
                if lit is not None:
                    recv, name = lit
                    if not _NAME_RE.match(name):
                        yield self.finding(
                            ctx, node,
                            f"metric name {name!r} is not "
                            f"lowercase_snake ([a-z][a-z0-9_]*) — "
                            f"Prometheus exposition requires it")
                    elif lit in seen:
                        yield self.finding(
                            ctx, node,
                            f"metric {name!r} registered twice on "
                            f"`{recv}` (first at line "
                            f"{seen[lit].lineno}) — each name may be "
                            f"registered exactly once per registry")
                    else:
                        seen[lit] = node
                # clock injection on tracer/registry construction
                fn = node.func
                ctor = (fn.id if isinstance(fn, ast.Name)
                        else fn.attr if isinstance(fn, ast.Attribute)
                        else None)
                if ctor in _CLOCKED:
                    has_clock = bool(node.args) or any(
                        kw.arg == "clock" or kw.arg is None  # **kw
                        for kw in node.keywords)
                    if not has_clock:
                        yield self.finding(
                            ctx, node,
                            f"`{ctor}()` constructed without an "
                            f"injectable clock — pass the gateway's "
                            f"clock (e.g. `{ctor}(clock.now)`) so "
                            f"telemetry replays in virtual time")
