"""RPL001 clock-discipline: no wall-clock calls outside the seam.

The serving plane is virtual-time-replayable end to end: every
latency-bearing component (Gateway, AsyncGateway, ContinuousEngine,
ChaosInjector, CircuitBreaker) takes an injectable ``clock``/``sleep``
and the traffic harness replays seeded runs bit-for-bit on a
``VirtualClock``.  A stray ``time.time()`` breaks replay *and* measures
the wrong thing — wall time jumps under NTP step/slew, so latency
accounting must be ``time.perf_counter()`` (the PR 4 Gateway fix).

Flagged: *calls* to ``time.time``, ``time.sleep``, ``datetime.now``,
``datetime.utcnow``, ``datetime.today``.  Referencing ``time.sleep``
without calling it (e.g. as an injectable default:
``self._sleep = sleep or time.sleep``) is the seam itself and is fine;
``time.perf_counter`` / ``time.monotonic`` are always fine.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.base import Finding, Rule
from repro.analysis.walker import dotted_name, qualified

_BANNED = {
    "time.time": "wall-clock timestamp",
    "time.time_ns": "wall-clock timestamp",
    "time.sleep": "wall-clock sleep",
    "datetime.datetime.now": "wall-clock timestamp",
    "datetime.datetime.utcnow": "wall-clock timestamp",
    "datetime.datetime.today": "wall-clock timestamp",
    "datetime.date.today": "wall-clock timestamp",
}


class ClockDisciplineRule(Rule):
    id = "RPL001"
    name = "clock-discipline"
    summary = ("wall-clock time.time()/time.sleep()/datetime.now() call "
               "outside the injectable-clock seam")

    def check(self, ctx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = qualified(dotted_name(node.func), ctx.imports)
            # `from datetime import datetime; datetime.now()` resolves
            # to datetime.datetime.now via the import table; a bare
            # `datetime.now()` on `import datetime` does not exist, so
            # both spellings land on the qualified key.
            what = _BANNED.get(name)
            if what is None:
                continue
            fix = ("time.perf_counter() for intervals, or thread the "
                   "injectable clock/sleep seam through"
                   if name.startswith("time.") else
                   "an injected clock (wall timestamps break replay)")
            yield self.finding(
                ctx, node,
                f"{what} `{name}()` — use {fix}")
