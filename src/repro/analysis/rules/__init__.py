"""reprolint rule modules — importing this package registers them all."""
from repro.analysis.rules import (clock, determinism, exceptions,  # noqa: F401
                                  jit_donation, metrics_hygiene,
                                  pallas_vmem, threads)
