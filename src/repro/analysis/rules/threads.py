"""RPL005 thread-shared-state: cross-thread attribute writes hold the lock.

The AsyncGateway runs its decode loop on a background
``threading.Thread`` while public methods (``submit``/``cancel``/
``stop``/``snapshot``) mutate the same object from the caller's thread.
Python's GIL makes single bytecodes atomic but nothing larger: a
check-then-set on ``self._inflight`` or a multi-field stats update torn
across threads produces counts that never add up — the exact class of
bug the loadtest suite can only catch probabilistically.  The repo
contract is simple: any attribute written BOTH inside a thread-target
scope AND inside a public method must be written under ``with
self._lock`` (any ``self.*lock*``/``*cond*``/``*cv*`` context manager)
on both sides.

The rule resolves ``threading.Thread(target=...)`` targets — a closure
defined in the spawning method, or a bound method ``self._run`` — then
intersects the attributes they write with the attributes public methods
write, and flags every write site of a shared attribute that is not
lexically under a lock ``with``.  Single-writer attributes (touched by
only one side) are not flagged; neither are reads — lock discipline for
reads is a judgment call the linter leaves to review.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.base import Finding, Rule
from repro.analysis.walker import dotted_name, qualified

_LOCKISH = ("lock", "cond", "cv", "mutex", "sem")


def _is_lock_ctx(expr: ast.AST) -> bool:
    d = dotted_name(expr) or ""
    if not d.startswith("self."):
        return False
    leaf = d.rsplit(".", 1)[-1].lower()
    return any(frag in leaf for frag in _LOCKISH)


def _self_attr(target: ast.AST) -> Optional[str]:
    """The first attribute name of a ``self.x[...].y = ...`` write."""
    node = target
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            return node.attr
        node = node.value
    return None


def _collect_writes(fn: ast.AST) -> List[Tuple[str, ast.AST, bool]]:
    """(attr, node, under_lock) for every ``self.<attr>`` write in one
    scope, not descending into nested defs (they are their own
    potential thread targets)."""
    out: List[Tuple[str, ast.AST, bool]] = []

    def visit(node: ast.AST, locked: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            locked = locked or any(
                _is_lock_ctx(item.context_expr) for item in node.items)
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            attr = _self_attr(t)
            if attr is not None:
                out.append((attr, node, locked))
        for child in ast.iter_child_nodes(node):
            visit(child, locked)

    for stmt in getattr(fn, "body", []):
        visit(stmt, False)
    return out


def _thread_targets(cls: ast.ClassDef,
                    imports: Dict[str, str]) -> List[ast.AST]:
    """Function nodes handed to ``threading.Thread(target=...)``
    anywhere in the class: closures in the spawning method, bound
    methods of the class, or module functions are resolved by name."""
    methods = {m.name: m for m in cls.body
               if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
    closures = {n.name: n for n in ast.walk(cls)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                and n.name not in methods}
    out: List[ast.AST] = []
    for node in ast.walk(cls):
        if not isinstance(node, ast.Call):
            continue
        name = qualified(dotted_name(node.func), imports)
        if not (name == "threading.Thread" or name.endswith(".Thread")
                or name == "Thread"):
            continue
        for kw in node.keywords:
            if kw.arg != "target":
                continue
            d = dotted_name(kw.value) or ""
            leaf = d.rsplit(".", 1)[-1]
            fn = None
            if d.startswith("self."):
                fn = methods.get(leaf)
            else:
                fn = closures.get(leaf) or methods.get(leaf)
            if fn is not None:
                out.append(fn)
    return out


class ThreadSharedStateRule(Rule):
    id = "RPL005"
    name = "thread-shared-state"
    summary = ("attribute written by both the background thread and a "
               "public method without holding self._lock")

    def check(self, ctx) -> Iterator[Finding]:
        if "Thread" not in ctx.source:
            return
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            targets = _thread_targets(cls, ctx.imports)
            if not targets:
                continue
            target_writes: List[Tuple[str, ast.AST, bool]] = []
            for fn in targets:
                target_writes.extend(_collect_writes(fn))
            public_writes: List[Tuple[str, ast.AST, bool]] = []
            target_ids = {id(t) for t in targets}
            for m in cls.body:
                if not isinstance(m, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    continue
                if m.name.startswith("_") or id(m) in target_ids:
                    continue        # __init__ runs before the spawn
                public_writes.extend(_collect_writes(m))
            shared = ({a for a, _, _ in target_writes}
                      & {a for a, _, _ in public_writes})
            for attr, node, locked in target_writes + public_writes:
                if attr in shared and not locked:
                    yield self.finding(
                        ctx, node,
                        f"`self.{attr}` is written by both the "
                        f"background thread target and a public method "
                        f"of `{cls.name}` — this write does not hold "
                        f"the lock; wrap it in `with self._lock`")
