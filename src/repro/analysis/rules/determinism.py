"""RPL002 determinism: no unseeded module-level RNG in the serving core.

Greedy-decode token parity, seeded chaos replay, and the offline-log /
OPE pipeline are all bit-for-bit reproducibility contracts (tested as
such).  Module-level RNG (``random.random()``, ``np.random.rand()``)
draws from hidden global state that any import can perturb — one call
in a serving path silently breaks every parity test downstream.  The
repo idiom is an explicitly seeded generator object
(``np.random.default_rng(seed)`` / ``jax.random.PRNGKey(seed)``)
threaded through constructors.

Flagged: any call into the stdlib ``random`` module, any
``numpy.random.*`` legacy global function (``rand``/``randn``/
``seed``/``shuffle``/...), and ``numpy.random.default_rng()`` /
``numpy.random.Generator`` constructions *with no seed argument*.
Instance methods on a seeded generator (``rng.normal(...)``) are fine
— the receiver is a local name, not the module.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.base import Finding, Rule
from repro.analysis.walker import dotted_name, qualified

# numpy legacy global-state functions (module-level draws + seeding)
_NP_GLOBAL = {
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "normal", "uniform",
    "standard_normal", "beta", "binomial", "poisson", "exponential",
    "gamma", "seed", "get_state", "set_state", "bytes",
}


class DeterminismRule(Rule):
    id = "RPL002"
    name = "determinism"
    summary = ("unseeded module-level RNG (random.* / np.random.*) in a "
               "deterministic path")

    def check(self, ctx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = qualified(dotted_name(node.func), ctx.imports)
            if not name:
                continue
            if name.startswith("random."):
                yield self.finding(
                    ctx, node,
                    f"stdlib global RNG `{name}()` — use an explicitly "
                    f"seeded np.random.default_rng(seed) threaded "
                    f"through the constructor")
            elif name.startswith("numpy.random."):
                tail = name.split(".", 2)[2]
                if tail in _NP_GLOBAL:
                    yield self.finding(
                        ctx, node,
                        f"numpy global RNG `np.random.{tail}()` draws "
                        f"from hidden shared state — use a seeded "
                        f"np.random.default_rng(seed) instance")
                elif tail in ("default_rng", "Generator", "PCG64",
                              "SeedSequence") and not (node.args
                                                       or node.keywords):
                    yield self.finding(
                        ctx, node,
                        f"`np.random.{tail}()` without a seed is "
                        f"entropy-seeded — pass an explicit seed so "
                        f"runs replay bit-for-bit")
