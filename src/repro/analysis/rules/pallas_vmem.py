"""RPL004 pallas-vmem-budget: static VMEM footprint + masked-tail check.

A TPU core has ~16 MiB of VMEM and a ``pl.pallas_call`` must fit its
working set there: every in/out BlockSpec block is double-buffered by
the pipeline (fetch of step i+1 overlaps compute of step i), and
scratch shapes are resident for the whole grid.  A kernel that compiles
fine at test shapes can silently blow VMEM at production shapes, and
Mosaic's failure mode is an opaque allocation error at trace time — so
this rule recomputes the footprint *statically* from the AST:

    bytes = (sum(in blocks) + sum(out blocks)) * pipeline_buffers
            + sum(scratch shapes)

Block dims are evaluated against a symbol-binding table
(``options["bindings"]``, default: the production shapes in
``lintconfig.DEFAULT_DIM_BINDINGS``); an unbound symbol is itself a
finding — the estimator refuses to guess.  Dtypes come from literal
annotations (``jnp.float32`` on scratch / out_shape), from
``<operand>.dtype`` references resolved through the call's operand
list, or from ``options["operand_dtypes"]`` overrides (e.g. int8 KV).

``PrefetchScalarGridSpec(num_scalar_prefetch=N, ...)`` is understood:
the first N invocation operands are scalar-prefetch (SMEM) and carry no
VMEM blocks, so in_specs align with operands[N:].

The second sub-check is the **masked tail**: a grid axis that does not
divide the array needs either an in-kernel ``broadcasted_iota`` bounds
mask (followed transitively through local kernel helpers — the paged
decode kernel delegates to the dense one) or an explicit divisibility
``assert x % block == 0`` in the wrapper.  A pallas_call with neither
reads garbage out of the last partial tile.

The extraction/estimation helpers are import-stable API — the VMEM
tests drive them directly against hand-computed block-shape math.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.base import Finding, Rule
from repro.analysis.walker import dotted_name, qualified, root_name, walk_scope

DTYPE_BYTES: Dict[str, int] = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool": 1, "bool_": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1,
}


class UnboundDim(Exception):
    """A BlockSpec dimension references a symbol with no binding."""

    def __init__(self, symbol: str):
        super().__init__(symbol)
        self.symbol = symbol


@dataclass
class PallasSite:
    """One ``pl.pallas_call`` site, decomposed for estimation."""

    line: int
    col: int
    node: ast.Call
    kernel: Optional[str] = None          # kernel function name
    in_specs: List[ast.Call] = field(default_factory=list)
    out_specs: List[ast.Call] = field(default_factory=list)
    out_shapes: List[ast.Call] = field(default_factory=list)
    scratch_shapes: List[ast.Call] = field(default_factory=list)
    num_scalar_prefetch: int = 0
    operands: List[str] = field(default_factory=list)   # invocation args
    enclosing: Optional[ast.AST] = None   # wrapper function node


def _elements(node: Optional[ast.AST]) -> List[ast.AST]:
    if node is None:
        return []
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return [node]


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _kernel_name(arg: ast.AST) -> Optional[str]:
    """Kernel function name from pallas_call's first positional arg —
    unwraps the ``functools.partial(_kernel, ...)`` idiom."""
    if isinstance(arg, ast.Call):
        fn = dotted_name(arg.func) or ""
        if fn.endswith("partial") and arg.args:
            return dotted_name(arg.args[0])
        return None
    return dotted_name(arg)


def _fill_specs(site: PallasSite, call: ast.Call) -> None:
    """Read in/out specs + scratch off either the pallas_call kwargs or
    a ``grid_spec=pltpu.PrefetchScalarGridSpec(...)`` value."""
    spec_src: ast.Call = call
    grid_spec = _kw(call, "grid_spec")
    if isinstance(grid_spec, ast.Call):
        spec_src = grid_spec
        nsp = _kw(grid_spec, "num_scalar_prefetch")
        if isinstance(nsp, ast.Constant) and isinstance(nsp.value, int):
            site.num_scalar_prefetch = nsp.value
    site.in_specs = [e for e in _elements(_kw(spec_src, "in_specs"))
                     if isinstance(e, ast.Call)]
    site.out_specs = [e for e in _elements(_kw(spec_src, "out_specs"))
                      if isinstance(e, ast.Call)]
    site.scratch_shapes = [e for e in
                           _elements(_kw(spec_src, "scratch_shapes"))
                           if isinstance(e, ast.Call)]
    site.out_shapes = [e for e in _elements(_kw(call, "out_shape"))
                       if isinstance(e, ast.Call)]


def extract_sites(tree: ast.Module,
                  imports: Optional[Dict[str, str]] = None
                  ) -> List[PallasSite]:
    """Every pallas_call in the module, with invocation operands and the
    enclosing wrapper function resolved."""
    imports = imports or {}
    sites: Dict[int, PallasSite] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = qualified(dotted_name(node.func), imports)
        if not name.endswith("pallas_call"):
            continue
        site = PallasSite(line=node.lineno, col=node.col_offset, node=node)
        if node.args:
            site.kernel = _kernel_name(node.args[0])
        _fill_specs(site, node)
        sites[id(node)] = site
    for node in ast.walk(tree):
        # the invocation `pl.pallas_call(...)(q, k, v)` — a Call whose
        # func IS a pallas_call Call
        if isinstance(node, ast.Call) and id(node.func) in sites:
            sites[id(node.func)].operands = [
                root_name(a) or f"<arg{i}>"
                for i, a in enumerate(node.args)]
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for sub in walk_scope(fn):
            if id(sub) in sites and sites[id(sub)].enclosing is None:
                sites[id(sub)].enclosing = fn
    return sorted(sites.values(), key=lambda s: (s.line, s.col))


# ---------------------------------------------------------------------------
# dim / dtype evaluation
# ---------------------------------------------------------------------------


def eval_dim(node: ast.AST, bindings: Dict[str, int]) -> int:
    """Statically evaluate one BlockSpec dimension expression."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if isinstance(node, ast.Name):
        if node.id in bindings:
            return int(bindings[node.id])
        raise UnboundDim(node.id)
    if isinstance(node, ast.BinOp):
        left = eval_dim(node.left, bindings)
        right = eval_dim(node.right, bindings)
        if isinstance(node.op, ast.Mult):
            return left * right
        if isinstance(node.op, ast.FloorDiv):
            return left // right
        if isinstance(node.op, ast.Add):
            return left + right
        if isinstance(node.op, ast.Sub):
            return left - right
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return -eval_dim(node.operand, bindings)
    raise UnboundDim(ast.dump(node)[:40])


def _shape_elems(call: ast.Call, pos: int = 0) -> List[ast.AST]:
    """The shape tuple of a BlockSpec/VMEM/ShapeDtypeStruct call."""
    val = call.args[pos] if len(call.args) > pos else _kw(call, "shape")
    return _elements(val)


def dtype_bytes(expr: Optional[ast.AST],
                operand_dtypes: Dict[str, str],
                default_dtype: str) -> int:
    """Bytes/element for a dtype expression: a ``jnp.float32``-style
    literal, an ``x.dtype`` operand reference, or the default."""
    name = None
    if expr is not None:
        d = dotted_name(expr) or ""
        tail = d.rsplit(".", 1)[-1]
        if tail in DTYPE_BYTES:
            name = tail
        elif tail == "dtype":
            base = root_name(expr)
            name = operand_dtypes.get(base or "", default_dtype)
    if name is None:
        name = default_dtype
    return DTYPE_BYTES.get(name, 4)


def _block_bytes(spec: ast.Call, bindings: Dict[str, int],
                 nbytes: int) -> int:
    n = 1
    for dim in _shape_elems(spec):
        n *= eval_dim(dim, bindings)
    return n * nbytes


@dataclass
class VmemEstimate:
    total_bytes: int
    in_bytes: int
    out_bytes: int
    scratch_bytes: int
    pipeline_buffers: int


def estimate_site(site: PallasSite, *,
                  bindings: Dict[str, int],
                  operand_dtypes: Optional[Dict[str, str]] = None,
                  default_dtype: str = "float32",
                  pipeline_buffers: int = 2) -> VmemEstimate:
    """Static VMEM bytes for one site.  Raises :class:`UnboundDim` on a
    dimension symbol missing from ``bindings``."""
    odt = operand_dtypes or {}
    tiles = site.operands[site.num_scalar_prefetch:]
    in_b = 0
    for i, spec in enumerate(site.in_specs):
        op = tiles[i] if i < len(tiles) else ""
        nbytes = DTYPE_BYTES.get(odt.get(op, default_dtype), 4)
        in_b += _block_bytes(spec, bindings, nbytes)
    out_b = 0
    for i, spec in enumerate(site.out_specs):
        dt = None
        if i < len(site.out_shapes):
            sh = site.out_shapes[i]
            dt = (sh.args[1] if len(sh.args) > 1 else _kw(sh, "dtype"))
        out_b += _block_bytes(spec, bindings,
                              dtype_bytes(dt, odt, default_dtype))
    scr_b = 0
    for scr in site.scratch_shapes:
        dt = scr.args[1] if len(scr.args) > 1 else _kw(scr, "dtype")
        scr_b += _block_bytes(scr, bindings,
                              dtype_bytes(dt, odt, default_dtype))
    total = (in_b + out_b) * pipeline_buffers + scr_b
    return VmemEstimate(total_bytes=total, in_bytes=in_b, out_bytes=out_b,
                        scratch_bytes=scr_b,
                        pipeline_buffers=pipeline_buffers)


# ---------------------------------------------------------------------------
# masked-tail analysis
# ---------------------------------------------------------------------------


def _has_iota(fn: ast.AST, functions: Dict[str, ast.AST],
              seen: Set[str]) -> bool:
    """True if the kernel body (transitively through local helper
    calls) builds a ``broadcasted_iota`` position mask."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func) or ""
        if name.rsplit(".", 1)[-1] in ("broadcasted_iota", "iota"):
            return True
        callee = name.rsplit(".", 1)[-1]
        if callee in functions and callee not in seen:
            seen.add(callee)
            if _has_iota(functions[callee], functions, seen):
                return True
    return False


def _has_divisibility_assert(fn: Optional[ast.AST]) -> bool:
    if fn is None:
        return False
    for node in walk_scope(fn):
        if isinstance(node, ast.Assert):
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.BinOp) and isinstance(
                        sub.op, ast.Mod):
                    return True
    return False


# ---------------------------------------------------------------------------
# the rule
# ---------------------------------------------------------------------------


class PallasVmemRule(Rule):
    id = "RPL004"
    name = "pallas-vmem-budget"
    summary = ("pallas_call working set over the VMEM budget, unbound "
               "block dim, or unguarded non-divisible grid tail")

    def check(self, ctx) -> Iterator[Finding]:
        if "pallas_call" not in ctx.source:
            return
        budget = int(self.options.get("budget_bytes", 16 * 2 ** 20))
        bindings = dict(self.options.get("bindings", {}))
        for frag, extra in (self.options.get("per_file_bindings")
                            or {}).items():
            if frag in ctx.path:
                bindings.update(extra)
        odt = self.options.get("operand_dtypes", {})
        default_dtype = self.options.get("default_dtype", "float32")
        bufs = int(self.options.get("pipeline_buffers", 2))

        functions = {n.name: n for n in ast.walk(ctx.tree)
                     if isinstance(n, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))}
        for site in extract_sites(ctx.tree, ctx.imports):
            try:
                est = estimate_site(site, bindings=bindings,
                                    operand_dtypes=odt,
                                    default_dtype=default_dtype,
                                    pipeline_buffers=bufs)
            except UnboundDim as exc:
                yield self.finding(
                    ctx, site.node,
                    f"cannot bound VMEM for this pallas_call: block dim "
                    f"symbol `{exc.symbol}` has no binding — add it to "
                    f"the RPL004 `bindings` option (production shape)")
            else:
                if est.total_bytes > budget:
                    yield self.finding(
                        ctx, site.node,
                        f"estimated VMEM working set "
                        f"{est.total_bytes:,} B "
                        f"(in {est.in_bytes:,} + out {est.out_bytes:,} "
                        f"x{est.pipeline_buffers} buffers + scratch "
                        f"{est.scratch_bytes:,}) exceeds the "
                        f"{budget:,} B budget — shrink the block shapes "
                        f"or split the grid")
            kernel_fn = functions.get(site.kernel or "")
            if kernel_fn is not None and not _has_iota(
                    kernel_fn, functions, {site.kernel or ""}):
                if not _has_divisibility_assert(site.enclosing):
                    yield self.finding(
                        ctx, site.node,
                        f"kernel `{site.kernel}` has no broadcasted_iota "
                        f"bounds mask and its wrapper asserts no "
                        f"divisibility — a non-divisible grid axis "
                        f"would read a garbage partial tile; add the "
                        f"iota mask or `assert dim % block == 0`")
