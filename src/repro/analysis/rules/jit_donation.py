"""RPL003 jit-donation: donated buffers are dead; sharded jits declare
their output placement.

Two sub-checks over every ``jax.jit`` site:

1. **Use-after-donate.**  ``donate_argnums`` hands the argument's
   buffer to XLA — reading the Python reference afterwards hits a
   deleted array.  The rule registers every jitted callable built with
   ``donate_argnums`` (both ``fn = jax.jit(...)`` locals and
   ``self._fn = jax.jit(...)`` executor attributes, matched across
   methods of the same class), then flags any read of a donated
   argument's name after the call site in the same function scope,
   unless the name was reassigned in between.  The repo idiom —
   rebinding at the call site,
   ``(self._cache, ...) = self._decode(self.params, self._cache, ...)``
   — clears the taint by construction.  Line-order analysis: a
   *loop-carried* read is only safe when the donating call rebinds the
   name, which is the only loop pattern in the tree.

2. **out_shardings under a mesh.**  A jitted program compiled in a
   class that owns a ``self.mesh`` must pin ``out_shardings``: without
   it GSPMD is free to choose output layouts, and a donated slot-cache
   buffer that comes back with a different sharding forces a silent
   full-buffer reshard every decode chunk (the PR 3/4 executors pin
   all five programs).  Scoped by the ``out_shardings_include`` paths
   — the dry-run harness jits ShapeDtypeStruct spec stand-ins where
   shardings ride the arguments instead.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.base import Finding, Rule
from repro.analysis.walker import (assigned_names, dotted_name, qualified,
                                   walk_scope)


def _jit_call(node: ast.AST, imports) -> Optional[ast.Call]:
    """The ``jax.jit(...)`` Call if ``node`` is one."""
    if (isinstance(node, ast.Call)
            and qualified(dotted_name(node.func), imports) == "jax.jit"):
        return node
    return None


def _donated_positions(call: ast.Call) -> Tuple[int, ...]:
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            return tuple(e.value for e in v.elts
                         if isinstance(e, ast.Constant)
                         and isinstance(e.value, int))
    return ()


def _has_kw(call: ast.Call, name: str) -> bool:
    return any(kw.arg == name for kw in call.keywords)


def _stmt_targets(stmt: ast.stmt) -> List[str]:
    if isinstance(stmt, ast.Assign):
        out: List[str] = []
        for t in stmt.targets:
            out.extend(assigned_names(t))
        return out
    if isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        return assigned_names(stmt.target)
    return []


class _Scope:
    """Ordered loads / stores / donating-call taints of one scope."""

    def __init__(self, fn: ast.AST, registry: Dict[str, Tuple[int, ...]],
                 imports: Dict[str, str]):
        self.loads: List[Tuple[str, int, ast.AST]] = []
        self.stores: List[Tuple[str, int]] = []
        # (donated dotted name, donating statement end line, call node)
        self.taints: List[Tuple[str, int, ast.Call]] = []
        self._registry = registry
        self._imports = imports
        for stmt in getattr(fn, "body", []):
            self._visit(stmt, stmt)

    def _visit(self, node: ast.AST, stmt: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda, ast.ClassDef)):
            return      # nested scope
        if isinstance(node, ast.stmt):
            stmt = node
            end = getattr(node, "end_lineno", node.lineno)
            self.stores.extend((n, end) for n in _stmt_targets(node))
            if isinstance(node, ast.For):
                self.stores.extend(
                    (n, node.lineno) for n in assigned_names(node.target))
            elif isinstance(node, ast.With):
                for item in node.items:
                    if item.optional_vars is not None:
                        self.stores.extend(
                            (n, node.lineno)
                            for n in assigned_names(item.optional_vars))
        if isinstance(node, ast.NamedExpr):
            self.stores.extend(
                (n, node.lineno) for n in assigned_names(node.target))
        if isinstance(node, (ast.Name, ast.Attribute)) and isinstance(
                getattr(node, "ctx", None), ast.Load):
            d = dotted_name(node)
            if d:
                # record the full chain only; taint matching treats a
                # read of `x.y` as a read of donated `x`
                self.loads.append((d, node.lineno, node))
                return
        if isinstance(node, ast.Call):
            self._maybe_taint(node, stmt)
        for child in ast.iter_child_nodes(node):
            self._visit(child, stmt)

    def _maybe_taint(self, call: ast.Call, stmt: ast.stmt) -> None:
        pos = self._registry.get(dotted_name(call.func) or "")
        direct = _jit_call(call.func, self._imports)
        if direct is not None:
            pos = _donated_positions(direct)
        if not pos:
            return
        end = getattr(stmt, "end_lineno", stmt.lineno)
        rebound = _stmt_targets(stmt)
        for p in pos:
            if p >= len(call.args):
                continue
            d = dotted_name(call.args[p])
            if d and d not in rebound:
                self.taints.append((d, end, call))


def _matches(load: str, donated: str) -> bool:
    return load == donated or load.startswith(donated + ".")


class JitDonationRule(Rule):
    id = "RPL003"
    name = "jit-donation"
    summary = ("donated jit argument read after the call / mesh-scoped "
               "jit missing out_shardings")

    def check(self, ctx) -> Iterator[Finding]:
        if "jax.jit" not in ctx.source:
            return
        yield from self._use_after_donate(ctx)
        inc = self.options.get("out_shardings_include", [])
        if not inc or any(f in ctx.path for f in inc):
            yield from self._out_shardings(ctx)

    # -- sub-check 1: use-after-donate ---------------------------------

    def _use_after_donate(self, ctx) -> Iterator[Finding]:
        # class-level registry: `self._fn = jax.jit(..., donate...)`
        # anywhere in a class taints `self._fn(...)` call sites in
        # every method of that class
        scopes: List[Tuple[ast.AST, Dict[str, Tuple[int, ...]]]] = []
        claimed = set()
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            reg: Dict[str, Tuple[int, ...]] = {}
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assign):
                    jc = _jit_call(sub.value, ctx.imports)
                    if jc is None:
                        continue
                    pos = _donated_positions(jc)
                    if not pos:
                        continue
                    for t in sub.targets:
                        d = dotted_name(t)
                        if d:
                            reg[d] = pos
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    scopes.append((sub, reg))
                    claimed.add(id(sub))
        for node in ast.walk(ctx.tree):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and id(node) not in claimed):
                scopes.append((node, {}))
        scopes.append((ctx.tree, {}))

        for fn, class_reg in scopes:
            registry = dict(class_reg)
            for sub in walk_scope(fn):
                if isinstance(sub, ast.Assign):
                    jc = _jit_call(sub.value, ctx.imports)
                    if jc is not None:
                        pos = _donated_positions(jc)
                        if pos:
                            for t in sub.targets:
                                d = dotted_name(t)
                                if d:
                                    registry[d] = pos
            scope = _Scope(fn, registry, ctx.imports)
            for name, tline, call in scope.taints:
                offender = None
                for lname, lline, lnode in scope.loads:
                    if not _matches(lname, name) or lline <= tline:
                        continue
                    if any(sname == name and tline < sline < lline
                           for sname, sline in scope.stores):
                        continue
                    if offender is None or lline < offender[0]:
                        offender = (lline, lnode)
                if offender is not None:
                    yield self.finding(
                        ctx, offender[1],
                        f"`{name}` was donated to the jitted call at "
                        f"line {call.lineno} (donate_argnums) — its "
                        f"buffer is gone; rebind the name from the "
                        f"call's results or drop the donation")

    # -- sub-check 2: out_shardings under a mesh ------------------------

    def _out_shardings(self, ctx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            owns_mesh = any(
                isinstance(sub, ast.Assign) and any(
                    dotted_name(t) == "self.mesh" for t in sub.targets)
                for sub in ast.walk(node))
            if not owns_mesh:
                continue
            for sub in ast.walk(node):
                jc = _jit_call(sub, ctx.imports) if isinstance(
                    sub, ast.Call) else None
                if jc is not None and not _has_kw(jc, "out_shardings"):
                    yield self.finding(
                        ctx, jc,
                        f"jax.jit in class `{node.name}` (owns "
                        f"self.mesh) without out_shardings — GSPMD "
                        f"picks output layouts freely and donated "
                        f"buffers can come back resharded; pin the "
                        f"NamedSharding like the executors do")
