"""RPL006 exception-hygiene: no silently swallowed broad catches.

The fault-tolerance plane (PR 7) is built on exceptions carrying
semantic weight: ``core.errors`` defines the taxonomy
(``TransientFaultError`` retries, ``FaultTimeoutError`` charges the
deadline, ``CircuitOpenError`` sheds), the gateway's retry/quarantine
logic dispatches on it, and every SLO metric downstream of a swallowed
exception silently under-counts failures.  A bare ``except Exception:
pass``-shaped handler in serving/retrieval turns a failing dependency
into invisible wrong answers — the worst failure mode a measurement
paper's codebase can have.

A broad handler (``except Exception``/``BaseException``/bare
``except:``) is compliant when its body does at least one of:

* **re-raise** — ``raise`` / ``raise X from exc`` (mapping into the
  ``core.errors`` taxonomy is a raise, so it's covered);
* **count it** — an ``AugAssign`` onto an attribute (the
  ``self.stats.<counter> += 1`` idiom) so dashboards see the loss;
* **record it** — a call whose name starts with ``record`` or routes
  through a ``.stats``/``.metrics`` object.

Narrow catches (``except KeyError``) are out of scope — catching a
specific exception is a statement of intent.  Intentional swallows
(e.g. best-effort cleanup on shutdown) get an inline
``# repro: allow[RPL006] <why>``.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.base import Finding, Rule
from repro.analysis.walker import dotted_name

_BROAD = {"Exception", "BaseException"}


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True                       # bare `except:`
    if isinstance(t, ast.Tuple):
        return any((dotted_name(e) or "").rsplit(".", 1)[-1] in _BROAD
                   for e in t.elts)
    return (dotted_name(t) or "").rsplit(".", 1)[-1] in _BROAD


def _is_compliant(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.AugAssign) and isinstance(
                node.target, ast.Attribute):
            return True
        if isinstance(node, ast.Call):
            d = dotted_name(node.func) or ""
            leaf = d.rsplit(".", 1)[-1]
            if leaf.startswith("record") or ".stats." in d \
                    or ".metrics." in d:
                return True
    return False


class ExceptionHygieneRule(Rule):
    id = "RPL006"
    name = "exception-hygiene"
    summary = ("broad `except Exception` that neither re-raises, maps "
               "into core.errors, nor increments a stats counter")

    def check(self, ctx) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _is_broad(node) or _is_compliant(node):
                continue
            yield self.finding(
                ctx, node,
                "broad exception handler swallows the failure — "
                "re-raise, map it into the core.errors taxonomy, or "
                "increment a stats counter so SLO accounting sees it")
