"""Three-term roofline analysis per (arch × input-shape × mesh).

Terms (seconds, per training/serving step, whole mesh):

    compute    = FLOPs / (chips × 197e12 bf16 FLOP/s)
    memory     = HBM bytes / (chips × 819e9 B/s)
    collective = collective bytes per chip / 50e9 B/s (ICI)

Sources: ``compiled.cost_analysis()`` + HLO collective census from the
dry-run, CORRECTED for XLA's while-body-counted-once convention (we
measured: both the microbatch scan and the layer scan bodies are counted
once — see EXPERIMENTS.md §Roofline methodology), cross-checked against
closed-form workload models below.  MODEL_FLOPS = 6·N(active)·D is the
"useful work" yardstick; its ratio to compiled FLOPs exposes remat /
redundancy overhead.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional

from repro.configs import INPUT_SHAPES, get_config
from repro.core.config import ModelConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.transformer import layer_structure

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# ---------------------------------------------------------------------------
# Closed-form workload models (per global step, whole job)
# ---------------------------------------------------------------------------


def _attn_layer_flops(cfg: ModelConfig, Tq: int, Skv: int,
                      causal_frac: float) -> float:
    """One attention layer, one sequence: projections + scores + AV."""
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.attn_type == "mla":
        m = cfg.mla
        qd = m.qk_nope_head_dim + m.qk_rope_head_dim
        q_in = m.q_lora_rank or d
        proj = 2 * Tq * (d * (m.q_lora_rank or 0) + q_in * H * qd
                         + d * (m.kv_lora_rank + m.qk_rope_head_dim))
        # latent -> per-head k/v expansion over the whole kv span
        proj += 2 * Skv * m.kv_lora_rank * H * (m.qk_nope_head_dim
                                                + m.v_head_dim)
        proj += 2 * Tq * H * m.v_head_dim * d
        att = 2 * Tq * Skv * H * (qd + m.v_head_dim) * causal_frac
        return proj + att
    proj = 2 * Tq * d * (2 * H * Dh + 2 * Hkv * Dh)
    att = 2 * Tq * Skv * H * Dh * 2 * causal_frac
    return proj + att


def _ssm_layer_flops(cfg: ModelConfig, T: int) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    H = d_inner // s.head_dim
    gn = s.n_groups * s.d_state
    proj = 2 * T * d * (2 * d_inner + 2 * gn + H) + 2 * T * d_inner * d
    c = s.chunk_size
    # intra-chunk: CB^T (c·N) + masked matmul (c·hd); inter: state ops
    ssd = 2 * T * H * (c * s.d_state + c * s.head_dim
                       + 2 * s.d_state * s.head_dim)
    return proj + ssd


def _moe_layer_flops(cfg: ModelConfig, T: int) -> float:
    e = cfg.moe
    active = e.top_k + e.n_shared_experts
    return 2 * T * cfg.d_model * 3 * e.d_ff_expert * active \
        + 2 * T * cfg.d_model * e.n_experts  # router


def _mlp_layer_flops(cfg: ModelConfig, T: int) -> float:
    return 2 * T * cfg.d_model * 3 * cfg.d_ff


def forward_flops(cfg: ModelConfig, B: int, Tq: int, Skv: int,
                  causal_frac: float = 0.5) -> float:
    """Whole-model forward FLOPs for B sequences."""
    prefix, block, n_blocks = layer_structure(cfg)
    sigs = prefix + block * n_blocks
    total = 0.0
    for s in sigs:
        if s.kind == "M":
            total += _ssm_layer_flops(cfg, Tq)
        else:
            skv_eff = min(Skv, s.window) if s.window else Skv
            cf = causal_frac if (Tq == Skv and not s.window) else 1.0
            total += _attn_layer_flops(cfg, Tq, skv_eff, cf)
            if s.cross:
                total += _attn_layer_flops(cfg, Tq, cfg.encoder_seq_len, 1.0)
        if s.is_moe:
            total += _moe_layer_flops(cfg, Tq)
        elif s.kind == "A" or cfg.d_ff:
            total += _mlp_layer_flops(cfg, Tq)
    if cfg.is_encoder_decoder:
        enc = cfg.encoder_seq_len
        total += cfg.n_encoder_layers * (_attn_layer_flops(cfg, enc, enc, 1.0)
                                         + _mlp_layer_flops(cfg, enc))
    total += 2 * Tq * cfg.d_model * cfg.padded_vocab      # unembed
    return total * B


def model_flops(cfg: ModelConfig, shape_name: str) -> Dict[str, float]:
    """MODEL_FLOPS (6·N·D convention) and the full analytic estimate."""
    sh = INPUT_SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    n_act = cfg.n_active_params()
    if kind == "train":
        toks = B * S
        simple = 6.0 * n_act * toks
        full = 3.0 * forward_flops(cfg, B, S, S)   # fwd + ~2x bwd
        if cfg.remat != "none":
            full += forward_flops(cfg, B, S, S)    # recompute pass
    elif kind == "prefill":
        toks = B * S
        simple = 2.0 * n_act * toks
        full = forward_flops(cfg, B, S, S)
    else:  # decode: one token against an S-long cache
        toks = B
        simple = 2.0 * n_act * toks
        full = forward_flops(cfg, B, 1, S, causal_frac=1.0)
    return {"model_flops": simple, "analytic_flops": full, "tokens": toks}


def hbm_bytes(cfg: ModelConfig, shape_name: str, n_chips: int,
              microbatches: int = 1) -> float:
    """Whole-job HBM traffic model per step (docs: §Roofline methodology)."""
    sh = INPUT_SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    P = cfg.n_params()
    d = cfg.d_model
    L = cfg.n_layers
    if kind == "train":
        # weights fwd+bwd (+recompute) + fp32 grads + adam m/v rw + params rw
        w = P * 2 * (3 if cfg.remat != "none" else 2) * microbatches
        opt = P * 4 * 5
        act = B * S * d * L * 2 * 6     # residual stream traffic, both passes
        return w + opt + act
    if kind == "prefill":
        return P * 2 + B * S * d * L * 2 * 3
    # decode: full weights + full KV cache read per token
    cache = _cache_bytes(cfg, B, S)
    return P * 2 + cache + B * d * L * 2 * 4


def _cache_bytes(cfg: ModelConfig, B: int, S: int) -> float:
    prefix, block, n_blocks = layer_structure(cfg)
    sigs = prefix + block * n_blocks
    total = 0.0
    for s in sigs:
        if s.kind == "M":
            ss = cfg.ssm
            d_inner = ss.expand * cfg.d_model
            H = d_inner // ss.head_dim
            total += B * H * ss.head_dim * ss.d_state * 4
        elif cfg.attn_type == "mla":
            total += B * S * (cfg.mla.kv_lora_rank
                              + cfg.mla.qk_rope_head_dim) * 2
        else:
            span = min(S, s.window) if s.window else S
            total += B * span * cfg.n_kv_heads * cfg.head_dim * 2 * 2
    return total


def collective_bytes_model(cfg: ModelConfig, shape_name: str,
                           mesh: Dict[str, int],
                           microbatches: int = 1) -> float:
    """Per-chip collective traffic model (FSDP AG + TP AR + MoE a2a)."""
    sh = INPUT_SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    data = mesh.get("data", 1) * mesh.get("pod", 1)
    model = mesh.get("model", 1)
    P = cfg.n_params()
    p_shard = P * 2 / model                       # bytes after TP shard
    T_loc = B * (S if kind != "decode" else 1) / data
    d = cfg.d_model
    L = cfg.n_layers

    fsdp = 0.0
    if kind == "train":
        # all-gather weights fwd+bwd per microbatch + reduce-scatter grads
        fsdp = p_shard * (1 - 1 / data) * (2 * microbatches + 2)
    elif data > 1:
        fsdp = p_shard * (1 - 1 / data)           # weights gathered once
    # TP all-reduce of activations: ~2 per layer, ring factor ~2
    tp = 2 * L * T_loc * d * 2 * 2 * (1 - 1 / model) * \
        (3 if kind == "train" else 1)
    a2a = 0.0
    if cfg.moe is not None:
        n_moe = sum(cfg.is_moe_layer(i) for i in range(L))
        trips = 2 * (2 if kind == "train" else 1)
        a2a = n_moe * trips * T_loc * cfg.moe.top_k * d * 2 / data
    return fsdp + tp + a2a


# ---------------------------------------------------------------------------
# Report assembly
# ---------------------------------------------------------------------------


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_scaled: float
    useful_ratio: float
    note: str

    def as_dict(self):
        return {k: (round(v, 6) if isinstance(v, float) else v)
                for k, v in self.__dict__.items()}


_NOTES = {
    "compute": "compute-bound: raise MXU utilization (larger tiles, fuse "
               "pointwise, reduce remat recompute)",
    "memory": "memory-bound: cut HBM traffic (KV-cache sharding/window "
              "ring-buffer, fused CE, fp8/bf16 cache)",
    "collective": "collective-bound: reshard to cut all-gathers (head "
                  "padding instead of head_dim TP, overlap FSDP gathers, "
                  "bigger microbatches)",
}


def analyse(arch: str, shape: str, mesh_kind: str = "single",
            record: Optional[dict] = None, tag: str = "") -> RooflineRow:
    cfg = get_config(arch, "full")
    if record is None:
        p = DRYRUN_DIR / f"{arch}__{shape}__{mesh_kind}{tag}.json"
        record = json.loads(p.read_text())
    chips = record["n_devices"]
    mesh = {"data": 16, "model": 16}
    if mesh_kind == "multi":
        mesh["pod"] = 2
    n_mb = record.get("info", {}).get("microbatches", 1)

    mf = model_flops(cfg, shape)
    comp_s = mf["analytic_flops"] / (chips * PEAK_FLOPS_BF16)
    mem_s = hbm_bytes(cfg, shape, chips, n_mb) / (chips * HBM_BW)
    coll_per_chip = collective_bytes_model(cfg, shape, mesh, n_mb)
    coll_s = coll_per_chip / ICI_BW

    prefix, block, n_blocks = layer_structure(cfg)
    scale = n_mb * n_blocks
    hlo_scaled = record.get("flops", 0.0) * scale * chips
    useful = mf["model_flops"] / hlo_scaled if hlo_scaled > 0 else 0.0

    terms = {"compute": comp_s, "memory": mem_s, "collective": coll_s}
    dom = max(terms, key=terms.get)
    return RooflineRow(
        arch=arch, shape=shape, mesh=mesh_kind, chips=chips,
        compute_s=comp_s, memory_s=mem_s, collective_s=coll_s,
        dominant=dom, model_flops=mf["model_flops"],
        hlo_flops_scaled=hlo_scaled, useful_ratio=useful,
        note=_NOTES[dom])


def full_table(mesh_kind: str = "single"):
    from repro.configs import ARCH_IDS, shape_supported
    rows = []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            if not shape_supported(arch, shape):
                continue
            p = DRYRUN_DIR / f"{arch}__{shape}__{mesh_kind}.json"
            if not p.exists():
                continue
            rows.append(analyse(arch, shape, mesh_kind))
    return rows
