"""reprolint configuration: rule enablement, path scopes, options.

Path scoping is substring-based over the posix display path: a rule
with ``include=("repro/serving",)`` only runs on files whose path
contains that fragment, and ``exclude`` wins over ``include``.  That is
the per-module allowlist mechanism — e.g. the determinism rule only
polices core/serving/retrieval/routing (a notebook-style launch script
may legitimately use ad-hoc RNG), and the ``out_shardings`` check only
polices the serving executors (the dry-run harness jits against
ShapeDtypeStruct spec stand-ins where shardings ride the arguments).

``DEFAULT_CONFIG`` is the repo contract checked by CI.  A JSON file
passed via ``--config`` overlays it::

    {"rules": {"RPL004": {"options": {"budget_bytes": 33554432},
               "exclude": ["repro/kernels/experimental"]}}}
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Tuple

VMEM_BUDGET_BYTES = 16 * 2 ** 20   # ~16 MiB VMEM per TPU core

#: dim-symbol bindings the VMEM estimator assumes when a BlockSpec
#: dimension is a bare name: the production-shape values each kernel is
#: deployed with (gemma3-12b head_dim 256 bounds D/Dv; block sizes as
#: written at the call sites).  Tests override these per variant.
DEFAULT_DIM_BINDINGS: Dict[str, int] = {
    # attention / decode
    "D": 256, "Dv": 256, "block_q": 128, "block_kv": 128,
    # paged decode: largest shipping page size
    "ps": 64,
    # dense retrieval: 128-aligned hashed-n-gram embedding, k<=64
    "E": 128, "block_d": 128, "k": 64,
    # bm25 hashed vocab tile
    "block_v": 512,
    # mamba2 ssd chunk scan
    "chunk": 128, "hd": 128, "N": 256,
}


@dataclass
class RuleConfig:
    enabled: bool = True
    include: Tuple[str, ...] = ()     # empty = everywhere
    exclude: Tuple[str, ...] = ()
    options: Dict[str, Any] = field(default_factory=dict)

    def applies_to(self, path: str) -> bool:
        if any(frag in path for frag in self.exclude):
            return False
        if self.include and not any(f in path for f in self.include):
            return False
        return True


@dataclass
class LintConfig:
    rules: Dict[str, RuleConfig] = field(default_factory=dict)

    def rule(self, rule_id: str) -> RuleConfig:
        return self.rules.setdefault(rule_id, RuleConfig())

    def overlay(self, data: Dict[str, Any]) -> "LintConfig":
        """Merge a ``--config`` JSON dict (shallow per rule)."""
        for rid, spec in (data.get("rules") or {}).items():
            rc = self.rule(rid)
            if "enabled" in spec:
                rc.enabled = bool(spec["enabled"])
            if "include" in spec:
                rc.include = tuple(spec["include"])
            if "exclude" in spec:
                rc.exclude = tuple(spec["exclude"])
            rc.options.update(spec.get("options") or {})
        return self

    @classmethod
    def from_file(cls, path: str) -> "LintConfig":
        return make_default_config().overlay(
            json.loads(Path(path).read_text()))


def make_default_config() -> LintConfig:
    return LintConfig(rules={
        # wall-clock discipline: everywhere (the serving plane is
        # virtual-time-replayable end to end; launch scripts time with
        # perf_counter like the Gateway does)
        "RPL001": RuleConfig(),
        # unseeded RNG only polices the deterministic serving core —
        # bit-for-bit replay is a tested invariant there
        "RPL002": RuleConfig(include=(
            "repro/core", "repro/serving", "repro/retrieval",
            "repro/routing", "repro/data", "repro/kernels")),
        "RPL003": RuleConfig(options={
            # the out_shardings sub-check polices the serving
            # executors; the dry-run harness jits spec stand-ins where
            # shardings ride the ShapeDtypeStruct arguments instead
            "out_shardings_include": ["repro/serving"],
        }),
        "RPL004": RuleConfig(
            include=("repro/kernels",),
            options={
                "budget_bytes": VMEM_BUDGET_BYTES,
                "bindings": dict(DEFAULT_DIM_BINDINGS),
                # per-file overrides keyed by path fragment
                "per_file_bindings": {},
                # in/out blocks are double-buffered by the pipeline
                "pipeline_buffers": 2,
                "default_dtype": "float32",
                "operand_dtypes": {},
            }),
        "RPL005": RuleConfig(),
        # exception hygiene polices the paths where a swallowed
        # exception silently erodes SLO accounting
        "RPL006": RuleConfig(include=(
            "repro/serving", "repro/retrieval", "repro/routing")),
        # metric hygiene: names, single registration, injected clocks
        # (everywhere — bench/launch scripts bind metrics too)
        "RPL007": RuleConfig(),
    })


DEFAULT_CONFIG = make_default_config()
