"""reprolint core types: findings, the rule protocol, the registry.

A rule is a class with a unique ``id`` (``RPLnnn``), a one-line
``summary`` (what invariant it enforces), and a ``check(ctx)`` method
yielding :class:`Finding` objects for one parsed module.  Rules are
stdlib-only (ast + tokenize) so the linter runs without the repo's
runtime dependencies installed.

Registration is import-time: defining a subclass of :class:`Rule` with
an ``id`` adds it to the registry (``all_rules()``).  The rule modules
in :mod:`repro.analysis.rules` are imported by the walker, so user code
only needs :func:`repro.analysis.run_lint`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Type

#: severity is informational only — every unsuppressed finding fails a
#: ``--fail-on-findings`` run; the tiers just order human output.
SEVERITIES = ("error", "warning")


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str                 # "RPL001"
    path: str                 # posix path as scanned (e.g. src/repro/...)
    line: int                 # 1-based
    col: int                  # 0-based
    message: str
    severity: str = "error"
    suppressed: bool = False
    suppress_reason: str = ""

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_dict(self) -> Dict:
        return {
            "rule": self.rule, "path": self.path, "line": self.line,
            "col": self.col, "message": self.message,
            "severity": self.severity, "suppressed": self.suppressed,
            "suppress_reason": self.suppress_reason,
        }


@dataclass
class LintResult:
    """Everything one lint run produced."""

    findings: List[Finding] = field(default_factory=list)   # active
    suppressed: List[Finding] = field(default_factory=list)
    n_files: int = 0

    @property
    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    def to_dict(self) -> Dict:
        return {
            "n_files": self.n_files,
            "counts": self.counts,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
        }


_REGISTRY: Dict[str, Type["Rule"]] = {}


class Rule:
    """Base class; subclasses self-register by ``id``.

    ``check`` receives a :class:`~repro.analysis.walker.ModuleContext`
    and yields findings for that module only — rules never hold state
    across files, which is what lets the walker scan files in any
    order.  ``options`` come from the rule's
    :class:`~repro.analysis.lintconfig.RuleConfig` (budget bytes, dim
    bindings, path scopes live in the config, not the rule).
    """

    id: str = ""
    name: str = ""
    summary: str = ""

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        if cls.id:
            if cls.id in _REGISTRY and _REGISTRY[cls.id] is not cls:
                raise ValueError(f"duplicate rule id {cls.id!r}")
            _REGISTRY[cls.id] = cls

    def __init__(self, options: Optional[Dict] = None):
        self.options = dict(options or {})

    def check(self, ctx) -> Iterator[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx, node, message: str, *,
                severity: str = "error") -> Finding:
        return Finding(rule=self.id, path=ctx.path,
                       line=getattr(node, "lineno", 0),
                       col=getattr(node, "col_offset", 0),
                       message=message, severity=severity)


def all_rules() -> Dict[str, Type[Rule]]:
    """id -> rule class, importing the bundled rule modules first."""
    import repro.analysis.rules  # noqa: F401  (registration side effect)
    return dict(sorted(_REGISTRY.items()))
