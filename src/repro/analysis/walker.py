"""reprolint walker: file discovery, parsing, suppressions, shared AST
helpers, and the run loop.

Suppressions
------------

A finding is silenced by an inline comment::

    x = time.time()  # repro: allow[RPL001] bench labels are wall-clock

or by a comment-only line immediately above the offending line::

    # repro: allow[RPL001] real-time pacing is the point of this loop
    time.sleep(lag)

The rule id list is comma-separable (``allow[RPL001,RPL006]``) and the
reason is REQUIRED: a bare ``allow[...]`` with no justification does
not suppress anything (and is itself reported), so every suppression in
the tree documents *why* the invariant doesn't apply.  Suppressed
findings stay in the report (``suppressed`` block of the JSON output)
— they are auditable, not invisible.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.base import Finding, LintResult, all_rules
from repro.analysis.lintconfig import DEFAULT_CONFIG, LintConfig

_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow\[([A-Za-z0-9_,\s-]+)\]\s*(.*)")


# ---------------------------------------------------------------------------
# Shared AST helpers (used by every rule)
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def root_name(node: ast.AST) -> Optional[str]:
    """The leftmost Name of any attribute/subscript/call chain:
    ``table.astype(jnp.int32)`` -> ``table``."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def assigned_names(target: ast.AST) -> List[str]:
    """Dotted names written by one assignment target (tuples/lists/
    starred unpacked; subscript writes count as writes to the base)."""
    out: List[str] = []
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            out.extend(assigned_names(elt))
    elif isinstance(target, ast.Starred):
        out.extend(assigned_names(target.value))
    elif isinstance(target, ast.Subscript):
        d = dotted_name(target.value)
        if d:
            out.append(d)
    else:
        d = dotted_name(target)
        if d:
            out.append(d)
    return out


def walk_scope(fn: ast.AST):
    """Yield every node in one function/module scope WITHOUT descending
    into nested function / class definitions (those are their own
    scopes).  The nested def/class node itself is yielded."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


def import_table(tree: ast.Module) -> Dict[str, str]:
    """Local name -> fully qualified name for module-level imports
    (``import numpy as np`` -> {"np": "numpy"}; ``from time import
    sleep`` -> {"sleep": "time.sleep"})."""
    table: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                table[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                if a.name == "*":
                    continue
                table[a.asname or a.name] = f"{node.module}.{a.name}"
    return table


def qualified(name: Optional[str], imports: Dict[str, str]) -> str:
    """Rewrite the chain root through the import table:
    ``np.random.rand`` -> ``numpy.random.rand``."""
    if not name:
        return ""
    head, _, rest = name.partition(".")
    head = imports.get(head, head)
    return f"{head}.{rest}" if rest else head


# ---------------------------------------------------------------------------
# Module context
# ---------------------------------------------------------------------------


@dataclass
class ModuleContext:
    """One parsed source file handed to every applicable rule."""

    path: str                      # posix path as scanned
    source: str
    tree: ast.Module
    # line -> {rule_id -> reason} for valid (justified) suppressions
    suppressions: Dict[int, Dict[str, str]] = field(default_factory=dict)
    # lines carrying an allow[] comment with NO reason (reported)
    bare_allows: List[Tuple[int, str]] = field(default_factory=list)
    imports: Dict[str, str] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: Path, display_path: str) -> "ModuleContext":
        source = path.read_text()
        tree = ast.parse(source, filename=display_path)
        ctx = cls(path=display_path, source=source, tree=tree)
        ctx.imports = import_table(tree)
        ctx._scan_comments()
        return ctx

    def _scan_comments(self) -> None:
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(self.source).readline))
        except tokenize.TokenError:
            return
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _ALLOW_RE.search(tok.string)
            if not m:
                continue
            ids = [s.strip() for s in m.group(1).split(",") if s.strip()]
            reason = m.group(2).strip()
            line = tok.start[0]
            if not reason:
                self.bare_allows.append((line, ",".join(ids)))
                continue
            # a comment-only line suppresses the NEXT line; an inline
            # comment suppresses its own line.  Registering both is
            # safe: a comment-only line has no code to flag.
            code = self.source.splitlines()[line - 1][:tok.start[1]]
            targets = (line + 1,) if not code.strip() else (line,)
            for ln in targets:
                slot = self.suppressions.setdefault(ln, {})
                for rid in ids:
                    slot[rid] = reason

    def suppression_for(self, rule_id: str, line: int) -> Optional[str]:
        return self.suppressions.get(line, {}).get(rule_id)


# ---------------------------------------------------------------------------
# Run loop
# ---------------------------------------------------------------------------


def discover(paths: Sequence[str]) -> List[Tuple[Path, str]]:
    """Expand files/dirs into (filesystem path, display path) pairs."""
    out: List[Tuple[Path, str]] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" in f.parts:
                    continue
                out.append((f, f.as_posix()))
        elif p.suffix == ".py":
            out.append((p, p.as_posix()))
    return out


def run_lint(paths: Sequence[str],
             config: Optional[LintConfig] = None) -> LintResult:
    """Lint every ``.py`` under ``paths``; returns the full result with
    suppressed findings separated out (exit-code policy is the CLI's)."""
    cfg = config or DEFAULT_CONFIG
    result = LintResult()
    rules = []
    for rid, cls in all_rules().items():
        rc = cfg.rule(rid)
        if rc.enabled:
            rules.append((cls(rc.options), rc))
    for fs_path, display in discover(paths):
        result.n_files += 1
        try:
            ctx = ModuleContext.parse(fs_path, display)
        except SyntaxError as exc:
            result.findings.append(Finding(
                rule="RPLERR", path=display, line=exc.lineno or 0,
                col=exc.offset or 0,
                message=f"syntax error: {exc.msg}"))
            continue
        for line, ids in ctx.bare_allows:
            result.findings.append(Finding(
                rule="RPLERR", path=display, line=line, col=0,
                message=f"suppression allow[{ids}] has no reason — "
                        f"every allow must carry a justification"))
        for rule, rc in rules:
            if not rc.applies_to(display):
                continue
            for f in rule.check(ctx):
                reason = ctx.suppression_for(f.rule, f.line)
                if reason is not None:
                    result.suppressed.append(Finding(
                        **{**f.to_dict(), "suppressed": True,
                           "suppress_reason": reason}))
                else:
                    result.findings.append(f)
    result.findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    result.suppressed.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return result
