"""Training driver.

Local mode (default) trains a reduced variant of ``--arch`` on CPU for a
few hundred steps — the end-to-end example path.  ``--production`` lowers
against the 16x16 (or 2x16x16) production mesh instead (dry-run only on
CPU containers).

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data.lm_dataset import LMDataset
from repro.models.registry import build_model
from repro.models.schema import init_from_schema
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import OptConfig, adamw_init_schema
from repro.training.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--variant", default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, args.variant)
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                        total_steps=args.steps)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_state = init_from_schema(key, adamw_init_schema(model.schema))

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))
    ds = LMDataset(cfg, args.seq)
    it = ds.batches(args.batch)

    t0 = time.perf_counter()
    losses = []
    for step in range(1, args.steps + 1):
        batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == 1:
            dt = time.perf_counter() - t0
            print(f"step {step:5d}  loss {np.mean(losses[-args.log_every:]):.4f}"
                  f"  grad_norm {float(metrics['grad_norm']):.3f}"
                  f"  lr {float(metrics['lr']):.2e}  {dt:.1f}s")
    if args.ckpt:
        p = save_checkpoint(args.ckpt, args.steps, params, opt_state,
                            {"arch": args.arch, "loss": losses[-1]})
        print("saved", p)
    assert np.isfinite(losses[-1]), "training diverged"
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
