import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

For each combination this builds ShapeDtypeStruct stand-ins (weights,
optimizer state, batch, KV caches — no allocation), jits the right step
function (train_step / prefill / serve_step), lowers, compiles, and
records:

* ``compiled.memory_analysis()`` — proves the per-device footprint fits;
* ``compiled.cost_analysis()``   — FLOPs / bytes for §Roofline;
* collective bytes parsed from the HLO — the third roofline term.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --shape train_4k --mesh single            # one combination
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, shape_supported
from repro.launch.mesh import make_production_mesh
from repro.launch.moe_parallel import make_ep_moe_fn
from repro.launch.specs import (batch_specs, cache_specs, opt_specs,
                                param_specs, use_ep)
from repro.models.registry import build_model
from repro.training.optimizer import OptConfig
from repro.training.steps import make_train_step
from repro import sharding as shlib

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str):
    """Sum output-buffer sizes of collective ops in (optimized) HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+(.*?)\s+(all-gather|all-reduce|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start|-done)?\(", line)
        if not m or (m.group(3) == "-done"):
            continue
        op = m.group(2)
        total = 0
        for dt, dims in shape_re.findall(m.group(1)):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[op] += total
        counts[op] += 1
    return out, counts


def pick_microbatches(cfg, B, S, n_batch_shards, budget=1 << 30):
    """Grad-accum factor keeping the scanned activation carry bounded.

    Budget is deliberately conservative (~1 GiB of carried activations):
    the backward live-set of one rematerialized block is ~4x the carry.
    """
    per_dev_tokens = (B // max(n_batch_shards, 1)) * S
    est = cfg.n_layers * per_dev_tokens * cfg.d_model * 2 * 2  # x + slack
    n_mb = 1
    while est / n_mb > budget and (B // max(n_batch_shards, 1)) % (n_mb * 2) == 0:
        n_mb *= 2
    return n_mb


def build_step(arch: str, shape_name: str, mesh, *, mla_absorb=False,
               capacity_factor=1.25, microbatches=None, pad_heads=0,
               moe_comm_bf16=False, moe_scatter_down=False, q_chunk=0,
               window_ring=False, embed_one_hot=False):
    """Returns (jitted_fn, example_args) for one (arch, shape)."""
    import dataclasses
    import jax.numpy as _jnp
    cfg = get_config(arch, "full")
    if q_chunk:
        cfg = dataclasses.replace(cfg, attn_q_chunk=q_chunk)
    if window_ring:
        cfg = dataclasses.replace(cfg, window_ring_cache=True)
    if embed_one_hot:
        cfg = dataclasses.replace(cfg, embed_one_hot=True)
    if pad_heads:
        # §Perf: pad head counts up to a TP-divisible multiple (zero-init
        # extra heads are exact; here it is a structural variant) instead
        # of falling back to head_dim sharding.
        up = lambda h: ((h + pad_heads - 1) // pad_heads) * pad_heads if h else h
        cfg = dataclasses.replace(cfg, n_heads=up(cfg.n_heads),
                                  n_kv_heads=up(cfg.n_kv_heads))
    model = build_model(cfg)
    kind = INPUT_SHAPES[shape_name]["kind"]
    B = INPUT_SHAPES[shape_name]["global_batch"]
    S = INPUT_SHAPES[shape_name]["seq_len"]
    ep = use_ep(cfg, mesh)
    moe_fn = make_ep_moe_fn(
        mesh, capacity_factor,
        comm_dtype=_jnp.bfloat16 if moe_comm_bf16 else None,
        scatter_down=moe_scatter_down) if ep else None

    pspecs = param_specs(cfg, mesh, ep=ep)

    if kind == "train":
        sizes = shlib.mesh_axis_sizes(mesh)
        nb = sizes.get("data", 1) * sizes.get("pod", 1)
        n_mb = microbatches or pick_microbatches(cfg, B, S, nb)
        step = make_train_step(model, OptConfig(), moe_fn=moe_fn,
                               microbatches=n_mb)
        ospecs = opt_specs(cfg, mesh, ep=ep)
        bspecs = batch_specs(cfg, shape_name, mesh)
        fn = jax.jit(step, donate_argnums=(0, 1))
        return fn, (pspecs, ospecs, bspecs), {"microbatches": n_mb, "ep": ep}

    if kind == "prefill":
        bspecs = batch_specs(cfg, shape_name, mesh)
        cspecs = cache_specs(cfg, shape_name, mesh)

        def prefill(params, inputs, cache):
            return model.prefill(params, inputs, cache, moe_fn=moe_fn,
                                 mla_absorb=mla_absorb)

        fn = jax.jit(prefill, donate_argnums=(2,))
        return fn, (pspecs, bspecs, cspecs), {"ep": ep}

    # decode
    bspecs = batch_specs(cfg, shape_name, mesh)
    cspecs = cache_specs(cfg, shape_name, mesh)

    def serve_step(params, tokens, cache):
        logits, new_cache = model.decode(params, {"tokens": tokens}, cache,
                                         moe_fn=moe_fn, mla_absorb=mla_absorb)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, new_cache

    fn = jax.jit(serve_step, donate_argnums=(2,))
    return fn, (pspecs, bspecs["tokens"], cspecs), {"ep": ep}


def run_one(arch: str, shape_name: str, mesh_kind: str, **kw):
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    t0 = time.perf_counter()
    shlib.FALLBACK_LOG.clear()
    fn, args, info = build_step(arch, shape_name, mesh, **kw)
    info.update({k: v for k, v in kw.items() if v})
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll, coll_counts = collective_bytes(compiled.as_text())

    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_devices": int(n_dev),
        "info": info,
        "fallbacks": list(dict.fromkeys(shlib.FALLBACK_LOG)),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1)) if cost else -1.0,
        "bytes_accessed": float(cost.get("bytes accessed", -1)) if cost else -1.0,
        "collective_bytes": coll,
        "collective_counts": coll_counts,
    }
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=1.25)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--pad-heads", type=int, default=0,
                    help="pad head counts to a multiple (e.g. 16)")
    ap.add_argument("--moe-comm-bf16", action="store_true")
    ap.add_argument("--moe-scatter-down", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--window-ring", action="store_true")
    ap.add_argument("--embed-one-hot", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            if not shape_supported(arch, shape):
                print(f"SKIP  {arch} × {shape} (documented in DESIGN.md)")
                continue
            for mk in meshes:
                name = f"{arch}__{shape}__{mk}{args.tag}"
                try:
                    rec = run_one(arch, shape, mk,
                                  mla_absorb=args.mla_absorb,
                                  capacity_factor=args.capacity_factor,
                                  microbatches=args.microbatches,
                                  pad_heads=args.pad_heads,
                                  moe_comm_bf16=args.moe_comm_bf16,
                                  moe_scatter_down=args.moe_scatter_down,
                                  q_chunk=args.q_chunk,
                                  window_ring=args.window_ring,
                                  embed_one_hot=args.embed_one_hot)
                    (RESULTS_DIR / f"{name}.json").write_text(
                        json.dumps(rec, indent=1))
                    per_dev = rec.get("temp_size_in_bytes", 0) / 2**30
                    print(f"OK    {name}: compile={rec['compile_s']}s "
                          f"flops={rec['flops']:.3g} temp={per_dev:.2f}GiB "
                          f"coll={sum(rec['collective_bytes'].values()):.3g}B")
                except Exception as e:  # noqa: BLE001
                    failures.append((name, repr(e)[:400]))
                    print(f"FAIL  {name}: {repr(e)[:400]}")
    if failures:
        print(f"\n{len(failures)} FAILURES")
        sys.exit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
