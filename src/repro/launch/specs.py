"""ShapeDtypeStruct input stand-ins for every (arch × input-shape) pair.

Nothing here allocates device memory: weights, caches and batches are
ShapeDtypeStructs with NamedShardings attached, ready for
``jax.jit(...).lower(...)``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES
from repro.core.config import ModelConfig
from repro.models.schema import schema_shapes
from repro.models.transformer import decoder_param_schema, init_cache_schema
from repro.sharding import input_sharding, shardings_for_schema
from repro.training.optimizer import adamw_init_schema


def _sds(shape, dtype, mesh, batch):
    return jax.ShapeDtypeStruct(
        shape, jnp.dtype(dtype),
        sharding=input_sharding(mesh, batch, len(shape)))


def batch_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh) -> Dict[str, Any]:
    """Train/prefill batch ShapeDtypeStructs (tokens, labels, modality)."""
    sh = INPUT_SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]

    specs: Dict[str, Any] = {}
    if kind == "decode":
        specs["tokens"] = _sds((B, 1), "int32", mesh, B)
        return specs

    s_txt = S - cfg.n_modality_tokens if cfg.modality == "vision" else S
    specs["tokens"] = _sds((B, s_txt), "int32", mesh, B)
    if kind == "train":
        specs["labels"] = _sds((B, s_txt), "int32", mesh, B)
    if cfg.modality == "vision":
        specs["image_emb"] = _sds((B, cfg.n_modality_tokens,
                                   cfg.modality_embed_dim), cfg.dtype, mesh, B)
    if cfg.modality == "audio":
        specs["audio_emb"] = _sds((B, cfg.encoder_seq_len, cfg.d_model),
                                  cfg.dtype, mesh, B)
    return specs


def param_specs(cfg: ModelConfig, mesh: Mesh, *, ep: bool = False):
    schema = decoder_param_schema(cfg)
    shapes = schema_shapes(schema)
    shards = shardings_for_schema(schema, mesh, fsdp=True, ep=ep)
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shards)


def opt_specs(cfg: ModelConfig, mesh: Mesh, *, ep: bool = False):
    schema = adamw_init_schema(decoder_param_schema(cfg))
    shapes = schema_shapes(schema)
    shards = shardings_for_schema(schema, mesh, fsdp=True, ep=ep)
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shards)


def cache_specs(cfg: ModelConfig, shape_name: str, mesh: Mesh):
    sh = INPUT_SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    schema = init_cache_schema(cfg, B, S)
    shapes = schema_shapes(schema)
    shards = shardings_for_schema(schema, mesh, fsdp=False)
    return jax.tree_util.tree_map(
        lambda s, sh_: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh_),
        shapes, shards)


def use_ep(cfg: ModelConfig, mesh: Mesh) -> bool:
    """Expert parallelism: only when experts divide the data axis."""
    if cfg.moe is None:
        return False
    from repro.sharding import mesh_axis_sizes
    return cfg.moe.n_experts % mesh_axis_sizes(mesh)["data"] == 0
