"""Serving driver: the full SLO-routed RAG service loop.

Builds the paper testbed (corpus, BM25 index, simulator backend), loads
or trains a routing policy, then serves a batch of queries end-to-end:
route -> retrieve -> generate -> report per-SLO metrics.

    PYTHONPATH=src python -m repro.launch.serve --slo quality_first -n 50
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.actions import ACTIONS, SLO_PROFILES
from repro.core.config import TestbedConfig
from repro.core.experiment import run_experiment
from repro.core.metrics import evaluate_actions
from repro.core.offline_log import build_testbed
from repro.core.policy import policy_actions, train_policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slo", default="quality_first",
                    choices=list(SLO_PROFILES))
    ap.add_argument("--objective", default="argmax_ce")
    ap.add_argument("-n", type=int, default=50)
    ap.add_argument("--refusal-cap", type=float, default=1.0)
    args = ap.parse_args()

    cfg = TestbedConfig()
    profile = SLO_PROFILES[args.slo]
    data, index, pipe, train_log, eval_log = build_testbed(cfg)
    tr = train_policy(train_log, train_log.rewards(profile), cfg.router,
                      objective=args.objective, refusal_cap=args.refusal_cap)

    # serve the first n eval queries
    eval_q = data.questions[-cfg.n_eval:][: args.n]
    acts = policy_actions(tr.params, eval_log.states[: args.n], cfg.router)
    print(f"# serving {args.n} queries under SLO={args.slo} "
          f"objective={args.objective}")
    for q, a in zip(eval_q[:10], acts[:10]):
        action = ACTIONS[a]
        out = pipe.execute(q, action)
        print(f"q={q.text[:48]:50s} -> a{a} (k={action.k},{action.mode:7s}) "
              f"cost={out.cost_tokens:6.0f} "
              f"{'REFUSED' if out.refused else ('OK' if out.correct else 'WRONG')}")
    rep = evaluate_actions(eval_log.subset(np.arange(args.n)), acts, profile,
                           args.objective)
    print(json.dumps(rep.row(), indent=1))


if __name__ == "__main__":
    main()
