"""Serving driver: the full SLO-routed RAG service loop via the Gateway.

Builds the paper testbed (corpus, BM25 index), trains a routing
policy, then serves queries end-to-end through the unified routing
API: Gateway -> RoutingPolicy.route -> action-bucketed
retrieval/generation -> reward + error-budget accounting.

The generation side is selectable: the default simulator backend (the
paper's cost model), or ``--backend continuous`` for the real JAX
continuous-batching engine — optionally sharded over a device mesh
with ``--mesh dp=N[,mp=M]``: slots partition over the ``dp`` data
axis, and with ``mp > 1`` the params run tensor-parallel over the
``mp`` model axis (combine with
``XLA_FLAGS=--xla_force_host_platform_device_count=N*M`` on a CPU
host).

    PYTHONPATH=src python -m repro.launch.serve --slo quality_first -n 50
    PYTHONPATH=src python -m repro.launch.serve --backend continuous \
        --mesh dp=1 -n 16
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve \
        --backend continuous --mesh dp=4,mp=2 -n 16
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import dataclasses

from repro.core.config import TestbedConfig
from repro.core.metrics import evaluate_actions
from repro.core.offline_log import build_testbed
from repro.routing import (ConstrainedPolicy, Gateway, MLPPolicy, Request,
                           SimulatorBackend, get_action_space,
                           get_slo_profile, list_action_spaces,
                           list_slo_profiles)
from repro.routing.registry import DEFAULT_SPACE


def _continuous_backend(index, mesh_spec, num_slots, retrievers=None,
                        cache_size: int = 0, clock=None):
    """Real-model generation: ContinuousEngine over an optional mesh."""
    import jax

    from repro.configs import get_config
    from repro.data.tokenizer import HashTokenizer
    from repro.launch.mesh import make_serving_mesh
    from repro.models import build_model
    from repro.routing import ContinuousEngineBackend

    mcfg = get_config("qwen1.5-32b", "smoke")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    # model_cfg: fail fast if mp doesn't divide the head/FFN dims
    mesh = (make_serving_mesh(mesh_spec, model_cfg=mcfg)
            if mesh_spec else None)
    kw = {} if clock is None else {"clock": clock}
    return ContinuousEngineBackend.create(
        model, params, HashTokenizer(mcfg.vocab_size), index,
        mesh=mesh, num_slots=num_slots, max_prompt_len=192,
        max_new_tokens=8, retrievers=retrievers,
        retrieval_cache_size=cache_size, **kw)


def _dump_telemetry(args, tracer, metrics) -> None:
    """Write the run's Chrome trace / Prometheus exposition on exit."""
    if tracer is not None and args.trace_out:
        with open(args.trace_out, "w") as f:
            f.write(tracer.chrome_trace_json(indent=1))
        probs = tracer.problems()
        print(f"# trace: {args.trace_out} "
              f"({tracer.n_finished} requests, "
              f"{len(tracer.sampled_trees)} sampled trees, "
              f"{len(probs)} problems)")
        for p in probs[:5]:
            print(f"#   trace problem: {p}")
    if metrics is not None and args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(metrics.exposition())
        print(f"# metrics: {args.metrics_out}")


def _serve_open_loop(args, policy, backend, cfg, space, index, data,
                     clock, tracer=None, metrics=None) -> None:
    """Open-loop mode: seeded Poisson arrivals through AsyncGateway in
    virtual time, per-request deadlines, SLO-actuated admission."""
    from repro.serving.streaming import AdmissionConfig, AsyncGateway
    from repro.serving.traffic import (LoadGenerator, PoissonProcess,
                                       build_trace)

    gateway = AsyncGateway(
        policy, backend, router_cfg=cfg.router, index=index,
        action_space=space, adaptive_refusal=args.adaptive,
        clock=clock.now, deadline_ms=args.deadline_ms,
        admission=AdmissionConfig(max_backlog=4 * args.num_slots),
        tracer=tracer, metrics=metrics)
    eval_q = data.questions[-cfg.n_eval:]
    trace = build_trace(eval_q, PoissonProcess(args.open_loop, seed=0),
                        args.n, slo=args.slo, deadline_ms=args.deadline_ms)
    print(f"# open-loop: {args.n} arrivals at {args.open_loop}/s "
          f"(poisson, seed 0), deadline {args.deadline_ms}ms")
    rep = LoadGenerator(gateway, trace).run_virtual(clock)
    print(json.dumps(rep.as_dict(), indent=1))
    st = gateway.stats
    print(f"# admission: shed={st.shed} forced_refusals="
          f"{st.forced_refusals} depth_clamped={st.depth_clamped}")
    print("# error budgets:",
          json.dumps(gateway.budget.report_dict(), indent=1))
    es = gateway.engine_stats
    if es is not None:
        print(f"# engine: prefills={es.n_prefills} "
              f"decode_chunks={es.n_decode_chunks} "
              f"max_concurrent={es.max_concurrent}")
    if tracer is not None and tracer.enabled:
        print("# stage percentiles:",
              json.dumps(tracer.stage_percentiles(), indent=1))
    _dump_telemetry(args, tracer, metrics)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slo", default="quality_first",
                    choices=list_slo_profiles())
    ap.add_argument("--objective", default="argmax_ce")
    ap.add_argument("-n", type=int, default=50)
    ap.add_argument("--refusal-cap", type=float, default=1.0)
    ap.add_argument("--adaptive", action="store_true",
                    help="enable budget-driven refusal back-pressure")
    ap.add_argument("--backend", default="simulator",
                    choices=("simulator", "continuous"),
                    help="simulator = paper cost model; continuous = real "
                         "JAX slot-based engine (see --mesh)")
    ap.add_argument("--mesh", default=None, metavar="dp=N[,mp=M]",
                    help="shard the continuous engine over a device "
                         "mesh: slots on the dp (data) axis, params "
                         "tensor-parallel on the mp (model) axis "
                         "(requires --backend continuous)")
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--space", default=DEFAULT_SPACE,
                    choices=list_action_spaces(),
                    help="registered action space to route over; "
                         "hybrid9 adds retriever choice "
                         "(bm25|dense|hybrid) to the action set")
    ap.add_argument("--retrieval-cache", type=int, default=0,
                    metavar="N", help="bounded LRU over retrieval "
                    "results (0 = off); hit counters land in "
                    "GatewayStats")
    ap.add_argument("--open-loop", type=float, default=0.0, metavar="RATE",
                    help="serve an open-loop seeded Poisson arrival "
                         "stream at RATE req/s (virtual time) through "
                         "AsyncGateway instead of the closed-loop serve")
    ap.add_argument("--deadline-ms", type=float, default=250.0,
                    help="per-request completion deadline for "
                         "--open-loop (goodput counts answers within it)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition of the "
                         "run's metrics registry at exit")
    args = ap.parse_args()
    if args.mesh and args.backend != "continuous":
        ap.error("--mesh requires --backend continuous")

    space = get_action_space(args.space)
    cfg = TestbedConfig()
    if space.n_actions != cfg.router.n_actions:
        cfg = dataclasses.replace(cfg, router=dataclasses.replace(
            cfg.router, n_actions=space.n_actions))
    profile = get_slo_profile(args.slo)
    data, index, pipe, train_log, eval_log = build_testbed(
        cfg, None if args.space == DEFAULT_SPACE else space)
    if args.objective == "constrained":
        policy = ConstrainedPolicy.train(train_log, train_log.rewards(profile),
                                         cfg.router,
                                         refusal_cap=args.refusal_cap)
    else:
        policy = MLPPolicy.train(train_log, train_log.rewards(profile),
                                 cfg.router, objective=args.objective,
                                 refusal_cap=args.refusal_cap)

    shown = [0]

    def report(req, action, out, rew):
        if shown[0] < 10:
            shown[0] += 1
            status = ("REFUSED" if out.refused
                      else ("OK" if out.correct else "WRONG"))
            print(f"q={req.question.text[:48]:50s} -> a{action.idx} "
                  f"(k={action.k},{action.mode:7s}) "
                  f"cost={out.cost_tokens:6.0f} {status}")

    clock = None
    if args.open_loop:
        from repro.serving.traffic import VirtualClock
        clock = VirtualClock()
    tracer = metrics = None
    if args.trace_out or args.metrics_out:
        from repro.obs import MetricsRegistry, Tracer
        obs_clock = clock.now if clock is not None else time.perf_counter
        tracer = Tracer(obs_clock)
        metrics = MetricsRegistry(obs_clock)
    if args.backend == "continuous":
        # reuse the suite build_testbed already wired into the pipeline
        # (it embedded the whole corpus once for non-bm25 spaces); the
        # backend wraps it behind its own cache when requested
        suite = (pipe.retrievers
                 if set(space.retriever_names) - {"bm25"} else None)
        backend = _continuous_backend(index, args.mesh, args.num_slots,
                                      retrievers=suite,
                                      cache_size=args.retrieval_cache,
                                      clock=clock.now if clock else None)
    else:
        if args.retrieval_cache and pipe.retrieval_cache is None:
            from repro.retrieval.hybrid import resolve_retrievers
            pipe.retrievers, pipe.retrieval_cache = resolve_retrievers(
                pipe.retrievers, index, cache_size=args.retrieval_cache)
        backend = SimulatorBackend(
            pipe, **({"clock": clock.now} if clock else {}))
    if args.open_loop:
        _serve_open_loop(args, policy, backend, cfg, space, index, data,
                         clock, tracer=tracer, metrics=metrics)
        return
    gateway = Gateway(policy, backend, router_cfg=cfg.router,
                      index=index, max_batch=16, action_space=space,
                      adaptive_refusal=args.adaptive, on_outcome=report,
                      tracer=tracer, metrics=metrics)

    eval_q = data.questions[-cfg.n_eval:][: args.n]
    print(f"# serving {args.n} queries under SLO={args.slo} "
          f"objective={args.objective}")
    stats = gateway.serve([Request(qid=q.qid, question=q, slo=args.slo)
                           for q in eval_q])
    print(f"# served={stats.served} avg_reward={stats.avg_reward:+.4f} "
          f"actions={dict(sorted(stats.action_counts.items()))}")
    if stats.retrieval_cache_lookups:
        print(f"# retrieval cache: {stats.retrieval_cache_hits}"
              f"/{stats.retrieval_cache_lookups} hits")
    es = gateway.engine_stats
    if es is not None:
        print(f"# engine: prefills={es.n_prefills} "
              f"decode_chunks={es.n_decode_chunks} "
              f"max_concurrent={es.max_concurrent} "
              f"cache_allocations={es.cache_allocations}")
    print("# error budgets:",
          json.dumps(gateway.budget.report_dict(), indent=1))
    _dump_telemetry(args, tracer, metrics)

    # offline metrics on the logged sweep for the same routed states
    acts = policy.route(eval_log.states[: args.n], args.slo).actions
    rep = evaluate_actions(eval_log.subset(np.arange(args.n)), acts, profile,
                           args.objective)
    print(json.dumps(rep.row(), indent=1))


if __name__ == "__main__":
    main()
