"""Serving driver: the full SLO-routed RAG service loop via the Gateway.

Builds the paper testbed (corpus, BM25 index, simulator backend),
trains a routing policy, then serves queries end-to-end through the
unified routing API: Gateway -> RoutingPolicy.route -> action-bucketed
retrieval/generation -> reward + error-budget accounting.

    PYTHONPATH=src python -m repro.launch.serve --slo quality_first -n 50
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.config import TestbedConfig
from repro.core.metrics import evaluate_actions
from repro.core.offline_log import build_testbed
from repro.routing import (ConstrainedPolicy, Gateway, MLPPolicy, Request,
                           SimulatorBackend, get_slo_profile,
                           list_slo_profiles)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slo", default="quality_first",
                    choices=list_slo_profiles())
    ap.add_argument("--objective", default="argmax_ce")
    ap.add_argument("-n", type=int, default=50)
    ap.add_argument("--refusal-cap", type=float, default=1.0)
    ap.add_argument("--adaptive", action="store_true",
                    help="enable budget-driven refusal back-pressure")
    args = ap.parse_args()

    cfg = TestbedConfig()
    profile = get_slo_profile(args.slo)
    data, index, pipe, train_log, eval_log = build_testbed(cfg)
    if args.objective == "constrained":
        policy = ConstrainedPolicy.train(train_log, train_log.rewards(profile),
                                         cfg.router,
                                         refusal_cap=args.refusal_cap)
    else:
        policy = MLPPolicy.train(train_log, train_log.rewards(profile),
                                 cfg.router, objective=args.objective,
                                 refusal_cap=args.refusal_cap)

    shown = [0]

    def report(req, action, out, rew):
        if shown[0] < 10:
            shown[0] += 1
            status = ("REFUSED" if out.refused
                      else ("OK" if out.correct else "WRONG"))
            print(f"q={req.question.text[:48]:50s} -> a{action.idx} "
                  f"(k={action.k},{action.mode:7s}) "
                  f"cost={out.cost_tokens:6.0f} {status}")

    gateway = Gateway(policy, SimulatorBackend(pipe), router_cfg=cfg.router,
                      index=index, max_batch=16,
                      adaptive_refusal=args.adaptive, on_outcome=report)

    eval_q = data.questions[-cfg.n_eval:][: args.n]
    print(f"# serving {args.n} queries under SLO={args.slo} "
          f"objective={args.objective}")
    stats = gateway.serve([Request(qid=q.qid, question=q, slo=args.slo)
                           for q in eval_q])
    print(f"# served={stats.served} avg_reward={stats.avg_reward:+.4f} "
          f"actions={dict(sorted(stats.action_counts.items()))}")
    print("# error budgets:", json.dumps(gateway.budget.report(), indent=1))

    # offline metrics on the logged sweep for the same routed states
    acts = policy.route(eval_log.states[: args.n], args.slo).actions
    rep = evaluate_actions(eval_log.subset(np.arange(args.n)), acts, profile,
                           args.objective)
    print(json.dumps(rep.row(), indent=1))


if __name__ == "__main__":
    main()
