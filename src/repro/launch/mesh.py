"""Production meshes.

Functions, not module constants, so importing never touches jax device
state.  Target hardware: TPU v5e pods — 256 chips/pod in a 16x16 mesh;
the multi-pod config is 2 pods = 512 chips.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (approx, per direction)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-host mesh for smoke tests / examples (1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_serving_mesh(spec: str):
    """Parse a ``dp=N[,mp=M]`` flag into a ``("data", "model")`` mesh.

    The serving executors shard the continuous engine's slot dimension
    over the ``data`` axis; ``mp`` defaults to 1 (params replicated).
    ``dp * mp`` must equal the visible device count — use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to test
    multi-device layouts on a CPU host.
    """
    parts = dict(kv.split("=", 1) for kv in spec.split(",") if kv)
    unknown = set(parts) - {"dp", "mp"}
    if unknown:
        raise ValueError(f"unknown mesh axes {sorted(unknown)} in {spec!r} "
                         "(expected dp=N[,mp=M])")
    dp = int(parts.get("dp", 1))
    mp = int(parts.get("mp", 1))
    n = len(jax.devices())
    if dp * mp != n:
        raise ValueError(
            f"mesh {spec!r} needs {dp * mp} devices but {n} are visible "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count)")
    return jax.make_mesh((dp, mp), ("data", "model"))
