"""Production meshes.

Functions, not module constants, so importing never touches jax device
state.  Target hardware: TPU v5e pods — 256 chips/pod in a 16x16 mesh;
the multi-pod config is 2 pods = 512 chips.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (approx, per direction)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-host mesh for smoke tests / examples (1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
