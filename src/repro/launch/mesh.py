"""Production meshes.

Functions, not module constants, so importing never touches jax device
state.  Target hardware: TPU v5e pods — 256 chips/pod in a 16x16 mesh;
the multi-pod config is 2 pods = 512 chips.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (approx, per direction)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-host mesh for smoke tests / examples (1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def check_mp_divisibility(model_cfg, mp: int, *, spec: str = "") -> None:
    """Fail fast when ``mp`` can't partition a model's param schema.

    Runs the REAL sharding resolver (``sharding.model_axis_fallbacks``
    — divisibility fallbacks included) over the config's schema on a
    stub ``mp``-wide mesh, so the validation can never diverge from
    what the executor will actually do.  Leaves that would silently
    replicate over the ``model`` axis raise ``ValueError`` naming the
    config and every offending tensor, instead of an opaque XLA
    sharding failure (or silently burned devices) at first decode.
    No jax devices are touched — safe to call before mesh creation.
    """
    if mp <= 1:
        return
    from types import SimpleNamespace

    import numpy as np

    from repro.models.transformer import decoder_param_schema
    from repro.sharding import model_axis_fallbacks

    stub = SimpleNamespace(axis_names=("data", "model"),
                           devices=np.empty((1, mp), object))
    _, fallbacks = model_axis_fallbacks(decoder_param_schema(model_cfg),
                                        stub)
    if fallbacks:
        raise ValueError(
            f"serving mesh {spec or f'mp={mp}'} cannot tensor-parallel "
            f"model {model_cfg.name!r}: mp={mp} divides no dim of "
            f"{', '.join(fallbacks)} — these tensors would silently "
            "replicate over the model axis; pick an mp that divides "
            "the model's head/FFN/vocab dims")


def make_serving_mesh(spec: str, model_cfg=None):
    """Parse a ``dp=N[,mp=M]`` flag into a ``("data", "model")`` mesh.

    The serving executors shard the continuous engine's slot dimension
    over the ``data`` axis and — with ``mp > 1`` — the model's
    attention-head / FFN / vocab dims over the ``model`` axis (tensor
    parallel).  Pass the target ``model_cfg`` to validate up front that
    ``mp`` divides those dims (:func:`check_mp_divisibility`) instead
    of silently replicating params.  ``dp * mp`` must equal the
    visible device count — use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to test
    multi-device layouts on a CPU host.
    """
    parts = dict(kv.split("=", 1) for kv in spec.split(",") if kv)
    unknown = set(parts) - {"dp", "mp"}
    if unknown:
        raise ValueError(f"unknown mesh axes {sorted(unknown)} in {spec!r} "
                         "(expected dp=N[,mp=M])")
    dp = int(parts.get("dp", 1))
    mp = int(parts.get("mp", 1))
    if model_cfg is not None:
        check_mp_divisibility(model_cfg, mp, spec=spec)
    n = len(jax.devices())
    if dp * mp != n:
        raise ValueError(
            f"mesh {spec!r} needs {dp * mp} devices but {n} are visible "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count)")
    return jax.make_mesh((dp, mp), ("data", "model"))
