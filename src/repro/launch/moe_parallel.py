"""shard_map wrapper that turns moe_apply_ep into a drop-in moe_fn."""
from __future__ import annotations

from functools import partial

import jax
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except (ImportError, TypeError):  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

from repro.core.config import ModelConfig
from repro.models.moe import moe_apply_ep
from repro.sharding import batch_axes


def make_ep_moe_fn(mesh: Mesh, capacity_factor: float = 1.25,
                   comm_dtype=None, scatter_down: bool = False):
    """Returns moe_fn(p, x, cfg) -> (y, aux) running expert-parallel.

    Expert weights must be sharded experts->"data", d_ff->"model"
    (``specs_for_schema(..., ep=True)``).  Tokens shard over
    ("pod","data"); the all_to_all runs over "data" within each pod.
    """
    ba = batch_axes(mesh)
    replica = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def moe_fn(p, x, cfg: ModelConfig):
        param_specs = {
            "router": P(None, None),
            "w_gate": P("data", None, "model"),
            "w_up": P("data", None, "model"),
            "w_down": P("data", "model", None),
        }
        if "shared" in p:
            param_specs["shared"] = {
                "w_gate": P(None, "model"),
                "w_up": P(None, "model"),
                "w_down": P("model", None),
            }
            if "b_ff" in p["shared"]:
                param_specs["shared"]["b_ff"] = P("model")
                param_specs["shared"]["b_out"] = P(None)
        bdim = x.shape[0]
        import numpy as np
        from repro.sharding import mesh_axis_sizes
        sizes = mesh_axis_sizes(mesh)
        prod = int(np.prod([sizes[a] for a in ba]))
        x_spec = P(ba if bdim % prod == 0 else None, None, None)

        fn = shard_map(
            partial(_ep_body, cfg=cfg, capacity_factor=capacity_factor,
                    replica=replica, comm_dtype=comm_dtype,
                    scatter_down=scatter_down),
            mesh,
            in_specs=(param_specs, x_spec),
            out_specs=(x_spec, P()),
        )
        return fn(p, x)

    return moe_fn


def _ep_body(p, x, *, cfg, capacity_factor, replica, comm_dtype=None,
             scatter_down=False):
    return moe_apply_ep(p, x, cfg, data_axis="data", model_axis="model",
                        replica_axes=replica,
                        capacity_factor=capacity_factor,
                        comm_dtype=comm_dtype, scatter_down=scatter_down)
