"""Transformer assembly: decoder-only / enc-dec / hybrid / MoE / VLM.

Layers are grouped into *periodic blocks*: an optional unrolled prefix
(e.g. DeepSeek-V3's first-3-dense layers) followed by ``n_blocks``
repeats of a heterogeneous block of ``P`` layers (Jamba: 7 Mamba + 1
attention per 8; Gemma3: 5 local + 1 global per 6).  The repeats are
executed with ``lax.scan`` over stacked parameters so HLO size and
compile time stay bounded at 40–72 layers.

Caches for decode are pytrees mirroring the block structure; the decode
scan threads per-block cache slices through ``xs``/``ys``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig
from repro.models.schema import ParamSpec, stack_specs
from repro.models import layers as L
from repro.models.moe import moe_schema, moe_apply_ragged
from repro.models.ssm import ssm_schema, ssm_apply, ssm_cache_schema


# ---------------------------------------------------------------------------
# Layer signatures & block structure
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSig:
    kind: str          # "A" | "M"
    window: int        # 0 = full attention
    is_moe: bool
    cross: bool        # enc-dec decoder cross-attention sublayer
    causal: bool = True


def _lcm(a, b):
    return a * b // math.gcd(a, b)


def layer_structure(cfg: ModelConfig) -> Tuple[List[LayerSig], List[LayerSig], int]:
    """Returns (prefix_sigs, block_sigs, n_blocks)."""
    def sig(i: int) -> LayerSig:
        kind = cfg.layer_kind(i)
        window = 0
        if kind == "A" and cfg.sliding_window and cfg.attn_kind(i) == "L":
            window = cfg.sliding_window
        return LayerSig(kind, window, cfg.is_moe_layer(i),
                        cfg.is_encoder_decoder)

    prefix_n = cfg.moe.first_k_dense if cfg.moe else 0
    P = _lcm(_lcm(len(cfg.layer_pattern) or 1, len(cfg.attn_pattern) or 1),
             cfg.moe.moe_period if cfg.moe else 1)
    rest = cfg.n_layers - prefix_n
    assert rest % P == 0, f"{cfg.name}: {rest} layers not divisible by period {P}"
    prefix = [sig(i) for i in range(prefix_n)]
    block = [sig(prefix_n + j) for j in range(P)]
    # verify periodicity
    for b in range(rest // P):
        for j in range(P):
            assert sig(prefix_n + b * P + j) == block[j], (cfg.name, b, j)
    return prefix, block, rest // P


def _layer_schema(cfg: ModelConfig, s: LayerSig) -> Dict[str, Any]:
    d = cfg.d_model
    out: Dict[str, Any] = {"ln1": L.rmsnorm_schema(d)}
    if s.kind == "M":
        out["ssm"] = ssm_schema(cfg)
    elif cfg.attn_type == "mla":
        out["attn"] = L.mla_schema(cfg)
    else:
        out["attn"] = L.gqa_schema(cfg)
    if s.cross and s.kind == "A":
        out["ln_cross"] = L.rmsnorm_schema(d)
        out["cross"] = L.gqa_schema(cfg)
    out["ln2"] = L.rmsnorm_schema(d)
    if s.is_moe:
        out["moe"] = moe_schema(cfg)
    elif s.kind == "A" or cfg.d_ff:
        out["mlp"] = L.mlp_schema(cfg)
    return out


def apply_layer(p, x, cfg: ModelConfig, s: LayerSig, *, positions,
                cache=None, enc_out=None, moe_fn=None, mla_absorb=False,
                page_table=None):
    """One residual block.  Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if s.kind == "M":
        sub = {k: cache[k] for k in ("state", "conv_x", "conv_B", "conv_C")} \
            if cache is not None else None
        out, nc = ssm_apply(p["ssm"], h, cfg, cache=sub)
        if nc is not None:
            new_cache.update(nc)
    elif cfg.attn_type == "mla":
        sub = {"c_kv": cache["c_kv"], "k_rope": cache["k_rope"]} \
            if cache is not None else None
        out, nc = L.mla_apply(p["attn"], h, cfg, positions=positions,
                              cache=sub, absorb=mla_absorb)
        if nc is not None:
            new_cache.update(nc)
    else:
        if cache is None:
            sub = None
        elif "k_q" in cache:     # int8-quantized cache (kv_quant_int8)
            sub = {k: cache[k] for k in ("k_q", "v_q", "k_s", "v_s")}
        else:
            sub = {"k": cache["k"], "v": cache["v"]}
        out, nc = L.gqa_apply(p["attn"], h, cfg, positions=positions,
                              cache=sub, window=s.window, causal=s.causal,
                              ring=bool(cfg.window_ring_cache and s.window),
                              page_table=page_table)
        if nc is not None:
            new_cache.update(nc)
    x = x + out

    if s.cross and s.kind == "A":
        hc = L.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        if enc_out is not None:
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross"]["wv"])
            if new_cache is not None:
                new_cache["cross_k"] = ck.astype(new_cache["cross_k"].dtype)
                new_cache["cross_v"] = cv.astype(new_cache["cross_v"].dtype)
        else:
            ck, cv = cache["cross_k"], cache["cross_v"]
        out, _ = L.gqa_apply(p["cross"], hc, cfg, positions=positions,
                             cross_kv=(ck, cv))
        x = x + out

    h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if s.is_moe:
        fn = moe_fn or moe_apply_ragged
        ff, a = fn(p["moe"], h2, cfg)
        aux = aux + a
    elif "mlp" in p:
        ff = L.mlp_apply(p["mlp"], h2)
    else:
        ff = 0.0
    x = x + ff
    return x, new_cache, aux


def _layer_cache_schema(cfg: ModelConfig, s: LayerSig, batch: int,
                        max_len: int) -> Dict[str, ParamSpec]:
    out: Dict[str, ParamSpec] = {}
    if s.kind == "M":
        out.update(ssm_cache_schema(cfg, batch))
    elif cfg.attn_type == "mla":
        m = cfg.mla
        out["c_kv"] = ParamSpec((batch, max_len, m.kv_lora_rank),
                                ("batch", "seq", "kv_lora"), cfg.dtype, "zeros")
        out["k_rope"] = ParamSpec((batch, max_len, m.qk_rope_head_dim),
                                  ("batch", "seq", ""), cfg.dtype, "zeros")
    else:
        # baseline allocates full max_len even for windowed layers; with
        # cfg.window_ring_cache those layers hold a `window`-sized ring
        # buffer instead (§Perf H4)
        span = max_len
        ring = bool(cfg.window_ring_cache and s.window)
        if ring:
            span = min(max_len, s.window)
        if cfg.kv_quant_int8 and not ring:
            # int8 payload + f16 per-position scales (serving layer owns
            # the quant scheme; lazy import keeps models free of the
            # serving package at import time)
            from repro.serving.kv_quant import quant_kv_cache_schema
            out.update(quant_kv_cache_schema(batch, span, cfg.n_kv_heads,
                                             cfg.head_dim))
        else:
            kv = (batch, span, cfg.n_kv_heads, cfg.head_dim)
            axes = ("batch", "seq", "kv_heads", "head_dim")
            out["k"] = ParamSpec(kv, axes, cfg.dtype, "zeros")
            out["v"] = ParamSpec(kv, axes, cfg.dtype, "zeros")
    if s.cross and s.kind == "A":
        ckv = (batch, cfg.encoder_seq_len, cfg.n_kv_heads, cfg.head_dim)
        axes = ("batch", "", "kv_heads", "head_dim")
        out["cross_k"] = ParamSpec(ckv, axes, cfg.dtype, "zeros")
        out["cross_v"] = ParamSpec(ckv, axes, cfg.dtype, "zeros")
    return out


# ---------------------------------------------------------------------------
# Parameter schema for the full model
# ---------------------------------------------------------------------------


def _retag_dtype(schema, dtype: str):
    """ParamSpecs default to bf16; retag to cfg.dtype (f32 smoke tests)."""
    if dtype == "bfloat16":
        return schema
    return jax.tree_util.tree_map(
        lambda s: (s if s.dtype != "bfloat16"
                   else ParamSpec(s.shape, s.axes, dtype, s.init, s.scale)),
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))


def decoder_param_schema(cfg: ModelConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.padded_vocab
    prefix, block, n_blocks = layer_structure(cfg)
    schema: Dict[str, Any] = {
        "embed": ParamSpec((V, d), ("vocab", "d_model")),
        "final_norm": L.rmsnorm_schema(d),
        "prefix": [_layer_schema(cfg, s) for s in prefix],
        "blocks": stack_specs(
            {f"p{j}": _layer_schema(cfg, s) for j, s in enumerate(block)},
            n_blocks),
    }
    if not cfg.tie_embeddings:
        schema["lm_head"] = ParamSpec((V, d), ("vocab", "d_model"))
    if cfg.modality == "vision":
        me = cfg.modality_embed_dim
        schema["projector"] = {
            "w1": ParamSpec((me, d), ("", "d_model")),
            "w2": ParamSpec((d, d), ("d_model", "d_model2")),
        }
    if cfg.is_encoder_decoder:
        enc_sig = LayerSig("A", 0, False, False, causal=False)
        schema["enc_pos"] = ParamSpec((cfg.encoder_seq_len, d), ("", "d_model"),
                                      init="small")
        schema["encoder"] = stack_specs(_layer_schema(cfg, enc_sig),
                                        cfg.n_encoder_layers)
        schema["enc_final_norm"] = L.rmsnorm_schema(d)
    if cfg.mtp_depth:
        mtp_sig = LayerSig("A", 0, False, False)
        schema["mtp"] = {
            "norm_h": L.rmsnorm_schema(d),
            "norm_e": L.rmsnorm_schema(d),
            "w_comb": ParamSpec((2 * d, d), ("", "d_model")),
            "layer": _layer_schema(cfg, mtp_sig),
            "final_norm": L.rmsnorm_schema(d),
        }
    return _retag_dtype(schema, cfg.dtype)


def init_cache_schema(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Decode-cache ShapeSpec tree (mirrors the param block structure)."""
    prefix, block, n_blocks = layer_structure(cfg)
    cache: Dict[str, Any] = {
        "pos": ParamSpec((batch,), ("batch",), "int32", "zeros"),
        "prefix": [_layer_cache_schema(cfg, s, batch, max_len) for s in prefix],
        "blocks": stack_specs(
            {f"p{j}": _layer_cache_schema(cfg, s, batch, max_len)
             for j, s in enumerate(block)}, n_blocks),
    }
    return cache


def paged_cache_schema(cfg: ModelConfig, num_slots: int, num_pages: int,
                       page_size: int, max_blocks: int) -> Dict[str, Any]:
    """Paged decode-cache ShapeSpec tree (vLLM-style block pool).

    Per layer: a global pool of ``num_pages`` K/V pages of ``page_size``
    positions, reused by :func:`_layer_cache_schema` with
    ``batch=num_pages, max_len=page_size`` — so the page dim carries the
    ``batch`` logical axis (pages shard with the slots on ``data``) and
    kv-head dims keep riding ``model``, int8 quant included.  On top: a
    per-slot ``table`` (num_slots, max_blocks) int32 shared across
    layers, and the usual per-slot ``pos``.  Only full-attention GQA
    stacks page (no SSM/MLA/cross state, no ring buffers): their cache
    rows are not position-addressed pools.
    """
    prefix, block, n_blocks = layer_structure(cfg)
    for s in prefix + block:
        if (s.kind != "A" or s.cross or cfg.attn_type == "mla"
                or (cfg.window_ring_cache and s.window)):
            raise ValueError(
                f"{cfg.name}: paged KV cache supports full-attention "
                f"GQA layers only (got kind={s.kind} cross={s.cross} "
                f"attn_type={cfg.attn_type} ring={bool(s.window)})")
    return {
        "pos": ParamSpec((num_slots,), ("batch",), "int32", "zeros"),
        "table": ParamSpec((num_slots, max_blocks), ("batch", ""),
                           "int32", "zeros"),
        "prefix": [_layer_cache_schema(cfg, s, num_pages, page_size)
                   for s in prefix],
        "blocks": stack_specs(
            {f"p{j}": _layer_cache_schema(cfg, s, num_pages, page_size)
             for j, s in enumerate(block)}, n_blocks),
    }


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed_lookup(params, cfg: ModelConfig, tokens):
    """Gather (default) or one-hot-matmul (§Perf H6) embedding lookup."""
    if cfg.embed_one_hot:
        w = params["embed"]
        oh = jax.nn.one_hot(tokens, w.shape[0], dtype=w.dtype)
        return oh @ w
    return jnp.take(params["embed"], tokens, axis=0)


def _embed_inputs(params, cfg: ModelConfig, inputs: Dict[str, jax.Array]):
    """Token (+ modality) embedding.  Returns (x, positions, label_mask_extra)."""
    tokens = inputs["tokens"]
    B, S_txt = tokens.shape
    x = _embed_lookup(params, cfg, tokens)
    if cfg.modality == "vision" and "image_emb" in inputs:
        pj = params["projector"]
        img = jax.nn.gelu(inputs["image_emb"].astype(x.dtype) @ pj["w1"]) @ pj["w2"]
        x = jnp.concatenate([img, x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions


def _encode(params, cfg: ModelConfig, audio_emb):
    """Whisper-style encoder over stub frame embeddings (B, T, d)."""
    x = audio_emb + params["enc_pos"][None].astype(audio_emb.dtype)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    enc_sig = LayerSig("A", 0, False, False, causal=False)

    def body(carry, lp):
        h, _, _ = apply_layer(lp, carry, cfg, enc_sig, positions=positions)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


def _unembed(params, cfg: ModelConfig, x):
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", x, w).astype(jnp.float32)


def forward_train(params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                  *, moe_fn: Optional[Callable] = None):
    """Full-sequence forward.  Returns (logits, aux) where aux holds the
    MoE load-balance loss and optional MTP logits."""
    prefix, block, n_blocks = layer_structure(cfg)
    x, positions = _embed_inputs(params, cfg, inputs)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, cfg, inputs["audio_emb"])

    aux = jnp.zeros((), jnp.float32)
    for lp, s in zip(params["prefix"], prefix):
        x, _, a = apply_layer(lp, x, cfg, s, positions=positions,
                              enc_out=enc_out, moe_fn=moe_fn)
        aux = aux + a

    def block_body(carry, bp):
        h, acc = carry
        for j, s in enumerate(block):
            h, _, a = apply_layer(bp[f"p{j}"], h, cfg, s, positions=positions,
                                  enc_out=enc_out, moe_fn=moe_fn)
            acc = acc + a
        return (h, acc), None

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        block_body = jax.checkpoint(block_body, policy=policy,
                                    prevent_cse=False)
    (x, aux), _ = jax.lax.scan(block_body, (x, aux), params["blocks"])

    h_final = x
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    extras = {"aux_loss": aux}

    if cfg.mtp_depth and "tokens" in inputs:
        # DeepSeek-V3 multi-token prediction (depth 1): combine final
        # hidden state at t with the embedding of token t+1, run one extra
        # block, predict token t+2 through the shared head.
        mp = params["mtp"]
        tok_emb = jnp.take(params["embed"], inputs["tokens"], axis=0)
        if cfg.modality == "vision" and "image_emb" in inputs:
            n_img = h_final.shape[1] - tok_emb.shape[1]
            h_txt = h_final[:, n_img:]
        else:
            h_txt = h_final
        h_in = jnp.concatenate(
            [L.rmsnorm(mp["norm_h"], h_txt[:, :-1], cfg.norm_eps),
             L.rmsnorm(mp["norm_e"], tok_emb[:, 1:], cfg.norm_eps)], axis=-1)
        h_mtp = h_in @ mp["w_comb"]
        pos_mtp = positions[:, : h_mtp.shape[1]]
        mtp_sig = LayerSig("A", 0, False, False)
        h_mtp, _, _ = apply_layer(mp["layer"], h_mtp, cfg, mtp_sig,
                                  positions=pos_mtp)
        h_mtp = L.rmsnorm(mp["final_norm"], h_mtp, cfg.norm_eps)
        extras["mtp_logits"] = _unembed(params, cfg, h_mtp)

    return logits, extras


def forward_prefill(params, cfg: ModelConfig, inputs, cache,
                    *, moe_fn: Optional[Callable] = None,
                    mla_absorb: bool = False):
    """Prefill: run the full prompt, fill the cache, return last logits."""
    return _forward_cached(params, cfg, inputs, cache, moe_fn=moe_fn,
                           mla_absorb=mla_absorb, prefill=True)


def forward_decode(params, cfg: ModelConfig, inputs, cache,
                   *, moe_fn: Optional[Callable] = None,
                   mla_absorb: bool = False):
    """One decode step: inputs["tokens"] is (B, 1)."""
    return _forward_cached(params, cfg, inputs, cache, moe_fn=moe_fn,
                           mla_absorb=mla_absorb, prefill=False)


def _forward_cached(params, cfg, inputs, cache, *, moe_fn, mla_absorb, prefill):
    prefix, block, n_blocks = layer_structure(cfg)
    tokens = inputs["tokens"]
    B, S = tokens.shape
    x = _embed_lookup(params, cfg, tokens)
    if cfg.modality == "vision" and "image_emb" in inputs and prefill:
        pj = params["projector"]
        img = jax.nn.gelu(inputs["image_emb"].astype(x.dtype) @ pj["w1"]) @ pj["w2"]
        x = jnp.concatenate([img, x], axis=1)
        S = x.shape[1]

    if prefill:
        # "pos0" (B,) shifts each row's positions: a paged suffix
        # prefill runs only tokens [pos0, pos0 + S) against a scratch
        # cache whose [0, pos0) rows hold the gathered shared prefix
        pos0 = inputs.get("pos0")
        if pos0 is None:
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            new_pos = jnp.full((B,), S, jnp.int32)
        else:
            positions = pos0[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
            new_pos = pos0 + S
    else:
        positions = cache["pos"][:, None]
        new_pos = cache["pos"] + 1

    # paged slot cache: per-slot block table, shared across layers
    # (closure-captured by the block scan — it is read-only there)
    page_table = None if prefill else cache.get("table")

    enc_out = None
    if cfg.is_encoder_decoder and "audio_emb" in inputs:
        enc_out = _encode(params, cfg, inputs["audio_emb"])

    new_cache: Dict[str, Any] = {"pos": new_pos, "prefix": []}
    if "table" in cache:
        new_cache["table"] = cache["table"]
    for lp, lc, s in zip(params["prefix"], cache["prefix"], prefix):
        x, nc, _ = apply_layer(lp, x, cfg, s, positions=positions, cache=lc,
                               enc_out=enc_out, moe_fn=moe_fn,
                               mla_absorb=mla_absorb, page_table=page_table)
        new_cache["prefix"].append(nc)

    def block_body(h, bp_bc):
        bp, bc = bp_bc
        ncs = {}
        for j, s in enumerate(block):
            h, nc, _ = apply_layer(bp[f"p{j}"], h, cfg, s, positions=positions,
                                   cache=bc[f"p{j}"], enc_out=enc_out,
                                   moe_fn=moe_fn, mla_absorb=mla_absorb,
                                   page_table=page_table)
            ncs[f"p{j}"] = nc
        return h, ncs

    x, blocks_cache = jax.lax.scan(block_body, x,
                                   (params["blocks"], cache["blocks"]))
    new_cache["blocks"] = blocks_cache

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, -1:])
    return logits, new_cache


def chunked_ce(x, w, labels, *, ignore_id: int = -1, z_loss: float = 1e-4,
               chunk: int = 512):
    """Cross-entropy without materializing (B, S, V) logits.

    x: (B, S, d) final hidden states; w: (V, d) unembedding.  The scan
    body is rematerialized so only per-chunk logits ever exist — the
    production trick that keeps 256k-vocab training inside HBM.
    Returns (sum_nll, n_valid).
    """
    B, S, d = x.shape
    c = min(chunk, S)
    while S % c != 0:
        c -= 1
    n = S // c
    xs = x.reshape(B, n, c, d).swapaxes(0, 1)
    ls = labels.reshape(B, n, c).swapaxes(0, 1)

    @jax.checkpoint
    def body(acc, args):
        xc, lc = args
        valid = lc != ignore_id
        lab = jnp.where(valid, lc, 0)
        logits = jnp.einsum("bcd,vd->bcv", xc, w).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        lse = jax.nn.logsumexp(logits, axis=-1)
        nll = nll + z_loss * lse ** 2
        s, nv = acc
        s = s + jnp.sum(jnp.where(valid, nll, 0.0))
        return (s, nv + jnp.sum(valid)), None

    (tot, nvalid), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                           jnp.zeros((), jnp.int32)), (xs, ls))
    return tot, nvalid


def forward_train_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                       *, moe_fn: Optional[Callable] = None,
                       mtp_weight: float = 0.3):
    """Memory-lean training loss: backbone + chunked CE (+ MTP)."""
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    labels = batch["labels"]
    prefix, block, n_blocks = layer_structure(cfg)
    x, positions = _embed_inputs(params, cfg, inputs)
    enc_out = _encode(params, cfg, inputs["audio_emb"]) \
        if cfg.is_encoder_decoder else None

    aux = jnp.zeros((), jnp.float32)
    for lp, s in zip(params["prefix"], prefix):
        x, _, a = apply_layer(lp, x, cfg, s, positions=positions,
                              enc_out=enc_out, moe_fn=moe_fn)
        aux = aux + a

    def block_body(carry, bp):
        h, acc = carry
        for j, s in enumerate(block):
            h, _, a = apply_layer(bp[f"p{j}"], h, cfg, s, positions=positions,
                                  enc_out=enc_out, moe_fn=moe_fn)
            acc = acc + a
        return (h, acc), None

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        block_body = jax.checkpoint(block_body, policy=policy,
                                    prevent_cse=False)
    (x, aux), _ = jax.lax.scan(block_body, (x, aux), params["blocks"])

    h_final = x
    xn = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    S_txt = labels.shape[1]
    tot, nvalid = chunked_ce(xn[:, -S_txt:], w, labels)
    loss = tot / jnp.maximum(nvalid, 1) + aux

    if cfg.mtp_depth:
        mp = params["mtp"]
        tok_emb = jnp.take(params["embed"], inputs["tokens"], axis=0)
        h_txt = h_final[:, -S_txt:]
        h_in = jnp.concatenate(
            [L.rmsnorm(mp["norm_h"], h_txt[:, :-1], cfg.norm_eps),
             L.rmsnorm(mp["norm_e"], tok_emb[:, 1:], cfg.norm_eps)], axis=-1)
        h_mtp = h_in @ mp["w_comb"]
        mtp_sig = LayerSig("A", 0, False, False)
        h_mtp, _, _ = apply_layer(mp["layer"], h_mtp, cfg, mtp_sig,
                                  positions=positions[:, : h_mtp.shape[1]])
        h_mtp = L.rmsnorm(mp["final_norm"], h_mtp, cfg.norm_eps)
        mtot, mn = chunked_ce(h_mtp, w, labels[:, 1:])
        loss = loss + mtp_weight * mtot / jnp.maximum(mn, 1)

    return loss


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def loss_fn(logits, labels, *, extras=None, ignore_id: int = -1,
            mtp_weight: float = 0.3, z_loss: float = 1e-4):
    """Next-token CE with ignore mask, MoE aux loss, optional MTP loss."""
    V = logits.shape[-1]
    S = labels.shape[1]
    logits_txt = logits[:, -S:]  # drop modality positions
    valid = labels != ignore_id
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits_txt, axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    lse = jax.nn.logsumexp(logits_txt, axis=-1)
    nll = nll + z_loss * lse ** 2
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, nll, 0.0)) / denom
    if extras:
        loss = loss + extras.get("aux_loss", 0.0)
        if "mtp_logits" in extras:
            # MTP predicts token t+2 from position t: shift labels by one.
            ml = extras["mtp_logits"]
            mlab = labels[:, 1:]
            mval = mlab != ignore_id
            mlab_s = jnp.where(mval, mlab, 0)
            mlogp = jax.nn.log_softmax(ml, axis=-1)
            mnll = -jnp.take_along_axis(mlogp, mlab_s[..., None], axis=-1)[..., 0]
            mdenom = jnp.maximum(jnp.sum(mval), 1)
            loss = loss + mtp_weight * jnp.sum(jnp.where(mval, mnll, 0.0)) / mdenom
    return loss
