"""Core layer primitives: norms, rope, chunked attention, MLP, MLA.

Everything is a pure function over explicit parameter pytrees.  Attention
is implemented with query-chunking (lax.scan over query blocks) so that a
(S x S) score tensor never materializes at 32k+ sequence lengths — this
is the jnp reference semantics for the Pallas flash kernel and also what
the dry-run lowers through XLA.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.config import MLAConfig, ModelConfig
from repro.models.schema import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_schema(d: int) -> ParamSpec:
    return ParamSpec((d,), ("d_model",), init="ones")


def rmsnorm(w, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_angles(head_dim: int, theta: float, positions):
    """positions (..., S) -> cos/sin (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D), positions: (B, S) or (S,)."""
    d = x.shape[-1]
    cos, sin = rope_angles(d, theta, positions)
    if cos.ndim == 2:  # (S, half) -> broadcast over batch
        cos, sin = cos[None], sin[None]
    cos = cos[..., None, :]  # (B, S, 1, half)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (chunked, GQA)
# ---------------------------------------------------------------------------


def _attn_block(q, k, v, q_pos, kv_pos, kv_len, *, causal, window, softcap):
    """One query block against full kv.

    q: (B, Sq, Hkv, G, Dh)  k/v: (B, Skv, Hkv, Dh)
    q_pos: (B, Sq)  kv_pos: (Skv,)  kv_len: (B,) or None
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    mask = jnp.ones(scores.shape[-2:], dtype=bool)[None]  # (1, Sq, Skv)
    qp = q_pos[:, :, None]          # (B, Sq, 1)
    kp = kv_pos[None, None, :]      # (1, 1, Skv)
    if causal:
        mask = mask & (kp <= qp)
    if window:
        mask = mask & (kp > qp - window)
    if kv_len is not None:
        mask = mask & (kp < kv_len[:, None, None])
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.astype(v.dtype)


def attention(q, k, v, *, q_pos, kv_len=None, causal=True, window=0,
              softcap=0.0, q_chunk=1024):
    """Grouped-query attention with query chunking.

    q: (B, Sq, H, Dh), k/v: (B, Skv, Hkv, Dh).
    q_pos: (B, Sq) absolute positions of queries.
    kv_len: (B,) valid cache length (None = all Skv valid).
    """
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    kv_pos = jnp.arange(Skv, dtype=jnp.int32)

    if Sq <= q_chunk or Sq % q_chunk != 0:
        out = _attn_block(qg, k, v, q_pos, kv_pos, kv_len,
                          causal=causal, window=window, softcap=softcap)
        return out.reshape(B, Sq, H, Dv)

    n = Sq // q_chunk
    qs = qg.reshape(B, n, q_chunk, Hkv, G, Dh).swapaxes(0, 1)
    ps = q_pos.reshape(B, n, q_chunk).swapaxes(0, 1)

    def body(_, qc_pc):
        qc, pc = qc_pc
        o = _attn_block(qc, k, v, pc, kv_pos, kv_len,
                        causal=causal, window=window, softcap=softcap)
        return None, o

    _, outs = jax.lax.scan(body, None, (qs, ps))
    out = outs.swapaxes(0, 1).reshape(B, Sq, Hkv, G, Dv)
    return out.reshape(B, Sq, H, Dv)


# ---------------------------------------------------------------------------
# GQA attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------


def gqa_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": ParamSpec((d, H, Dh), ("d_model", "heads", "head_dim")),
        "wk": ParamSpec((d, Hkv, Dh), ("d_model", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, Hkv, Dh), ("d_model", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, Dh, d), ("heads", "head_dim", "d_model")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H, Dh), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((Hkv, Dh), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamSpec((Hkv, Dh), ("kv_heads", "head_dim"), init="zeros")
    return s


def gqa_apply(p, x, cfg: ModelConfig, *, positions, cache=None, window=0,
              causal=True, cross_kv=None, ring=False, page_table=None):
    """x: (B, S, d). cache: {"k","v"} or None.  positions: (B, S).

    The valid cache length is derived from positions: after inserting
    this step's kv, entries [0, positions[:, -1] + 1) are valid.
    Returns (out, new_cache).  With ``cross_kv=(k_src, v_src)`` this is
    cross-attention (no rope on kv side, no causal mask).

    ``ring=True`` (requires ``window > 0``): the cache is a ring buffer
    of size ``window``; slot = position % window.  Keys carry their
    absolute-position rope phases, so relative attention is exact; all
    resident entries are within the window by construction, hence the
    score mask reduces to "slot filled".

    ``page_table`` (B, max_blocks) int32 switches decode (S == 1) to a
    *paged* cache: the cache leaves are global page pools of shape
    (num_pages, page_size, Hkv, Dh[v]) and logical block ``i`` of row
    ``b`` lives in pool page ``page_table[b, i]``.  Prefill (S > 1)
    never sees a table — it runs on a dense scratch cache, writing at
    the absolute ``positions`` (which may start past 0 when a shared
    prefix is already resident in the scratch).
    """
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]

    if cross_kv is not None:
        k, v = cross_kv
        out = attention(q, k, v, q_pos=positions, causal=False)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache

    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        if (cfg.use_pallas_attention and causal and not window
                and not cfg.attn_logit_softcap and q.shape[1] == k.shape[1]
                and q.shape[1] % 128 == 0):
            from repro.kernels.ops import flash_attention as _flash
            out = _flash(q, k, v, causal=True)
        else:
            out = attention(q, k, v, q_pos=positions, causal=causal,
                            window=window, softcap=cfg.attn_logit_softcap,
                            q_chunk=cfg.attn_q_chunk)
        new_cache = None
    elif ring and window:
        ck, cv = cache["k"], cache["v"]           # (B, window, Hkv, Dh)
        bidx = jnp.arange(B)
        W = ck.shape[1]
        if S == 1:
            slot = positions[:, 0] % W
            ck = ck.at[bidx, slot].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[bidx, slot].set(v[:, 0].astype(cv.dtype))
            # every resident entry is within the window and ≤ the query
            # position; only mask unfilled slots during warm-up (pos < W)
            kv_len = jnp.minimum(positions[:, -1] + 1, W)
            out = attention(q, ck, cv, q_pos=positions, kv_len=kv_len,
                            causal=False, window=0,
                            softcap=cfg.attn_logit_softcap,
                            q_chunk=cfg.attn_q_chunk)
        else:
            # prefill: attend over the fresh k/v (exact windowed-causal),
            # the ring only receives the trailing window of keys
            out = attention(q, k, v, q_pos=positions, causal=causal,
                            window=window, softcap=cfg.attn_logit_softcap,
                            q_chunk=cfg.attn_q_chunk)
            span = min(S, W)
            slots = positions[:, -span:] % W       # (B, span)
            ck = ck.at[bidx[:, None], slots].set(k[:, -span:].astype(ck.dtype))
            cv = cv.at[bidx[:, None], slots].set(v[:, -span:].astype(cv.dtype))
        new_cache = {"k": ck, "v": cv}
    elif page_table is not None:
        # paged decode (S == 1): write this step's k/v into the page
        # holding `pos`, read back through the block table.  Idle slots
        # carry an out-of-range sentinel position, so their write drops
        # — their pages may already belong to a newly admitted request.
        pool_k = cache["k_q"] if "k_q" in cache else cache["k"]
        NP, ps = pool_k.shape[0], pool_k.shape[1]
        MB = page_table.shape[1]
        bidx = jnp.arange(B)
        pos = positions[:, 0]
        blk = pos // ps
        off = pos % ps
        page = jnp.where(blk < MB,
                         page_table[bidx, jnp.minimum(blk, MB - 1)], NP)
        if "k_q" in cache:
            from repro.serving import kv_quant as KQ
            kq, ks = KQ.quantize(k[:, 0])
            vq, vs = KQ.quantize(v[:, 0])
            new_cache = {
                "k_q": cache["k_q"].at[page, off].set(kq, mode="drop"),
                "v_q": cache["v_q"].at[page, off].set(vq, mode="drop"),
                "k_s": cache["k_s"].at[page, off].set(ks, mode="drop"),
                "v_s": cache["v_s"].at[page, off].set(vs, mode="drop"),
            }
            pk, pv = KQ.read(new_cache, dtype=v.dtype)
        else:
            pk = cache["k"].at[page, off].set(
                k[:, 0].astype(cache["k"].dtype), mode="drop")
            pv = cache["v"].at[page, off].set(
                v[:, 0].astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": pk, "v": pv}
        kv_len = pos + 1
        if (cfg.use_flash_decode and causal and not window
                and not cfg.attn_logit_softcap):
            from repro.kernels.ops import paged_flash_decode as _pfd
            out = _pfd(q[:, 0], pk, pv, page_table, kv_len)[:, None]
            out = out.astype(v.dtype)
        else:
            # reference read: gather every block except the last (the
            # executor's write-overflow block — reads never need it)
            # into a contiguous (B, max_len) row, so the softmax
            # reduction length matches the dense path exactly and
            # greedy decode stays bit-identical to the dense cache
            tbl = page_table[:, :MB - 1] if MB > 1 else page_table
            ck = pk[tbl].reshape(B, -1, *pk.shape[2:])
            cv = pv[tbl].reshape(B, -1, *pv.shape[2:])
            out = attention(q, ck, cv, q_pos=positions, kv_len=kv_len,
                            causal=causal, window=window,
                            softcap=cfg.attn_logit_softcap,
                            q_chunk=cfg.attn_q_chunk)
    elif "k_q" in cache:
        # int8-quantized slot cache (cfg.kv_quant_int8): insert this
        # step's k/v quantized, attend over the dequantized views.  The
        # serving layer owns the quant scheme; import here at call time
        # so models never pulls the serving package at import time.
        from repro.serving import kv_quant as KQ
        if S == 1:  # decode: quantize one step, scatter at per-slot pos
            new_cache = KQ.insert_step(cache, k, v, positions[:, 0])
        else:       # prefill at absolute positions (a suffix prefill
            # starts past 0 when a shared prefix is already resident)
            kq, ks = KQ.quantize(k)
            vq, vs = KQ.quantize(v)
            bidx = jnp.arange(B)
            new_cache = {
                "k_q": cache["k_q"].at[bidx[:, None], positions].set(kq),
                "v_q": cache["v_q"].at[bidx[:, None], positions].set(vq),
                "k_s": cache["k_s"].at[bidx[:, None], positions].set(ks),
                "v_s": cache["v_s"].at[bidx[:, None], positions].set(vs),
            }
        ck, cv = KQ.read(new_cache, dtype=v.dtype)
        kv_len = positions[:, -1] + 1
        if (S == 1 and cfg.use_flash_decode and causal and not window
                and not cfg.attn_logit_softcap):
            from repro.kernels.ops import flash_decode as _flash_decode
            out = _flash_decode(q[:, 0], ck, cv, kv_len)[:, None]
            out = out.astype(v.dtype)
        else:
            out = attention(q, ck, cv, q_pos=positions, kv_len=kv_len,
                            causal=causal, window=window,
                            softcap=cfg.attn_logit_softcap,
                            q_chunk=cfg.attn_q_chunk)
    else:
        ck, cv = cache["k"], cache["v"]
        bidx = jnp.arange(B)
        if S == 1:  # decode: scatter at per-request positions
            ck = ck.at[bidx, positions[:, 0]].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[bidx, positions[:, 0]].set(v[:, 0].astype(cv.dtype))
        else:  # prefill at absolute positions (a suffix prefill starts
            # past 0 when a shared prefix is already resident)
            ck = ck.at[bidx[:, None], positions].set(k.astype(ck.dtype))
            cv = cv.at[bidx[:, None], positions].set(v.astype(cv.dtype))
        kv_len = positions[:, -1] + 1
        if (S == 1 and cfg.use_flash_decode and causal and not window
                and not cfg.attn_logit_softcap):
            # single-query split-KV kernel over the slot cache; kv_len
            # masking subsumes the causal mask at decode (kv_len = pos+1)
            from repro.kernels.ops import flash_decode as _flash_decode
            out = _flash_decode(q[:, 0], ck, cv, kv_len)[:, None]
            out = out.astype(v.dtype)
        else:
            out = attention(q, ck, cv, q_pos=positions, kv_len=kv_len,
                            causal=causal, window=window,
                            softcap=cfg.attn_logit_softcap,
                            q_chunk=cfg.attn_q_chunk)
        new_cache = {"k": ck, "v": cv}

    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) — DeepSeek-V2/V3, MiniCPM3
# ---------------------------------------------------------------------------


def mla_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, H = cfg.d_model, cfg.n_heads
    m: MLAConfig = cfg.mla
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    s = {}
    if m.q_lora_rank:
        s["wq_a"] = ParamSpec((d, m.q_lora_rank), ("d_model", ""))
        s["q_norm"] = ParamSpec((m.q_lora_rank,), ("",), init="ones")
        s["wq_b"] = ParamSpec((m.q_lora_rank, H, qd), ("", "heads", "head_dim"))
    else:
        s["wq_b"] = ParamSpec((d, H, qd), ("d_model", "heads", "head_dim"))
    s["wkv_a"] = ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim), ("d_model", ""))
    s["kv_norm"] = ParamSpec((m.kv_lora_rank,), ("",), init="ones")
    s["wk_b"] = ParamSpec((m.kv_lora_rank, H, m.qk_nope_head_dim),
                          ("", "heads", "head_dim"))
    s["wv_b"] = ParamSpec((m.kv_lora_rank, H, m.v_head_dim),
                          ("", "heads", "head_dim"))
    s["wo"] = ParamSpec((H, m.v_head_dim, d), ("heads", "head_dim", "d_model"))
    return s


def mla_apply(p, x, cfg: ModelConfig, *, positions, cache=None, causal=True,
              absorb: bool = False):
    """MLA forward.  Cache stores the *compressed* (c_kv, k_rope) pair.

    ``absorb=True`` uses the weight-absorption decode trick (attention in
    latent space) — a beyond-paper §Perf optimization; ``False`` is the
    naive expansion (baseline).
    """
    B, S, d = x.shape
    m: MLAConfig = cfg.mla
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    # --- queries
    if m.q_lora_rank:
        cq = rmsnorm(p["q_norm"], x @ p["wq_a"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # --- compressed kv
    ckv_full = x @ p["wkv_a"]
    c_kv = rmsnorm(p["kv_norm"], ckv_full[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank:]  # (B, S, dr) shared across heads
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if cache is not None:
        bidx = jnp.arange(B)
        if S == 1:
            pos0 = positions[:, 0]
            cc = cache["c_kv"].at[bidx, pos0].set(c_kv[:, 0].astype(cache["c_kv"].dtype))
            cr = cache["k_rope"].at[bidx, pos0].set(k_rope[:, 0].astype(cache["k_rope"].dtype))
        else:
            cc = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
            cr = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, 0, 0))
        new_cache = {"c_kv": cc, "k_rope": cr}
        kv_src, kr_src, kv_len = cc, cr, positions[:, -1] + 1
    else:
        new_cache = None
        kv_src, kr_src, kv_len = c_kv, k_rope, None

    scale = 1.0 / math.sqrt(dn + dr)
    Skv = kv_src.shape[1]
    kv_pos = jnp.arange(Skv, dtype=jnp.int32)

    if absorb:
        # latent-space attention: fold wk_b into q, wv_b into the output.
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope.astype(jnp.float32),
                           p["wk_b"].astype(jnp.float32))
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, kv_src.astype(jnp.float32))
                  + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                               kr_src.astype(jnp.float32))) * scale
        mask = jnp.ones((S, Skv), bool)[None]
        if causal:
            mask = mask & (kv_pos[None, None, :] <= positions[:, :, None])
        if kv_len is not None:
            mask = mask & (kv_pos[None, None, :] < kv_len[:, None, None])
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, kv_src.astype(jnp.float32))
        out = jnp.einsum("bshr,rhv->bshv", o_lat, p["wv_b"].astype(jnp.float32))
        out = out.astype(x.dtype)
    else:
        # naive: expand the latent into per-head k/v, then plain MHA.
        k_nope = jnp.einsum("btr,rhk->bthk", kv_src, p["wk_b"])
        v = jnp.einsum("btr,rhv->bthv", kv_src, p["wv_b"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_src[:, :, None, :],
                                      (B, Skv, H, dr)).astype(k_nope.dtype)],
            axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attention(qq, k, v, q_pos=positions, kv_len=kv_len, causal=causal)

    return jnp.einsum("bshv,hvd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_schema(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {
        "w_gate": ParamSpec((d, f), ("d_model", "d_ff")),
        "w_up": ParamSpec((d, f), ("d_model", "d_ff")),
        "w_down": ParamSpec((f, d), ("d_ff", "d_model")),
    }
    if cfg.use_bias:
        s["b_ff"] = ParamSpec((f,), ("d_ff",), init="zeros")
        s["b_out"] = ParamSpec((d,), ("d_model",), init="zeros")
    return s


def mlp_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    if "b_ff" in p:
        h = h + p["b_ff"]
    out = h @ p["w_down"]
    if "b_out" in p:
        out = out + p["b_out"]
    return out
