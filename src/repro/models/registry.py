"""Model façade: bundle schema + forward fns for a ModelConfig."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax

from repro.core.config import ModelConfig
from repro.models import transformer as T
from repro.models.schema import init_from_schema, schema_shapes, n_params


@dataclass
class Model:
    cfg: ModelConfig
    schema: Dict[str, Any]

    def init(self, key) -> Dict[str, Any]:
        return init_from_schema(key, self.schema)

    def param_shapes(self):
        return schema_shapes(self.schema)

    def n_params(self) -> int:
        return n_params(self.schema)

    def cache_schema(self, batch: int, max_len: int):
        return T.init_cache_schema(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int):
        return init_from_schema(jax.random.PRNGKey(0),
                                self.cache_schema(batch, max_len))

    def paged_cache_schema(self, num_slots: int, num_pages: int,
                           page_size: int, max_blocks: int):
        return T.paged_cache_schema(self.cfg, num_slots, num_pages,
                                    page_size, max_blocks)

    def init_paged_cache(self, num_slots: int, num_pages: int,
                         page_size: int, max_blocks: int):
        return init_from_schema(
            jax.random.PRNGKey(0),
            self.paged_cache_schema(num_slots, num_pages, page_size,
                                    max_blocks))

    # forward passes --------------------------------------------------
    def train_logits(self, params, inputs, *, moe_fn: Optional[Callable] = None):
        return T.forward_train(params, self.cfg, inputs, moe_fn=moe_fn)

    def prefill(self, params, inputs, cache, *, moe_fn=None, mla_absorb=False):
        return T.forward_prefill(params, self.cfg, inputs, cache,
                                 moe_fn=moe_fn, mla_absorb=mla_absorb)

    def decode(self, params, inputs, cache, *, moe_fn=None, mla_absorb=False):
        return T.forward_decode(params, self.cfg, inputs, cache,
                                moe_fn=moe_fn, mla_absorb=mla_absorb)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, schema=T.decoder_param_schema(cfg))
