"""Mixture-of-Experts blocks.

Two execution paths:

* :func:`moe_apply_ragged` — sort-based dispatch + ``jax.lax.ragged_dot``
  grouped matmuls.  Shard-agnostic; used for smoke tests and small runs.
* :func:`moe_apply_ep` — production expert parallelism inside
  ``shard_map``: capacity-based dispatch, ``all_to_all`` over the data
  axis to the expert shards, dense batched matmuls on the MXU, and the
  return ``all_to_all``.  This is the GShard/Switch pattern reworked for
  TPU (dense (E_loc, C_tot, d) @ (E_loc, d, f_loc) contractions instead
  of GPU-style sparse gathers).

Expert weights live as (E, d, f) with logical axes
("experts", "d_model", "d_ff_expert"); the sharding resolver maps
experts->data and d_ff_expert->model under EP.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, MoEConfig
from repro.models.schema import ParamSpec
from repro.models.layers import mlp_schema, mlp_apply


def _axis_size(name: str) -> int:
    """Mapped-axis size inside shard_map; ``jax.lax.axis_size`` only
    exists on newer jax, so fall back to the classic psum(1) idiom
    (concrete for a constant operand)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def moe_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    e: MoEConfig = cfg.moe
    f = e.d_ff_expert
    s = {
        "router": ParamSpec((d, e.n_experts), ("d_model", "experts_r"), init="small"),
        "w_gate": ParamSpec((e.n_experts, d, f), ("experts", "d_model", "d_ff_expert")),
        "w_up": ParamSpec((e.n_experts, d, f), ("experts", "d_model", "d_ff_expert")),
        "w_down": ParamSpec((e.n_experts, f, d), ("experts", "d_ff_expert", "d_model")),
    }
    if e.n_shared_experts:
        s["shared"] = mlp_schema(cfg, d_ff=f * e.n_shared_experts)
    return s


def router_probs(p, xf, e: MoEConfig):
    """xf: (T, d) -> (top_vals (T,k), top_idx (T,k), aux_loss scalar)."""
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, e.top_k)
    top_vals = top_vals / (jnp.sum(top_vals, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balance auxiliary loss: E * sum_e f_e * p_e
    pe = jnp.mean(probs, axis=0)                      # mean router prob
    onehot = jax.nn.one_hot(top_idx[:, 0], e.n_experts)
    fe = jnp.mean(onehot, axis=0)                     # fraction routed (top-1)
    aux = e.n_experts * jnp.sum(pe * fe) * e.load_balance_coef
    return top_vals, top_idx, aux


def _shared_out(p, x):
    return mlp_apply(p["shared"], x) if "shared" in p else 0.0


# ---------------------------------------------------------------------------
# Path 1: ragged_dot (shard-agnostic)
# ---------------------------------------------------------------------------


def moe_apply_ragged(p, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss)."""
    B, S, d = x.shape
    e = cfg.moe
    T = B * S
    xf = x.reshape(T, d)
    top_vals, top_idx, aux = router_probs(p, xf, e)

    flat_e = top_idx.reshape(-1)                       # (T*k,)
    sort_idx = jnp.argsort(flat_e)                     # stable
    tok_idx = sort_idx // e.top_k
    xs = xf[tok_idx]                                   # (T*k, d)
    group_sizes = jnp.bincount(flat_e, length=e.n_experts).astype(jnp.int32)

    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["w_gate"], group_sizes))
    h = h * jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    out = jax.lax.ragged_dot(h, p["w_down"], group_sizes)

    w = top_vals.reshape(-1)[sort_idx][:, None].astype(out.dtype)
    y = jnp.zeros((T, d), out.dtype).at[tok_idx].add(out * w)
    y = y.reshape(B, S, d) + _shared_out(p, x)
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Path 2: expert parallelism with all_to_all (inside shard_map)
# ---------------------------------------------------------------------------


def moe_apply_ep(p, x, cfg: ModelConfig, *, data_axis: str = "data",
                 model_axis: str = "model", replica_axes=("data",),
                 capacity_factor: float = 1.25,
                 comm_dtype=None,
                 scatter_down: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE.  MUST run inside shard_map where:

    * x is the per-shard token slice (B_loc, S, d) — full d_model;
    * p["router"] replicated; expert weights sharded experts->data_axis
      (so the local leaf is (E_loc, d, f_loc)) and d_ff->model_axis.

    Dispatch: per-shard capacity buffers -> all_to_all over data_axis ->
    dense per-expert matmul -> all_to_all back -> weighted combine.
    """
    B, S, d = x.shape
    e = cfg.moe
    n_shards = _axis_size(data_axis)
    E, E_loc = e.n_experts, e.n_experts // n_shards
    T = B * S
    xf = x.reshape(T, d)

    top_vals, top_idx, aux = router_probs(p, xf, e)
    aux = jax.lax.pmean(aux, replica_axes)

    # --- capacity-based slotting (sort by expert, position within group)
    cap = max(1, int(-(-capacity_factor * e.top_k * T // E)))
    flat_e = top_idx.reshape(-1)                            # (T*k,)
    sort_idx = jnp.argsort(flat_e)
    sorted_e = flat_e[sort_idx]
    # position of each routed token within its expert group
    seg_pos = jnp.arange(T * e.top_k) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = seg_pos < cap
    tok_idx = sort_idx // e.top_k

    # scatter tokens into (E, cap, d) send buffer (dropped tokens -> 0)
    send_dtype = comm_dtype or xf.dtype
    buf = jnp.zeros((E, cap, d), send_dtype)
    slot_e = jnp.where(keep, sorted_e, 0)
    slot_c = jnp.where(keep, seg_pos, 0)
    contrib = jnp.where(keep[:, None], xf[tok_idx], 0.0).astype(send_dtype)
    buf = buf.at[slot_e, slot_c].add(contrib)

    # --- all_to_all: (E, cap, d) -> (n_shards * cap tokens per local expert)
    # split axis 0 (experts) across shards, concat source shards on axis 1.
    recv = jax.lax.all_to_all(
        buf.reshape(n_shards, E_loc, cap, d), data_axis,
        split_axis=0, concat_axis=0, tiled=False)
    # recv: (n_shards, E_loc, cap, d) — first dim is the source shard
    recv = recv.transpose(1, 0, 2, 3).reshape(E_loc, n_shards * cap, d)

    # --- dense per-expert compute (local experts, local d_ff shard)
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    recv = recv.astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, wg))
    h = h * jnp.einsum("ecd,edf->ecf", recv, wu)
    out = jnp.einsum("ecf,efd->ecd", h, wd)
    out = out.astype(send_dtype)

    n_model = _axis_size(model_axis)
    if scatter_down and d % n_model == 0:
        # §Perf it3: reduce-scatter the partial down-proj over the model
        # axis onto the d dim, send a d/n_model slice through the return
        # all_to_all, and all-gather d only at token granularity.
        out = jax.lax.psum_scatter(out, model_axis, scatter_dimension=2,
                                   tiled=True)              # (E_loc, C', d/m)
        d_loc = d // n_model
    else:
        # d_ff is sharded over model_axis -> partial sums
        out = jax.lax.psum(out, model_axis)
        d_loc = d

    # --- all_to_all back to source shards
    back = out.reshape(E_loc, n_shards, cap, d_loc).transpose(1, 0, 2, 3)
    send = jax.lax.all_to_all(back, data_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    send = send.reshape(E, cap, d_loc)                     # (E, cap, d_loc)

    # --- combine: gather each routed token's expert output, weight, sum
    gathered = send[slot_e, slot_c]                        # (T*k, d_loc)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w = top_vals.reshape(-1)[sort_idx][:, None].astype(gathered.dtype)
    y = jnp.zeros((T, d_loc), gathered.dtype).at[tok_idx].add(gathered * w)
    if d_loc != d:
        y = jax.lax.all_gather(y, model_axis, axis=1, tiled=True)  # (T, d)
    y = y.reshape(B, S, d)
    if "shared" in p:
        # shared-expert d_ff is sharded over model_axis -> partial sum
        y = y + jax.lax.psum(_shared_out(p, x), model_axis)
    return y.astype(x.dtype), aux
