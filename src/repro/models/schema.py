"""Parameter schema: declarative weight descriptors.

A model is described as a pytree of :class:`ParamSpec` leaves.  The same
tree drives three consumers:

* ``init_from_schema(key, schema)`` — materialize parameters;
* ``schema_shapes(schema)`` — ShapeDtypeStructs for ``jax.eval_shape`` /
  dry-run lowering (no allocation);
* ``repro.sharding.specs_for_schema`` — PartitionSpecs resolved from the
  *logical axes* recorded on each leaf (with divisibility fallback).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    # logical axis names, one per dim: "vocab" | "d_model" | "d_ff" |
    # "heads" | "kv_heads" | "head_dim" | "experts" | "layers" |
    # "d_inner" | "d_state" | null ""
    axes: Tuple[str, ...]
    dtype: str = "bfloat16"
    init: str = "normal"            # normal | zeros | ones | small
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(key, spec: ParamSpec):
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    scale = spec.scale
    if spec.init == "small":
        scale = spec.scale * 0.1
    # fan-in scaled normal
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def init_from_schema(key, schema):
    """Materialize a parameter pytree from a ParamSpec tree."""
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=_is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def schema_shapes(schema):
    """ShapeDtypeStruct tree — for .lower() without allocating."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        schema,
        is_leaf=_is_spec,
    )


def n_params(schema) -> int:
    leaves = jax.tree_util.tree_leaves(schema, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Add a leading stacked dim (for lax.scan over layers)."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.dtype, s.init, s.scale),
        spec_tree,
        is_leaf=_is_spec,
    )
