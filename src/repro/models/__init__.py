"""Model zoo: dense GQA / MLA / MoE / SSM / hybrid / enc-dec / multimodal.

All models are pure-function JAX: ``param_schema(cfg)`` describes every
weight (shape + logical axes), ``init_params(key, cfg)`` materializes
them, and ``forward_*`` functions run train / prefill / decode paths.
"""

from repro.models.schema import ParamSpec, init_from_schema, schema_shapes
from repro.models.transformer import (
    decoder_param_schema,
    forward_train,
    forward_prefill,
    forward_decode,
    init_cache_schema,
    loss_fn,
)
from repro.models.registry import build_model

__all__ = [
    "ParamSpec",
    "init_from_schema",
    "schema_shapes",
    "decoder_param_schema",
    "forward_train",
    "forward_prefill",
    "forward_decode",
    "init_cache_schema",
    "loss_fn",
    "build_model",
]
