"""Mamba2 (SSD — state-space duality) layer.

Chunked SSD algorithm [arXiv:2405.21060]: the sequence is split into
chunks; within a chunk the quadratic (attention-like) form runs on the
MXU, across chunks a recurrent state (B, H, head_dim, d_state) is carried
by a scan.  Decode is a single-token state update — O(1) in sequence
length, which is what makes ``long_500k`` feasible for SSM/hybrid archs.

GPU implementations lean on warp-level scans; here the chunk is the VMEM
tile and the inter-chunk recurrence is a ``lax.scan`` — see
``repro.kernels.ssd_chunk_scan`` for the Pallas version of the
intra-chunk term.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, SSMConfig
from repro.models.schema import ParamSpec
from repro.models.layers import rmsnorm


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    return d_inner, nheads


def ssm_schema(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    s: SSMConfig = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    return {
        "w_z": ParamSpec((d, d_inner), ("d_model", "d_inner")),
        "w_x": ParamSpec((d, d_inner), ("d_model", "d_inner")),
        "w_B": ParamSpec((d, gn), ("d_model", "")),
        "w_C": ParamSpec((d, gn), ("d_model", "")),
        "w_dt": ParamSpec((d, H), ("d_model", "")),
        "dt_bias": ParamSpec((H,), ("",), init="zeros"),
        "A_log": ParamSpec((H,), ("",), init="zeros"),
        "D": ParamSpec((H,), ("",), init="ones"),
        "conv_x": ParamSpec((s.d_conv, d_inner), ("", "d_inner"), init="small"),
        "conv_B": ParamSpec((s.d_conv, gn), ("", ""), init="small"),
        "conv_C": ParamSpec((s.d_conv, gn), ("", ""), init="small"),
        "norm": ParamSpec((d_inner,), ("d_inner",), init="ones"),
        "w_out": ParamSpec((d_inner, d), ("d_inner", "d_model")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x: (B, S, C), w: (K, C).

    With ``state`` (B, K-1, C) the conv consumes it as left context and
    the updated state is returned (decode path).
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return jax.nn.silu(out), new_state


def _expand_groups(t, H):
    """(B, ..., G, N) -> (B, ..., H, N) by repeating groups."""
    G = t.shape[-2]
    return jnp.repeat(t, H // G, axis=-2) if G != H else t


def ssd_chunked(x, Bm, Cm, dt, A_log, c: int):
    """SSD chunked scan (reference jnp path).

    x:  (B, S, H, hd)   Bm/Cm: (B, S, G, N)   dt: (B, S, H)
    Returns y (B, S, H, hd) and final state (B, H, hd, N).
    """
    Bsz, S, H, hd = x.shape
    N = Bm.shape[-1]
    assert S % c == 0, (S, c)
    nc = S // c
    a = -jnp.exp(A_log.astype(jnp.float32))            # (H,)

    xf = x.astype(jnp.float32).reshape(Bsz, nc, c, H, hd)
    Bc = _expand_groups(Bm.astype(jnp.float32), H).reshape(Bsz, nc, c, H, N)
    Cc = _expand_groups(Cm.astype(jnp.float32), H).reshape(Bsz, nc, c, H, N)
    dtc = dt.astype(jnp.float32).reshape(Bsz, nc, c, H)

    da = dtc * a                                       # (B, nc, c, H) ≤ 0
    cum = jnp.cumsum(da, axis=2)

    # intra-chunk quadratic term
    att = jnp.einsum("bzthn,bzshn->bztsh", Cc, Bc)     # (B, nc, c, c, H)
    L = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((c, c), bool))[None, None, :, :, None]
    w = jnp.where(tri, att * L, 0.0) * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bztsh,bzshd->bzthd", w, xf)

    # chunk summaries -> inter-chunk recurrence
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)    # (B, nc, c, H)
    S_chunk = jnp.einsum("bzsh,bzshn,bzshd->bzhdn",
                         dtc * decay_to_end, Bc, xf)   # (B, nc, H, hd, N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # (B, nc, H)

    def step(state, inp):
        s_c, dec = inp
        prev = state
        state = state * dec[:, :, None, None] + s_c
        return state, prev

    init = jnp.zeros((Bsz, H, hd, N), jnp.float32)
    final, prevs = jax.lax.scan(
        step, init,
        (S_chunk.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    states_prev = prevs.swapaxes(0, 1)                 # (B, nc, H, hd, N)

    y_inter = jnp.einsum("bzthn,bzhdn,bzth->bzthd",
                         Cc, states_prev, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, S, H, hd)
    return y.astype(x.dtype), final


def ssm_apply(p, x, cfg: ModelConfig, *, cache=None) -> Tuple[jax.Array, dict]:
    """Mamba2 block.  x: (B, S, d).

    cache (decode): {"state": (B,H,hd,N) f32, "conv_x": (B,K-1,d_inner),
    "conv_B": (B,K-1,GN), "conv_C": (B,K-1,GN)}.
    """
    B, S, d = x.shape
    s = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    hd, N, G = s.head_dim, s.d_state, s.n_groups

    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bm = x @ p["w_B"]
    Cm = x @ p["w_C"]
    dt = jax.nn.softplus(x @ p["w_dt"] + p["dt_bias"])  # (B, S, H)

    if cache is None or S > 1:
        # train (no cache) or prefill (fill conv + ssm state from scratch)
        xs, cx = _causal_conv(xs, p["conv_x"])
        Bm, cb = _causal_conv(Bm, p["conv_B"])
        Cm, cc = _causal_conv(Cm, p["conv_C"])
        xh = xs.reshape(B, S, H, hd)
        c = min(s.chunk_size, S)
        while S % c != 0:
            c -= 1
        if cfg.use_pallas_ssd and cache is None and S % 128 == 0:
            from repro.kernels.ops import ssd_chunk_scan as _ssd
            y = _ssd(xh, Bm.reshape(B, S, G, N), Cm.reshape(B, S, G, N),
                     dt, p["A_log"], chunk=c)
            final = None  # train path: no state carry needed
        else:
            y, final = ssd_chunked(xh, Bm.reshape(B, S, G, N),
                                   Cm.reshape(B, S, G, N), dt,
                                   p["A_log"], c)
        if cache is None:
            new_cache = None
        else:
            new_cache = {"state": final, "conv_x": cx, "conv_B": cb,
                         "conv_C": cc}
    else:
        xs, cx = _causal_conv(xs, p["conv_x"], cache["conv_x"])
        Bm, cb = _causal_conv(Bm, p["conv_B"], cache["conv_B"])
        Cm, cc = _causal_conv(Cm, p["conv_C"], cache["conv_C"])
        xh = xs.reshape(B, H, hd).astype(jnp.float32)
        Bt = _expand_groups(Bm.reshape(B, G, N).astype(jnp.float32), H)
        Ct = _expand_groups(Cm.reshape(B, G, N).astype(jnp.float32), H)
        dtt = dt.reshape(B, H).astype(jnp.float32)
        a = -jnp.exp(p["A_log"].astype(jnp.float32))
        decay = jnp.exp(dtt * a)                        # (B, H)
        state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
            "bhn,bhd,bh->bhdn", Bt, xh, dtt)
        y = jnp.einsum("bhn,bhdn->bhd", Ct, state)[:, None].astype(x.dtype)
        new_cache = {"state": state, "conv_x": cx, "conv_B": cb, "conv_C": cc}
        y = y.reshape(B, S, H, hd)

    y = y + p["D"].astype(y.dtype)[:, None] * xs.reshape(B, S, H, hd)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["w_out"], new_cache


def ssm_cache_schema(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    gn = s.n_groups * s.d_state
    K = s.d_conv
    return {
        "state": ParamSpec((batch, H, s.head_dim, s.d_state),
                           ("batch", "", "", ""), "float32", "zeros"),
        "conv_x": ParamSpec((batch, K - 1, d_inner),
                            ("batch", "", "d_inner"), cfg.dtype, "zeros"),
        "conv_B": ParamSpec((batch, K - 1, gn), ("batch", "", ""), cfg.dtype, "zeros"),
        "conv_C": ParamSpec((batch, K - 1, gn), ("batch", "", ""), cfg.dtype, "zeros"),
    }
