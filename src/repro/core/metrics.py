"""Evaluation metrics and reporting (paper §5.1 conventions).

* accuracy — normalized exact match (the simulator scores EM directly);
* avg_cost_tokens — prompt + completion tokens;
* hallucination_rate — incorrect answer where refusal was appropriate;
* refusal_rate;
* retrieval_hit_rate — answerable questions only: gold answer string
  contained in the retrieved set.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.core.config import SLOProfile
from repro.core.offline_log import OfflineLog


@dataclass
class PolicyReport:
    name: str
    acc: float
    cost: float
    reward: float
    refusal_rate: float
    hallucination_rate: float
    hit_rate: float
    action_dist: np.ndarray

    def row(self) -> Dict[str, float]:
        d = {"method": self.name, "acc": round(self.acc, 3),
             "cost": round(self.cost, 1), "reward": round(self.reward, 4),
             "refuse": round(self.refusal_rate, 3),
             "hall": round(self.hallucination_rate, 3),
             "hit": round(self.hit_rate, 3)}
        d["action_dist"] = [round(float(x), 3) for x in self.action_dist]
        return d


def evaluate_actions(log: OfflineLog, actions: np.ndarray,
                     profile: SLOProfile, name: str = "") -> PolicyReport:
    """Score a per-state action assignment against the logged sweep."""
    n = log.n
    idx = np.arange(n)
    r = log.rewards(profile)[idx, actions]
    ans = log.answerable.astype(bool)
    hall = log.hallucinated[idx, actions]
    # hallucination defined on queries where refusal was appropriate
    unans = ~ans
    hall_rate = float(hall[unans].mean()) if unans.any() else 0.0
    hit = log.hit[idx, actions]
    # sized to the LOGGED action space (paper5's 5, hybrid9's 9, ...)
    dist = np.bincount(actions, minlength=log.n_actions) / n
    return PolicyReport(
        name=name,
        acc=float(log.correct[idx, actions].mean()),
        cost=float(log.cost[idx, actions].mean()),
        reward=float(r.mean()),
        refusal_rate=float(log.refused[idx, actions].mean()),
        hallucination_rate=hall_rate,
        hit_rate=float(hit[ans].mean()) if ans.any() else 0.0,
        action_dist=dist,
    )


def fixed_action_report(log: OfflineLog, action: int, profile: SLOProfile,
                        name: str = "") -> PolicyReport:
    acts = np.full(log.n, action, np.int64)
    return evaluate_actions(log, acts, profile,
                            name or f"fixed(a{action})")


def best_fixed_action(log: OfflineLog, profile: SLOProfile):
    """The single action maximizing average reward (paper §5.3)."""
    r = log.rewards(profile)
    means = r.mean(axis=0)
    a = int(np.argmax(means))
    return a, fixed_action_report(log, a, profile, f"best-fixed(a{a})")
