"""End-to-end paper experiment: build testbed → train policies → report.

Reproduces the paper's Table 1 grid: {quality_first, cheap} ×
{Baseline(a1), Best-fixed, Argmax-CE, Argmax-CE-WT} (+ beyond-paper
objectives), and the Figure 1 action distributions.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.core.actions import SLO_PROFILES
from repro.core.config import RouterConfig, TestbedConfig
from repro.core.metrics import (PolicyReport, best_fixed_action,
                                evaluate_actions, fixed_action_report)
from repro.core.offline_log import OfflineLog, build_testbed
from repro.routing.policy import MLPPolicy


@dataclass
class ExperimentResult:
    rows: List[dict] = field(default_factory=list)

    def add(self, slo: str, report: PolicyReport):
        self.rows.append({"slo": slo, **report.row()})

    def table(self) -> str:
        cols = ["slo", "method", "acc", "cost", "reward", "refuse",
                "hall", "hit"]
        lines = [" | ".join(f"{c:>13s}" for c in cols)]
        for r in self.rows:
            lines.append(" | ".join(f"{str(r[c]):>13s}" for c in cols))
        return "\n".join(lines)

    def save(self, path):
        Path(path).write_text(json.dumps(self.rows, indent=1))


def run_experiment(cfg: Optional[TestbedConfig] = None,
                   objectives=("argmax_ce", "argmax_ce_wt"),
                   include_mitigation: bool = False,
                   refusal_cap: float = 0.5,
                   verbose: bool = True):
    cfg = cfg or TestbedConfig()
    data, index, pipe, train_log, eval_log = build_testbed(cfg)
    res = ExperimentResult()
    extras: Dict[str, dict] = {"train_hist": {}, "action_dists": {},
                               "testbed": (data, index, pipe)}

    for slo_name, profile in SLO_PROFILES.items():
        # fixed baselines (paper §5.3)
        res.add(slo_name, fixed_action_report(eval_log, 1, profile,
                                              "baseline(a1)"))
        bf_a, bf_rep = best_fixed_action(eval_log, profile)
        res.add(slo_name, bf_rep)

        train_rewards = train_log.rewards(profile)
        objs = list(objectives)
        if include_mitigation:
            objs.append("constrained")
        for obj in objs:
            policy = MLPPolicy.train(train_log, train_rewards, cfg.router,
                                     objective=obj, refusal_cap=refusal_cap)
            acts = policy.actions(eval_log.states)
            rep = evaluate_actions(eval_log, acts, profile, obj)
            res.add(slo_name, rep)
            extras["train_hist"][f"{slo_name}/{obj}"] = \
                policy.train_result.history[-1]
            extras["action_dists"][f"{slo_name}/{obj}"] = \
                [float(x) for x in rep.action_dist]
        if verbose:
            print(f"[{slo_name}] best fixed = a{bf_a}")

    if verbose:
        print(res.table())
    return res, extras, (train_log, eval_log)
