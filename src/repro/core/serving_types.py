"""Shared serving-side record types."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RequestOutcome:
    qid: int
    action: int
    correct: bool
    refused: bool
    hallucinated: bool
    cost_tokens: float
    answerable: bool
    latency_ms: float = 0.0
