"""State representation s(q) — paper §3.3.

A hashed bag-of-words question embedding plus lightweight metadata
(length and uncertainty indicators computed from retrieval scores).
"""
from __future__ import annotations

import numpy as np

from repro.core.config import RouterConfig
from repro.data.tokenizer import words, _h
from repro.retrieval.bm25 import BM25Index

WH_WORDS = ("what", "who", "when", "where", "why", "how", "which")


def question_embedding(text: str, dim: int) -> np.ndarray:
    v = np.zeros(dim, np.float32)
    ws = words(text)
    for i, w in enumerate(ws):
        v[_h(w, dim)] += 1.0
        if i + 1 < len(ws):  # bigram channel
            v[_h(w + "_" + ws[i + 1], dim)] += 0.5
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


def metadata_features(text: str, index: BM25Index, n: int) -> np.ndarray:
    ws = words(text)
    stats = index.score_stats(text, k=5)          # max, mean, std, gap
    cooc = index.cooccurrence_stats(text, k=5)
    smax = stats[0] + 1e-6
    feats = [
        len(ws) / 20.0,
        len(text) / 120.0,
        float(any(w in WH_WORDS for w in ws)),
        float(ws[0] in WH_WORDS) if ws else 0.0,
        stats[0] / 10.0,
        stats[1] / 10.0,
        stats[2] / 10.0,
        stats[3] / 10.0,
        stats[3] / smax,                           # relative gap
        stats[1] / smax,                           # flatness
        float(len(set(ws)) / max(len(ws), 1)),
        float(sum(1 for w in ws if any(c.isdigit() for c in w))) / 5.0,
        float(cooc[0]), float(cooc[1]), float(cooc[2]), float(cooc[3]),
    ]
    feats = feats[:n] + [0.0] * max(0, n - len(feats))
    return np.asarray(feats, np.float32)


def state_vector(text: str, index: BM25Index, cfg: RouterConfig) -> np.ndarray:
    emb = question_embedding(text, cfg.embed_dim)
    meta = metadata_features(text, index, cfg.n_meta_features)
    return np.concatenate([emb, meta])
