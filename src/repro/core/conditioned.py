"""Beyond paper: a single SLO-conditioned policy.

The paper trains one policy per SLO profile.  Here the profile's weight
vector is appended to the state so ONE router serves every profile —
including interpolated profiles never seen at training time (the Pareto
sweep benchmark).  This is the natural production deployment: the SLO is
a request header, not a model version.

Serving-side access goes through the
:class:`repro.routing.policy.ConditionedPolicy` adapter, which wraps
``train_conditioned`` and appends the profile vector per request inside
``route``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.config import RouterConfig, SLOProfile
from repro.core.offline_log import OfflineLog
from repro.core.policy import TrainResult, policy_actions, train_policy


def profile_vector(p: SLOProfile) -> np.ndarray:
    return np.array([p.w_acc, p.w_cost, p.w_hall, p.w_ref, p.w_ref_wrong],
                    np.float32)


def conditioned_states(log: OfflineLog, p: SLOProfile) -> np.ndarray:
    v = np.tile(profile_vector(p)[None], (log.n, 1))
    return np.concatenate([log.states, v], axis=1)


def interpolate(a: SLOProfile, b: SLOProfile, t: float) -> SLOProfile:
    mix = lambda x, y: (1 - t) * x + t * y
    return SLOProfile(
        name=f"mix({a.name},{b.name},{t:.2f})",
        w_acc=mix(a.w_acc, b.w_acc), w_cost=mix(a.w_cost, b.w_cost),
        w_hall=mix(a.w_hall, b.w_hall), w_ref=mix(a.w_ref, b.w_ref),
        w_ref_wrong=mix(a.w_ref_wrong, b.w_ref_wrong))


def train_conditioned(log: OfflineLog, profiles: Sequence[SLOProfile],
                      cfg: RouterConfig, *, objective: str = "argmax_ce",
                      n_interp: int = 3) -> TrainResult:
    """Train one policy on the union of profile-conditioned examples.

    ``n_interp`` adds interpolated profiles between consecutive training
    profiles so the conditioning dimension is densely covered.
    """
    all_profiles: List[SLOProfile] = list(profiles)
    for a, b in zip(profiles[:-1], profiles[1:]):
        for i in range(1, n_interp + 1):
            all_profiles.append(interpolate(a, b, i / (n_interp + 1)))

    states = np.concatenate(
        [conditioned_states(log, p) for p in all_profiles], axis=0)
    rewards = np.concatenate([log.rewards(p) for p in all_profiles], axis=0)

    big = _concat_logs(log, len(all_profiles), states)
    ccfg = dataclasses.replace(
        cfg, state_dim=states.shape[1], condition_on_slo=True)
    return train_policy(big, rewards, ccfg, objective=objective), ccfg


def _concat_logs(log: OfflineLog, k: int, states: np.ndarray) -> OfflineLog:
    rep = lambda x: np.concatenate([x] * k, axis=0)
    return OfflineLog(states, rep(log.correct), rep(log.refused),
                      rep(log.hallucinated), rep(log.cost), rep(log.hit),
                      rep(log.answerable), rep(log.qids),
                      refuse_action=log.refuse_action)


def conditioned_actions(result: TrainResult, ccfg: RouterConfig,
                        log: OfflineLog, p: SLOProfile) -> np.ndarray:
    return policy_actions(result.params, conditioned_states(log, p), ccfg)
