"""Off-policy evaluation estimators — the paper's §8 "future work on
counterfactual estimators", implemented beyond the paper.

Because the testbed logs the FULL action sweep, the ground-truth value
of any deterministic policy is exactly computable; we can therefore
measure estimator error directly.  We synthesize a partial log by
sampling one action per state from a logging policy, then estimate the
target policy's value with IPS, SNIPS [Swaminathan & Joachims 2015] and
Doubly Robust [Dudík, Langford & Li 2011].
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.core.actions import N_ACTIONS


@dataclass
class PartialLog:
    actions: np.ndarray     # (N,) logged action
    rewards: np.ndarray     # (N,) observed reward
    propensity: np.ndarray  # (N,) logging prob of the logged action
    states: np.ndarray      # (N, d)


def make_logging_policy(n_actions: int = N_ACTIONS, kind: str = "uniform",
                        anchor: int = 1, eps: float = 0.25) -> np.ndarray:
    """Returns per-action probabilities (A,) of the logging policy."""
    if kind == "uniform":
        return np.full(n_actions, 1.0 / n_actions)
    if kind == "eps_anchor":  # mostly the paper's fixed baseline action
        p = np.full(n_actions, eps / n_actions)
        p[anchor] += 1.0 - eps
        return p
    raise ValueError(kind)


def sample_partial_log(full_rewards: np.ndarray, states: np.ndarray,
                       log_probs: np.ndarray, seed: int = 0) -> PartialLog:
    rng = np.random.default_rng(seed)
    n = len(full_rewards)
    acts = rng.choice(len(log_probs), size=n, p=log_probs)
    return PartialLog(
        actions=acts,
        rewards=full_rewards[np.arange(n), acts],
        propensity=log_probs[acts],
        states=states)


def true_value(full_rewards: np.ndarray, target_actions: np.ndarray) -> float:
    return float(full_rewards[np.arange(len(full_rewards)),
                              target_actions].mean())


def ips(log: PartialLog, target_actions: np.ndarray,
        clip: float = 50.0) -> float:
    match = (log.actions == target_actions).astype(np.float64)
    w = np.minimum(match / log.propensity, clip)
    return float(np.mean(w * log.rewards))


def snips(log: PartialLog, target_actions: np.ndarray,
          clip: float = 50.0) -> float:
    match = (log.actions == target_actions).astype(np.float64)
    w = np.minimum(match / log.propensity, clip)
    denom = np.mean(w)
    return float(np.mean(w * log.rewards) / max(denom, 1e-9))


def _ridge_q(log: PartialLog, lam: float = 1.0) -> np.ndarray:
    """Direct method: per-action ridge regression q̂(s, a).  Returns
    (N, A) predicted rewards."""
    n, d = log.states.shape
    q = np.zeros((n, N_ACTIONS))
    for a in range(N_ACTIONS):
        mask = log.actions == a
        if mask.sum() < 3:
            continue
        X = log.states[mask]
        y = log.rewards[mask]
        A = X.T @ X + lam * np.eye(d)
        beta = np.linalg.solve(A, X.T @ y)
        q[:, a] = log.states @ beta
    return q


def doubly_robust(log: PartialLog, target_actions: np.ndarray,
                  clip: float = 50.0) -> float:
    q = _ridge_q(log)
    n = len(target_actions)
    dm = q[np.arange(n), target_actions]
    match = (log.actions == target_actions).astype(np.float64)
    w = np.minimum(match / log.propensity, clip)
    corr = w * (log.rewards - q[np.arange(n), log.actions])
    return float(np.mean(dm + corr))


def estimator_suite(full_rewards: np.ndarray, states: np.ndarray,
                    target_actions: np.ndarray, *, kind: str = "uniform",
                    seeds: int = 20) -> Dict[str, Dict[str, float]]:
    """Bias/RMSE of each estimator over logging-seed replicates."""
    probs = make_logging_policy(kind=kind)
    truth = true_value(full_rewards, target_actions)
    res = {name: [] for name in ("ips", "snips", "dr")}
    for s in range(seeds):
        plog = sample_partial_log(full_rewards, states, probs, seed=s)
        res["ips"].append(ips(plog, target_actions))
        res["snips"].append(snips(plog, target_actions))
        res["dr"].append(doubly_robust(plog, target_actions))
    out = {"truth": {"value": truth, "bias": 0.0, "rmse": 0.0}}
    for name, vals in res.items():
        v = np.asarray(vals)
        out[name] = {"value": float(v.mean()),
                     "bias": float(v.mean() - truth),
                     "rmse": float(np.sqrt(((v - truth) ** 2).mean()))}
    return out
