"""Offline log generation (paper §4.1) and the logged-replay dataset.

For every question we execute ALL actions ("full action sweep") and
store per-action metrics; rewards are recomputed per SLO profile from
the stored indicators, exactly as the paper regenerates rewards without
re-calling the generator.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import List, Sequence

import numpy as np

from repro.core.actions import ACTIONS, N_ACTIONS, reward
from repro.core.config import RouterConfig, SLOProfile, TestbedConfig
from repro.core.features import state_vector
from repro.data.synthetic_squad import Question, SyntheticSquad
from repro.data.tokenizer import HashTokenizer
from repro.generation.simulator import SimulatedGenerator
from repro.retrieval.bm25 import BM25Index
from repro.serving.pipeline import RAGPipeline


@dataclass
class OfflineLog:
    states: np.ndarray        # (N, state_dim)
    correct: np.ndarray       # (N, A) bool
    refused: np.ndarray       # (N, A) bool
    hallucinated: np.ndarray  # (N, A) bool
    cost: np.ndarray          # (N, A) float
    hit: np.ndarray           # (N, A) bool
    answerable: np.ndarray    # (N,) bool
    qids: np.ndarray          # (N,)

    @property
    def n(self) -> int:
        return len(self.qids)

    def rewards(self, profile: SLOProfile) -> np.ndarray:
        """(N, A) reward matrix under an SLO profile (eq. 1)."""
        r = np.zeros((self.n, N_ACTIONS), np.float32)
        for i in range(self.n):
            for a in range(N_ACTIONS):
                r[i, a] = reward(
                    profile,
                    correct=bool(self.correct[i, a]),
                    cost_tokens=float(self.cost[i, a]),
                    hallucinated=bool(self.hallucinated[i, a]),
                    refused=bool(self.refused[i, a]),
                    answerable=bool(self.answerable[i]),
                    pre_retrieval=(a == 4))
        return r

    def subset(self, idx: np.ndarray) -> "OfflineLog":
        return OfflineLog(self.states[idx], self.correct[idx],
                          self.refused[idx], self.hallucinated[idx],
                          self.cost[idx], self.hit[idx],
                          self.answerable[idx], self.qids[idx])

    def save(self, path: str | Path):
        np.savez_compressed(path, **{k: getattr(self, k) for k in (
            "states", "correct", "refused", "hallucinated", "cost", "hit",
            "answerable", "qids")})

    @classmethod
    def load(cls, path: str | Path) -> "OfflineLog":
        z = np.load(path)
        return cls(**{k: z[k] for k in z.files})


def generate_log(questions: Sequence[Question], pipeline: RAGPipeline,
                 index: BM25Index, router_cfg: RouterConfig) -> OfflineLog:
    n = len(questions)
    states = np.zeros((n, router_cfg.state_dim), np.float32)
    correct = np.zeros((n, N_ACTIONS), bool)
    refused = np.zeros((n, N_ACTIONS), bool)
    hall = np.zeros((n, N_ACTIONS), bool)
    cost = np.zeros((n, N_ACTIONS), np.float32)
    hit = np.zeros((n, N_ACTIONS), bool)
    answerable = np.zeros(n, bool)
    qids = np.zeros(n, np.int64)

    for i, q in enumerate(questions):
        states[i] = state_vector(q.text, index, router_cfg)
        answerable[i] = q.answerable
        qids[i] = q.qid
        for out in pipeline.sweep(q):
            a = out.action
            correct[i, a] = out.correct
            refused[i, a] = out.refused
            hall[i, a] = out.hallucinated
            cost[i, a] = out.cost_tokens
            hit[i, a] = out.hit
    return OfflineLog(states, correct, refused, hall, cost, hit,
                      answerable, qids)


def build_testbed(cfg: TestbedConfig):
    """Corpus + index + pipeline + (train_log, eval_log)."""
    data = SyntheticSquad(
        n_paragraphs=cfg.n_paragraphs,
        n_questions=cfg.n_train + cfg.n_eval,
        answerable_frac=cfg.answerable_frac,
        seed=cfg.seed)
    index = BM25Index.build([p.text for p in data.paragraphs], cfg.retrieval)
    tok = HashTokenizer(32768)
    gen = SimulatedGenerator(tok, seed=cfg.seed)
    pipe = RAGPipeline(index, gen)
    train_q, eval_q = data.split(cfg.n_eval)
    train_log = generate_log(train_q, pipe, index, cfg.router)
    eval_log = generate_log(eval_q, pipe, index, cfg.router)
    return data, index, pipe, train_log, eval_log
