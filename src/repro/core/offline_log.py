"""Offline log generation (paper §4.1) and the logged-replay dataset.

For every question we execute ALL actions ("full action sweep") and
store per-action metrics; rewards are recomputed per SLO profile from
the stored indicators, exactly as the paper regenerates rewards without
re-calling the generator.

Logs are action-space generic: the sweep runs over any registered
:class:`~repro.routing.registry.ActionSpace` (the paper's ``paper5`` is
the default and reproduces bit-for-bit), and the log remembers which
action index is the pre-retrieval refusal so eq. (1)'s refusal-credit
scaling survives spaces where refuse is not action 4 (e.g. ``hybrid9``).
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional, Sequence

import numpy as np

from repro.core.actions import reward
from repro.core.config import RouterConfig, SLOProfile, TestbedConfig
from repro.core.features import state_vector
from repro.data.synthetic_squad import Question, SyntheticSquad
from repro.data.tokenizer import HashTokenizer
from repro.generation.simulator import SimulatedGenerator
from repro.retrieval.bm25 import BM25Index
from repro.routing.registry import ActionSpace, get_action_space
from repro.serving.pipeline import RAGPipeline

_SAVE_KEYS = ("states", "correct", "refused", "hallucinated", "cost",
              "hit", "answerable", "qids")


@dataclass
class OfflineLog:
    states: np.ndarray        # (N, state_dim)
    correct: np.ndarray       # (N, A) bool
    refused: np.ndarray       # (N, A) bool
    hallucinated: np.ndarray  # (N, A) bool
    cost: np.ndarray          # (N, A) float
    hit: np.ndarray           # (N, A) bool
    answerable: np.ndarray    # (N,) bool
    qids: np.ndarray          # (N,)
    # which action is the pre-retrieval refusal (paper5's action 4);
    # None = no refuse action in the logged space
    refuse_action: Optional[int] = 4

    @property
    def n(self) -> int:
        return len(self.qids)

    @property
    def n_actions(self) -> int:
        return self.correct.shape[1]

    def rewards(self, profile: SLOProfile) -> np.ndarray:
        """(N, A) reward matrix under an SLO profile (eq. 1)."""
        A = self.n_actions
        r = np.zeros((self.n, A), np.float32)
        for i in range(self.n):
            for a in range(A):
                r[i, a] = reward(
                    profile,
                    correct=bool(self.correct[i, a]),
                    cost_tokens=float(self.cost[i, a]),
                    hallucinated=bool(self.hallucinated[i, a]),
                    refused=bool(self.refused[i, a]),
                    answerable=bool(self.answerable[i]),
                    pre_retrieval=(a == self.refuse_action))
        return r

    def subset(self, idx: np.ndarray) -> "OfflineLog":
        return OfflineLog(self.states[idx], self.correct[idx],
                          self.refused[idx], self.hallucinated[idx],
                          self.cost[idx], self.hit[idx],
                          self.answerable[idx], self.qids[idx],
                          refuse_action=self.refuse_action)

    def save(self, path: str | Path):
        arrays = {k: getattr(self, k) for k in _SAVE_KEYS}
        # -1 encodes "no refuse action in this space" so None round-trips
        # (a missing key means a pre-PR-5 paper5 log: refuse at 4)
        arrays["refuse_action"] = np.int64(
            -1 if self.refuse_action is None else self.refuse_action)
        np.savez_compressed(path, **arrays)

    @classmethod
    def load(cls, path: str | Path) -> "OfflineLog":
        z = np.load(path)
        ra = int(z["refuse_action"]) if "refuse_action" in z.files else 4
        return cls(**{k: z[k] for k in _SAVE_KEYS},
                   refuse_action=None if ra < 0 else ra)


def generate_log(questions: Sequence[Question], pipeline: RAGPipeline,
                 index: BM25Index, router_cfg: RouterConfig,
                 space: Optional[ActionSpace] = None) -> OfflineLog:
    space = space if space is not None else get_action_space()
    n, A = len(questions), len(space)
    states = np.zeros((n, router_cfg.state_dim), np.float32)
    correct = np.zeros((n, A), bool)
    refused = np.zeros((n, A), bool)
    hall = np.zeros((n, A), bool)
    cost = np.zeros((n, A), np.float32)
    hit = np.zeros((n, A), bool)
    answerable = np.zeros(n, bool)
    qids = np.zeros(n, np.int64)

    for i, q in enumerate(questions):
        states[i] = state_vector(q.text, index, router_cfg)
        answerable[i] = q.answerable
        qids[i] = q.qid
        for out in pipeline.sweep(q, space):
            a = out.action
            correct[i, a] = out.correct
            refused[i, a] = out.refused
            hall[i, a] = out.hallucinated
            cost[i, a] = out.cost_tokens
            hit[i, a] = out.hit
    return OfflineLog(states, correct, refused, hall, cost, hit,
                      answerable, qids, refuse_action=space.refuse_action)


def build_testbed(cfg: TestbedConfig, space: Optional[ActionSpace] = None):
    """Corpus + index + pipeline + (train_log, eval_log).

    ``space=None`` is the paper's registered default (bit-for-bit).  A
    space whose actions reference the ``dense``/``hybrid`` retrievers
    (e.g. ``hybrid9``) additionally builds the dense index and wires
    the full retriever suite into the pipeline.
    """
    data = SyntheticSquad(
        n_paragraphs=cfg.n_paragraphs,
        n_questions=cfg.n_train + cfg.n_eval,
        answerable_frac=cfg.answerable_frac,
        seed=cfg.seed)
    texts = [p.text for p in data.paragraphs]
    index = BM25Index.build(texts, cfg.retrieval)
    retrievers = None
    if space is not None and set(space.retriever_names) - {"bm25"}:
        from repro.retrieval.dense import DenseIndex
        from repro.retrieval.hybrid import build_retriever_suite
        retrievers = build_retriever_suite(
            index, DenseIndex.build(texts, cfg.retrieval))
    tok = HashTokenizer(32768)
    gen = SimulatedGenerator(tok, seed=cfg.seed)
    pipe = RAGPipeline(index, gen, retrievers)
    train_q, eval_q = data.split(cfg.n_eval)
    train_log = generate_log(train_q, pipe, index, cfg.router, space)
    eval_log = generate_log(eval_q, pipe, index, cfg.router, space)
    return data, index, pipe, train_log, eval_log
