"""The paper's action space and SLO profiles (§3.1, §3.2).

Since the Unified Router API, the action space and the SLO profiles
live in the ``repro.routing`` registry (``repro/routing/registry.py``);
this module re-exports the paper defaults so every existing import
keeps working:

* ``ACTIONS`` / ``N_ACTIONS`` / ``REFUSE_ACTION`` — the registered
  ``"paper5"`` action space;
* ``SLO_PROFILES`` — the LIVE profile registry dict (profiles
  registered through ``repro.routing.register_slo_profile`` appear
  here too);
* ``reward`` — eq. (1), unchanged.

New code should prefer ``repro.routing.get_action_space()`` /
``get_slo_profile()``.
"""
from __future__ import annotations

from repro.core.config import SLOProfile
from repro.routing.registry import (Action, ActionSpace,  # noqa: F401
                                    PAPER_ACTION_SPACE, SLO_PROFILES,
                                    get_action_space, get_slo_profile,
                                    register_slo_profile)

# Action 0..4 exactly as in the paper §3.1, via the default registry
# entry — paper numbers reproduce bit-for-bit through the registry.
ACTIONS = PAPER_ACTION_SPACE.actions
N_ACTIONS = PAPER_ACTION_SPACE.n_actions
REFUSE_ACTION = PAPER_ACTION_SPACE.refuse_action


def reward(profile: SLOProfile, *, correct: bool, cost_tokens: float,
           hallucinated: bool, refused: bool, answerable: bool,
           pre_retrieval: bool = False) -> float:
    """Eq. (1):  r = w_acc·Acc − w_cost·Cost − w_hall·Hall + w_ref·Ref.

    Ref credits correct refusals and penalizes incorrect ones (paper
    §3.2: "captures correct refusals (and penalizes incorrect
    refusals)").  Pre-retrieval refusals earn scaled credit (§3.1's
    refusal-semantics distinction).
    """
    acc = 1.0 if correct else 0.0
    hall = 1.0 if hallucinated else 0.0
    if refused:
        if answerable:
            ref = -profile.w_ref_wrong
        else:
            ref = profile.w_ref * (profile.w_ref_pre_scale
                                   if pre_retrieval else 1.0)
    else:
        ref = 0.0
    return (profile.w_acc * acc
            - profile.w_cost * cost_tokens / profile.cost_scale
            - profile.w_hall * hall
            + ref)
