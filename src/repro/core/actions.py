"""The paper's action space and SLO profiles (§3.1, §3.2)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.config import SLOProfile


@dataclass(frozen=True)
class Action:
    idx: int
    k: int            # retrieval depth (0 = no retrieval)
    mode: str         # guarded | auto | refuse


# Action 0..4 exactly as in the paper §3.1.
ACTIONS = (
    Action(0, 2, "guarded"),
    Action(1, 5, "guarded"),
    Action(2, 10, "guarded"),
    Action(3, 5, "auto"),
    Action(4, 0, "refuse"),
)
N_ACTIONS = len(ACTIONS)
REFUSE_ACTION = 4


# SLO profiles (§3.2): quality_first weighs correctness / hallucination
# avoidance; cheap weighs token cost and rewards refusal heavily — the
# configuration under which the paper observes refusal collapse.
SLO_PROFILES: Dict[str, SLOProfile] = {
    "quality_first": SLOProfile(
        name="quality_first",
        w_acc=1.0, w_cost=0.1, w_hall=0.25, w_ref=0.1, w_ref_wrong=0.15),
    "cheap": SLOProfile(
        name="cheap",
        w_acc=0.3, w_cost=0.8, w_hall=0.3, w_ref=0.35, w_ref_wrong=1.0),
}


def reward(profile: SLOProfile, *, correct: bool, cost_tokens: float,
           hallucinated: bool, refused: bool, answerable: bool,
           pre_retrieval: bool = False) -> float:
    """Eq. (1):  r = w_acc·Acc − w_cost·Cost − w_hall·Hall + w_ref·Ref.

    Ref credits correct refusals and penalizes incorrect ones (paper
    §3.2: "captures correct refusals (and penalizes incorrect
    refusals)").  Pre-retrieval refusals earn scaled credit (§3.1's
    refusal-semantics distinction).
    """
    acc = 1.0 if correct else 0.0
    hall = 1.0 if hallucinated else 0.0
    if refused:
        if answerable:
            ref = -profile.w_ref_wrong
        else:
            ref = profile.w_ref * (profile.w_ref_pre_scale
                                   if pre_retrieval else 1.0)
    else:
        ref = 0.0
    return (profile.w_acc * acc
            - profile.w_cost * cost_tokens / profile.cost_scale
            - profile.w_hall * hall
            + ref)
