"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
paper's SLO-routing testbed is a :class:`RouterConfig` +
:class:`SLOProfile`.  Configs are plain frozen dataclasses so they can be
hashed into jit static args and printed into EXPERIMENTS.md verbatim.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model-zoo configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3, MiniCPM3)."""

    kv_lora_rank: int = 512          # latent dim c_KV
    q_lora_rank: int = 0             # 0 = no q compression
    qk_nope_head_dim: int = 128      # non-rope portion of q/k head
    qk_rope_head_dim: int = 64       # decoupled rope portion
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config."""

    n_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0             # per-expert hidden dim
    n_shared_experts: int = 0        # DeepSeek-style always-on experts
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01  # aux loss weight
    # Layer indices (mod moe_period) that are MoE; dense otherwise.
    moe_period: int = 1              # 1 = every layer is MoE
    moe_offset: int = 0
    first_k_dense: int = 0           # DeepSeek-V3: first 3 layers dense


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD config."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    n_groups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"         # dense|moe|ssm|hybrid|audio|vlm
    source: str = ""                 # citation for the config values

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                # 0 → d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention flavour
    attn_type: str = "gqa"           # gqa|mla|none
    qkv_bias: bool = False           # Qwen1.5
    attn_logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    # sliding window attention: 0 = full attention everywhere
    sliding_window: int = 0
    # query-chunk size for the chunked-softmax attention path
    attn_q_chunk: int = 1024
    # serve sliding-window layers from a ring buffer of size `window`
    # instead of a full max_len cache (§Perf H4)
    window_ring_cache: bool = False
    # route eligible compute through the Pallas kernels (TPU target;
    # interpret-mode on CPU — used by tests/examples, off by default)
    use_pallas_attention: bool = False
    use_pallas_ssd: bool = False
    # route the S==1 cached-decode attention through the Pallas
    # flash-decode kernel (split-KV online softmax over the slot cache
    # with per-slot length masking); dense jnp path is the oracle
    use_flash_decode: bool = False
    # store GQA decode caches int8 with per-(batch, pos, head) f16
    # absmax scales (serving/kv_quant.py): ~2x less cache HBM + read
    # traffic at a dequant multiply per read.  Applies to the standard
    # slot-cache path (not ring-buffer windowed layers, not MLA);
    # greedy decode parity is smoke-tested at smoke-model scale
    kv_quant_int8: bool = False
    # §Perf H6: one-hot-matmul embedding lookup instead of gather — XLA
    # SPMD can keep a (vocab->model, d->data)-sharded table sharded for
    # a matmul but replicates it for a gather; trades extra MXU flops
    # for the table all-gather
    embed_one_hot: bool = False
    # layer pattern for local/global mixes, e.g. ("L","L","L","L","L","G")
    # repeated across depth; empty → all "G" (global/full)
    attn_pattern: Tuple[str, ...] = ()

    # hybrid (Jamba) pattern: per-layer "A" (attention) or "M" (mamba),
    # repeated; empty → homogeneous per arch_type
    layer_pattern: Tuple[str, ...] = ()

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # encoder-decoder (Whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper: 30 s audio → 1500 frames

    # multimodal stub frontends
    modality: str = "text"           # text|audio|vision
    n_modality_tokens: int = 0       # patch/frame embeddings prepended
    modality_embed_dim: int = 0      # raw frontend embedding dim (projector in)

    # misc
    use_bias: bool = False           # dense layers bias (command-r: False)
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    mtp_depth: int = 0               # DeepSeek-V3 multi-token prediction heads
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256

    # remat policy for training: "none" | "full" | "dots"
    remat: str = "none"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    def layer_kind(self, i: int) -> str:
        """'A' attention / 'M' mamba for layer i."""
        if self.layer_pattern:
            return self.layer_pattern[i % len(self.layer_pattern)]
        return "M" if self.arch_type == "ssm" else "A"

    def attn_kind(self, i: int) -> str:
        """'G' global / 'L' local(sliding) for attention layer i."""
        if self.attn_pattern:
            return self.attn_pattern[i % len(self.attn_pattern)]
        return "L" if self.sliding_window else "G"

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if i < self.moe.first_k_dense:
            return False
        j = i - self.moe.first_k_dense
        return j % self.moe.moe_period == self.moe.moe_offset

    def n_params(self) -> int:
        """Approximate parameter count (for roofline 6ND napkin math)."""
        d, v = self.d_model, self.padded_vocab
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for i in range(self.n_layers):
            total += self._layer_params(i)
        if self.is_encoder_decoder:
            for i in range(self.n_encoder_layers):
                total += self._attn_params() + 3 * d * self.d_ff
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE top-k only)."""
        d, v = self.d_model, self.padded_vocab
        total = v * d
        for i in range(self.n_layers):
            total += self._layer_params(i, active_only=True)
        return total

    def _attn_params(self) -> int:
        d = self.d_model
        if self.attn_type == "mla":
            m = self.mla
            qd = m.qk_nope_head_dim + m.qk_rope_head_dim
            q_in = m.q_lora_rank or d
            p = (d * m.q_lora_rank if m.q_lora_rank else 0)
            p += q_in * self.n_heads * qd
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += self.n_heads * m.v_head_dim * d
            return p
        hd = self.head_dim
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

    def _ssm_params(self) -> int:
        s = self.ssm
        d_inner = s.expand * self.d_model
        nheads = d_inner // s.head_dim
        p = self.d_model * (2 * d_inner + 2 * s.n_groups * s.d_state + nheads)
        p += d_inner * self.d_model  # out proj
        return p

    def _layer_params(self, i: int, active_only: bool = False) -> int:
        d = self.d_model
        kind = self.layer_kind(i)
        p = self._ssm_params() if kind == "M" else self._attn_params()
        if self.is_moe_layer(i):
            e = self.moe
            n_e = e.top_k if active_only else e.n_experts
            p += 3 * d * e.d_ff_expert * (n_e + e.n_shared_experts)
            p += d * e.n_experts  # router
        elif kind == "A" or self.arch_type != "ssm":
            p += 3 * d * self.d_ff
        return p


# ---------------------------------------------------------------------------
# Paper-core configs (SLO routing)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SLOProfile:
    """SLO weight vector — eq. (1) of the paper."""

    name: str
    w_acc: float
    w_cost: float     # applied to cost_tokens / cost_scale
    w_hall: float
    w_ref: float      # reward for a correct refusal
    w_ref_wrong: float = 0.0  # penalty weight for refusing an answerable q
    # Pre-retrieval (action-4) refusals earn scaled credit: an informed
    # post-retrieval "I don't know" is worth more than a blind refusal
    # (paper §3.1 distinguishes the two refusal kinds).
    w_ref_pre_scale: float = 0.5
    cost_scale: float = 1000.0  # tokens are divided by this before weighting
    # Mitigation (beyond baseline paper objectives): cap on refusal rate
    # enforced with a Lagrangian penalty during policy training.
    max_refusal_rate: float = 1.0


@dataclass(frozen=True)
class RouterConfig:
    """The paper's controller: MLP over state features → 5 actions."""

    state_dim: int = 272            # 256-d query embedding + 16 metadata
    embed_dim: int = 256
    n_meta_features: int = 16
    hidden_dims: Tuple[int, ...] = (128, 64)
    n_actions: int = 5
    dropout: float = 0.0
    # objective: argmax_ce | argmax_ce_wt | reward_weighted | constrained
    objective: str = "argmax_ce"
    margin_temp: float = 1.0        # WT weighting temperature
    lr: float = 3e-4
    batch_size: int = 64
    n_epochs: int = 30
    weight_decay: float = 1e-4
    seed: int = 0
    # SLO-conditioning (beyond paper): feed the SLO weight vector into the
    # state so one policy serves all profiles.
    condition_on_slo: bool = False


@dataclass(frozen=True)
class RetrievalConfig:
    vocab_hash_dim: int = 4096      # hashed lexical vocab (128-aligned)
    k1: float = 1.2                 # BM25 params [Robertson & Zaragoza 2009]
    b: float = 0.75
    max_k: int = 10
    # dense retriever: hashed signed n-gram embedding dim (128-aligned
    # so the (D, E) doc matrix feeds the Pallas dense_topk kernel)
    dense_embed_dim: int = 256
    # hybrid fusion: "rrf" (reciprocal rank) | "weighted" (normalized
    # score mix); bm25 weight for "weighted" (dense gets 1 - alpha)
    hybrid_method: str = "rrf"
    hybrid_alpha: float = 0.5


@dataclass(frozen=True)
class TestbedConfig:
    """End-to-end paper testbed: corpus + retrieval + generator + router."""

    # not a pytest test class, despite the name (silences collection warning)
    __test__ = False

    n_train: int = 800
    n_eval: int = 200               # paper: N=200 dev examples
    n_paragraphs: int = 600
    answerable_frac: float = 0.5    # SQuAD2 dev is ~50/50
    seed: int = 0
    retrieval: RetrievalConfig = field(default_factory=RetrievalConfig)
    router: RouterConfig = field(default_factory=RouterConfig)
    generator_backend: str = "simulator"   # simulator | local_model


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
