"""Fault taxonomy shared across the serving, routing, and retrieval
layers.

Lives in ``core`` (stdlib-only, no heavy deps) so the retrieval layer
can raise/catch these without importing the serving package and vice
versa — the chaos injector (``repro.serving.faults``), the circuit
breakers (``repro.retrieval.hybrid``), and the gateways' retry paths
all key on :class:`TransientFaultError`.
"""
from __future__ import annotations


class FaultError(RuntimeError):
    """Base class for injected and detected serving-plane faults."""


class TransientFaultError(FaultError):
    """A fault worth retrying: the operation may succeed if repeated
    (retriever brownout, timeout, transient executor failure).  The
    gateway retry path and the circuit breakers key on this type."""


class FaultTimeoutError(TransientFaultError):
    """An injected (or detected) operation timeout."""


class CircuitOpenError(TransientFaultError):
    """A call was refused because the target's circuit breaker is
    open — transient by definition: the breaker will probe again."""

    def __init__(self, name: str, message: str = ""):
        super().__init__(message or f"circuit open for {name!r}")
        self.name = name
