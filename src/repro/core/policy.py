"""SLO-conditioned routing policies (paper §4.2) in JAX.

The controller is a small MLP over s(q) producing a categorical
distribution over the 5 actions.  Objectives:

* ``argmax_ce``     — supervised classification of the per-state best
                      action (paper's Argmax-CE);
* ``argmax_ce_wt``  — CE weighted by the best-vs-second action margin
                      (paper's Argmax-CE-WT);
* ``soft_reward``   — reward-softmax soft targets (paper §4.2's
                      reward-weighted variant);
* ``constrained``   — beyond-paper mitigation for refusal collapse:
                      Argmax-CE with a Lagrangian cap on the expected
                      refusal probability (paper §7.1 calls for "a
                      calibrated abstention constraint").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.actions import N_ACTIONS, REFUSE_ACTION
from repro.core.config import RouterConfig
from repro.core.offline_log import OfflineLog
from repro.models.schema import ParamSpec, init_from_schema


def policy_schema(cfg: RouterConfig):
    dims = (cfg.state_dim,) + cfg.hidden_dims + (cfg.n_actions,)
    schema = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        schema[f"w{i}"] = ParamSpec((a, b), ("", ""), "float32",
                                    "normal", scale=float(np.sqrt(2.0 / a)))
        schema[f"b{i}"] = ParamSpec((b,), ("",), "float32", "zeros")
    return schema


def init_policy(key, cfg: RouterConfig):
    return init_from_schema(key, policy_schema(cfg))


def policy_logits(params, states, cfg: RouterConfig):
    x = states
    n_layers = len(cfg.hidden_dims) + 1
    for i in range(n_layers):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x


def policy_actions(params, states, cfg: RouterConfig) -> np.ndarray:
    logits = policy_logits(params, jnp.asarray(states), cfg)
    return np.asarray(jnp.argmax(logits, axis=-1))


# ---------------------------------------------------------------------------
# Objectives
# ---------------------------------------------------------------------------


def make_targets(rewards: np.ndarray, objective: str,
                 margin_temp: float = 1.0):
    """Per-example targets/weights from the (N, A) reward matrix."""
    order = np.argsort(-rewards, axis=1)
    best = order[:, 0]
    second = order[:, 1]
    n = len(rewards)
    margin = rewards[np.arange(n), best] - rewards[np.arange(n), second]
    if objective in ("argmax_ce", "constrained"):
        w = np.ones(n, np.float32)
    elif objective == "argmax_ce_wt":
        w = (margin / (margin.mean() + 1e-8)) ** margin_temp
        w = w.astype(np.float32)
    elif objective == "soft_reward":
        w = np.ones(n, np.float32)
    else:
        raise ValueError(objective)
    soft = None
    if objective == "soft_reward":
        z = rewards / max(margin_temp, 1e-3)
        z = z - z.max(axis=1, keepdims=True)
        soft = (np.exp(z) / np.exp(z).sum(axis=1, keepdims=True)).astype(np.float32)
    return best.astype(np.int64), w, soft


@dataclass
class TrainResult:
    params: Dict
    history: list
    lagrange: float = 0.0


def train_policy(log: OfflineLog, rewards: np.ndarray, cfg: RouterConfig,
                 *, objective: Optional[str] = None,
                 refusal_cap: float = 1.0, dual_lr: float = 8.0,
                 seed: Optional[int] = None,
                 refuse_action: Optional[int] = None) -> TrainResult:
    """Minibatch Adam training of the routing MLP on the offline log.

    ``refuse_action`` is the action index the Lagrangian refusal terms
    watch; the default resolves to the logged space's refuse action
    (falling back to the paper's action 4 for legacy logs without the
    field), so non-paper5 spaces — where refuse is not index 4 —
    constrain the right logit.  A log whose space has NO refuse action
    (``log.refuse_action is None``) disables the refusal term entirely
    instead of penalizing whatever action sits at index 4.
    """
    objective = objective or cfg.objective
    seed = cfg.seed if seed is None else seed
    if refuse_action is None:
        refuse_action = getattr(log, "refuse_action", REFUSE_ACTION)
    ra = None if refuse_action is None else int(refuse_action)
    assert ra is None or ra < cfg.n_actions, (ra, cfg.n_actions)
    best, w, soft = make_targets(rewards, objective, cfg.margin_temp)

    states = jnp.asarray(log.states)
    best_j = jnp.asarray(best)
    w_j = jnp.asarray(w)
    soft_j = None if soft is None else jnp.asarray(soft)

    params = init_policy(jax.random.PRNGKey(seed), cfg)
    opt = {"m": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params),
           "v": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params),
           "t": jnp.zeros((), jnp.int32)}

    def loss_fn(params, sb, tb, wb, softb, lam):
        logits = policy_logits(params, sb, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        if softb is not None:
            ce = -jnp.sum(softb * logp, axis=-1)
        else:
            ce = -jnp.take_along_axis(logp, tb[:, None], axis=-1)[:, 0]
        loss = jnp.mean(wb * ce)
        # weight decay
        l2 = sum(jnp.sum(p ** 2) for k, p in params.items() if k.startswith("w"))
        loss = loss + cfg.weight_decay * l2
        if ra is None:      # refuse-free space: no refusal term at all
            p_refuse = jnp.zeros(())
        else:
            p_refuse = jnp.mean(jnp.exp(logp[:, ra]))
        loss = loss + lam * p_refuse
        return loss, p_refuse

    @jax.jit
    def step(params, opt, sb, tb, wb, softb, lam):
        (loss, p_ref), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, sb, tb, wb, softb, lam)
        t = opt["t"] + 1
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree_util.tree_map(lambda m_, g_: b1 * m_ + (1 - b1) * g_,
                                   opt["m"], g)
        v = jax.tree_util.tree_map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ ** 2,
                                   opt["v"], g)
        tf = t.astype(jnp.float32)
        params = jax.tree_util.tree_map(
            lambda p, m_, v_: p - cfg.lr * (m_ / (1 - b1 ** tf))
            / (jnp.sqrt(v_ / (1 - b2 ** tf)) + eps),
            params, m, v)
        return params, {"m": m, "v": v, "t": t}, loss, p_ref

    n = log.n
    rng = np.random.default_rng(seed)
    lam = 0.0
    history = []
    for epoch in range(cfg.n_epochs):
        perm = rng.permutation(n)
        losses, prefs = [], []
        for s0 in range(0, n, cfg.batch_size):
            mb = perm[s0: s0 + cfg.batch_size]
            sb = states[mb]
            tb = best_j[mb]
            wb = w_j[mb]
            softb = None if soft_j is None else soft_j[mb]
            params, opt, loss, p_ref = step(params, opt, sb, tb, wb, softb,
                                            jnp.float32(lam))
            losses.append(float(loss))
            prefs.append(float(p_ref))
        avg_ref = float(np.mean(prefs))
        if objective == "constrained":
            lam = max(0.0, lam + dual_lr * (avg_ref - refusal_cap))
        history.append({"epoch": epoch, "loss": float(np.mean(losses)),
                        "p_refuse": avg_ref, "lambda": lam})
    return TrainResult(params=params, history=history, lagrange=lam)
