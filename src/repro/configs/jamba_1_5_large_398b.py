"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7, MoE 16e top-2.
[arXiv:2403.19887]

Block of 8 layers: attention at position 4 (attn_layer_period=8,
offset=4), MoE on odd positions (expert_layer_period=2, offset=1).
"""
from repro.core.config import ModelConfig, MoEConfig, SSMConfig

_PATTERN = ("M", "M", "M", "M", "A", "M", "M", "M")

FULL = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attn_type="gqa",
    layer_pattern=_PATTERN,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576,
                  moe_period=2, moe_offset=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    remat="full",
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    arch_type="hybrid",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    attn_type="gqa",
    layer_pattern=("M", "A"),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=512,
                  moe_period=2, moe_offset=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk_size=64),
    vocab_pad_multiple=64,
)
