"""Assigned-architecture configs (``--arch <id>``) + the paper testbed.

Each module exposes ``FULL`` (the exact assigned configuration, cited)
and ``SMOKE`` (a reduced same-family variant: ≤2 layers, d_model ≤ 512,
≤4 experts) used by the per-arch CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.core.config import ModelConfig

ARCH_IDS: List[str] = [
    "dbrx-132b",
    "minicpm3-4b",
    "whisper-large-v3",
    "jamba-1.5-large-398b",
    "phi-3-vision-4.2b",
    "command-r-35b",
    "mamba2-130m",
    "deepseek-v3-671b",
    "gemma3-12b",
    "qwen1.5-32b",
]

_MODULES: Dict[str, str] = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str, variant: str = "full") -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return getattr(mod, variant.upper())


# Input shapes assigned to this paper (global batch × sequence).
INPUT_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# long_500k needs sub-quadratic attention / bounded KV growth — see
# DESIGN.md §Dry-run shape skips.
LONG_CONTEXT_ARCHS = {"mamba2-130m", "jamba-1.5-large-398b", "gemma3-12b"}


def shape_supported(arch_id: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True
