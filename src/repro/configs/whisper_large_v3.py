"""Whisper-large-v3 — enc-dec audio; conv frontend stubbed. [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, 1500, d_model).
We implement the transformer backbone (32 encoder + 32 decoder layers).
"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=32,               # decoder
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    attn_type="gqa",
    is_encoder_decoder=True,
    encoder_seq_len=1500,      # 30 s audio → 1500 frames
    modality="audio",
    modality_embed_dim=1280,
    remat="full",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    arch_type="audio",
    n_layers=2,
    n_encoder_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    attn_type="gqa",
    is_encoder_decoder=True,
    encoder_seq_len=64,
    modality="audio",
    modality_embed_dim=256,
    vocab_pad_multiple=64,
)
