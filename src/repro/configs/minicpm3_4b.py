"""MiniCPM3-4B — dense with MLA. [hf:openbmb/MiniCPM3-4B]"""
from repro.core.config import MLAConfig, ModelConfig

FULL = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    source="hf:openbmb/MiniCPM3-4B",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,  # qk_nope(64) + qk_rope(32)
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    remat="full",
)

SMOKE = ModelConfig(
    name="minicpm3-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    head_dim=48,
    d_ff=512,
    vocab_size=512,
    attn_type="mla",
    mla=MLAConfig(kv_lora_rank=64, q_lora_rank=96,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
    vocab_pad_multiple=64,
)
