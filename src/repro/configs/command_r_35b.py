"""Command-R 35B — dense GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="command-r-35b",
    arch_type="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    attn_type="gqa",
    use_bias=False,
    tie_embeddings=True,
    rope_theta=8000000.0,
    remat="full",
)

SMOKE = ModelConfig(
    name="command-r-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    attn_type="gqa",
    use_bias=False,
    vocab_pad_multiple=64,
)
