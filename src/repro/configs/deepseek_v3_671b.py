"""DeepSeek-V3 671B — MLA, 1 shared + 256 routed top-8 fine-grained MoE,
multi-token prediction. [arXiv:2412.19437]

Assigned spec: 61L d_model=7168 128H d_ff=2048 (= per-expert hidden)
vocab=129280.  First 3 layers dense (intermediate 18432 per the paper).
"""
from repro.core.config import MLAConfig, ModelConfig, MoEConfig

FULL = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    source="arXiv:2412.19437",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,              # qk_nope(128) + qk_rope(64)
    d_ff=18432,                # dense-prefix MLP width (paper §4)
    vocab_size=129280,
    attn_type="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, first_k_dense=3),
    mtp_depth=1,
    tie_embeddings=False,
    remat="full",
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    arch_type="moe",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    head_dim=48,
    d_ff=512,
    vocab_size=512,
    attn_type="mla",
    mla=MLAConfig(kv_lora_rank=64, q_lora_rank=96,
                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                  n_shared_experts=1, first_k_dense=1),
    mtp_depth=1,
    tie_embeddings=False,
    vocab_pad_multiple=64,
)
