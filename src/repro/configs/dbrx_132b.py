"""DBRX-132B — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]"""
from repro.core.config import ModelConfig, MoEConfig

FULL = ModelConfig(
    name="dbrx-132b",
    arch_type="moe",
    source="hf:databricks/dbrx-base",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    attn_type="gqa",
    rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
    remat="full",
)

SMOKE = ModelConfig(
    name="dbrx-smoke",
    arch_type="moe",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    attn_type="gqa",
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=512),
    vocab_pad_multiple=64,
)
