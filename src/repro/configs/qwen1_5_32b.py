"""Qwen1.5-32B — dense with QKV bias. [hf:Qwen/Qwen1.5-0.5B family]"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    attn_type="gqa",
    qkv_bias=True,
    rope_theta=1000000.0,
    remat="full",
)

SMOKE = ModelConfig(
    name="qwen-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    attn_type="gqa",
    qkv_bias=True,
    vocab_pad_multiple=64,
)
