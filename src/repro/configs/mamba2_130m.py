"""Mamba2-130M — attention-free SSD. [arXiv:2405.21060]"""
from repro.core.config import ModelConfig, SSMConfig

FULL = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    source="arXiv:2405.21060",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                    # pure mamba blocks, no MLP
    vocab_size=50280,
    attn_type="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256),
    remat="full",
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    arch_type="ssm",
    n_layers=2,
    d_model=256,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=512,
    attn_type="none",
    ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=64, chunk_size=64),
    vocab_pad_multiple=64,
)
