"""Gemma3-12B — 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt family card; 12B variant]
"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    attn_type="gqa",
    sliding_window=1024,
    attn_pattern=("L", "L", "L", "L", "L", "G"),
    rope_theta=1000000.0,
    remat="full",
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    arch_type="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    attn_type="gqa",
    sliding_window=32,
    attn_pattern=("L", "G"),
    vocab_pad_multiple=64,
)
