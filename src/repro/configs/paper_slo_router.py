"""The paper's own configuration: the SLO-routing testbed.

Unlike the 10 assigned transformer architectures, the paper's
"architecture" is a control system: the 5-action space, two SLO
profiles, the BM25 retriever, and the Argmax-CE router MLP.  This module
pins the canonical hyperparameters used throughout EXPERIMENTS.md §Paper.
"""
from repro.core.config import (RetrievalConfig, RouterConfig, TestbedConfig)

# Canonical testbed: N=200 eval (paper §5.1), 800 train, 600 paragraphs.
FULL = TestbedConfig(
    n_train=800,
    n_eval=200,
    n_paragraphs=600,
    answerable_frac=0.5,
    seed=0,
    retrieval=RetrievalConfig(vocab_hash_dim=4096, k1=1.2, b=0.75, max_k=10),
    router=RouterConfig(
        state_dim=272, embed_dim=256, n_meta_features=16,
        hidden_dims=(128, 64), n_actions=5,
        objective="argmax_ce", lr=3e-4, batch_size=64, n_epochs=30),
)

# Reduced variant for smoke tests / quickstart.
SMOKE = TestbedConfig(
    n_train=120, n_eval=60, n_paragraphs=120,
    router=RouterConfig(n_epochs=8),
)
