"""Phi-3-vision-4.2B — phi3-mini backbone + CLIP vision stub.
[hf:microsoft/Phi-3-vision-128k-instruct]

The ViT/SigLIP encoder is a STUB per the assignment: ``input_specs``
provides precomputed patch embeddings (B, 576, 1024); we implement the
projector + language decoder.
"""
from repro.core.config import ModelConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    attn_type="gqa",
    modality="vision",
    n_modality_tokens=576,     # CLIP ViT-L/14 @ 336px
    modality_embed_dim=1024,
    remat="full",
)

SMOKE = ModelConfig(
    name="phi3v-smoke",
    arch_type="vlm",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    head_dim=64,
    d_ff=512,
    vocab_size=512,
    attn_type="gqa",
    modality="vision",
    n_modality_tokens=16,
    modality_embed_dim=64,
    vocab_pad_multiple=64,
)
