"""Typed metrics registry with Prometheus-style exposition.

One ``MetricsRegistry`` is shared across the serving stack so the
formerly disconnected stat blocks (``GatewayStats``, ``EngineStats``,
``PagePool`` occupancy, breaker trip counts, chaos fire counts) become
views over a single exportable surface.  Two usage modes:

* **Direct instruments** — ``registry.counter(...)``, ``.gauge(...)``,
  ``.histogram(...)`` hand back mutable instruments updated on the hot
  path (e.g. per-request latency histograms).
* **Collectors** — ``registry.register_collector(fn)`` registers a
  callback run at scrape time (``collect()``).  The hot path keeps
  mutating its cheap dataclass counters; the callback copies them into
  gauges only when someone actually asks for an exposition/snapshot.
  This is the standard Prometheus client pattern and keeps the
  instrumented loops allocation-free.

Stdlib-only by design: the linter's ``static-analysis`` CI job runs
reprolint with no third-party installs, and reprolint imports nothing
from here — but tests for this module must run everywhere.
"""
from __future__ import annotations

import json
import math
import re
from bisect import bisect_left
from typing import Callable, Dict, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")

# Default latency-ish bucket upper bounds (ms).  Callers can pass their
# own; merge() requires identical bounds.
DEFAULT_BUCKETS_MS: Tuple[float, ...] = (
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
    1000.0, 2000.0, 5000.0)


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(
            f"metric name {name!r} is not lowercase_snake "
            f"(must match {_NAME_RE.pattern})")
    return name


class Counter:
    """Monotonic counter.  ``set_total`` exists for collector views that
    mirror an externally-maintained running total at scrape time."""

    __slots__ = ("name", "help", "value")
    kind = "counter"

    def __init__(self, name: str, help_text: str = "") -> None:
        self.name = _check_name(name)
        self.help = help_text
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def set_total(self, total: float) -> None:
        self.value = float(total)

    def sample_lines(self, prefix: str) -> List[str]:
        return [f"{prefix}{self.name} {_fmt(self.value)}"]

    def as_dict(self) -> Dict[str, float]:
        return {"value": self.value}


class Gauge:
    """Point-in-time value (occupancy, share, queue depth)."""

    __slots__ = ("name", "help", "value")
    kind = "gauge"

    def __init__(self, name: str, help_text: str = "") -> None:
        self.name = _check_name(name)
        self.help = help_text
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def sample_lines(self, prefix: str) -> List[str]:
        return [f"{prefix}{self.name} {_fmt(self.value)}"]

    def as_dict(self) -> Dict[str, float]:
        return {"value": self.value}


class Histogram:
    """Fixed-bucket histogram with cumulative Prometheus exposition.

    Bucket ``i`` counts observations ``<= bounds[i]``; an implicit
    ``+Inf`` bucket catches the tail.  ``merge`` is associative and
    commutative over histograms with identical bounds, so shards can be
    combined in any grouping (exercised by the registry tests).
    """

    __slots__ = ("name", "help", "bounds", "counts", "inf_count",
                 "total", "count")
    kind = "histogram"

    def __init__(self, name: str, help_text: str = "",
                 bounds: Sequence[float] = DEFAULT_BUCKETS_MS) -> None:
        self.name = _check_name(name)
        self.help = help_text
        bounds = tuple(float(b) for b in bounds)
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing: {bounds}")
        self.bounds = bounds
        self.counts = [0] * len(bounds)
        self.inf_count = 0
        self.total = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        i = bisect_left(self.bounds, value)
        if i < len(self.counts):
            self.counts[i] += 1
        else:
            self.inf_count += 1
        self.total += value
        self.count += 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Return a NEW histogram with summed buckets (inputs unchanged)."""
        if self.bounds != other.bounds:
            raise ValueError(
                f"cannot merge histograms with different bounds: "
                f"{self.bounds} vs {other.bounds}")
        out = Histogram(self.name, self.help, self.bounds)
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.inf_count = self.inf_count + other.inf_count
        out.total = self.total + other.total
        out.count = self.count + other.count
        return out

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile; nan when empty."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        seen = 0
        lo = 0.0
        for ub, c in zip(self.bounds, self.counts):
            if seen + c >= target and c > 0:
                frac = (target - seen) / c
                return lo + frac * (ub - lo)
            seen += c
            lo = ub
        return self.bounds[-1] if self.bounds else math.nan

    def sample_lines(self, prefix: str) -> List[str]:
        lines = []
        cum = 0
        for ub, c in zip(self.bounds, self.counts):
            cum += c
            lines.append(f'{prefix}{self.name}_bucket{{le="{_fmt(ub)}"}} '
                         f"{cum}")
        cum += self.inf_count
        lines.append(f'{prefix}{self.name}_bucket{{le="+Inf"}} {cum}')
        lines.append(f"{prefix}{self.name}_sum {_fmt(self.total)}")
        lines.append(f"{prefix}{self.name}_count {self.count}")
        return lines

    def as_dict(self) -> Dict[str, object]:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "inf_count": self.inf_count, "sum": self.total,
                "count": self.count}


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


class MetricsRegistry:
    """Namespace of uniquely-named instruments plus scrape collectors.

    The clock is a required constructor argument (injectable, RPL007):
    snapshots stamp ``clock_s`` with it, so virtual-time runs produce
    virtual-time-stamped snapshots instead of smuggling wall time in.
    """

    def __init__(self, clock: Callable[[], float], *,
                 prefix: str = "repro_") -> None:
        if not callable(clock):
            raise TypeError("MetricsRegistry requires an injectable "
                            "clock callable as its first argument")
        self.clock = clock
        self.prefix = prefix
        self._metrics: Dict[str, object] = {}
        self._collectors: List[Callable[[], None]] = []

    # -- registration -----------------------------------------------------
    def _register(self, metric):
        if metric.name in self._metrics:
            raise ValueError(
                f"metric {metric.name!r} registered twice — each name "
                f"may be registered exactly once per registry")
        self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help_text: str = "") -> Counter:
        return self._register(Counter(name, help_text))

    def gauge(self, name: str, help_text: str = "") -> Gauge:
        return self._register(Gauge(name, help_text))

    def histogram(self, name: str, help_text: str = "",
                  bounds: Sequence[float] = DEFAULT_BUCKETS_MS,
                  ) -> Histogram:
        return self._register(Histogram(name, help_text, bounds))

    def get(self, name: str) -> Optional[object]:
        return self._metrics.get(name)

    def register_collector(self, fn: Callable[[], None]) -> None:
        """``fn`` runs at every scrape; it should copy externally-held
        counters into instruments via ``set_total``/``set``."""
        self._collectors.append(fn)

    # -- scrape -----------------------------------------------------------
    def collect(self) -> None:
        for fn in self._collectors:
            fn()

    def exposition(self) -> str:
        """Prometheus text exposition (# HELP / # TYPE / samples)."""
        self.collect()
        out: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            full = f"{self.prefix}{name}"
            if m.help:
                out.append(f"# HELP {full} {m.help}")
            out.append(f"# TYPE {full} {m.kind}")
            out.extend(m.sample_lines(self.prefix))
        return "\n".join(out) + "\n"

    def snapshot(self) -> Dict[str, object]:
        """JSON-ready dict of every instrument, stamped with clock_s."""
        self.collect()
        return {
            "clock_s": self.clock(),
            "metrics": {
                name: {"kind": m.kind, "help": m.help, **m.as_dict()}
                for name, m in sorted(self._metrics.items())},
        }

    def snapshot_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent)
