"""repro.obs — unified telemetry plane (stdlib-only).

Three pieces, threaded through the whole serving stack:

* :mod:`repro.obs.metrics` — ``MetricsRegistry`` of typed counters /
  gauges / fixed-bucket mergeable histograms with Prometheus-style text
  exposition and JSON snapshots; existing stat blocks register scrape
  collectors so they become views over one registry.
* :mod:`repro.obs.trace` — ``Tracer`` producing per-request span trees
  (queue_wait → admission → retrieval → prefill → decode → harvest)
  with an injectable clock, bounded seeded sampling, and Chrome
  trace-event export.  ``NULL_TRACER`` is the zero-overhead disabled
  path.
* :mod:`repro.obs.attribution` — per-request stage breakdowns whose
  top-level stages sum to end-to-end latency, aggregated so SLO
  burn-rate reports can name the dominant stage.
"""
from repro.obs.attribution import (KINDS, STAGES, TOP_LEVEL,
                                   RequestBreakdown, StageAttribution)
from repro.obs.metrics import (DEFAULT_BUCKETS_MS, Counter, Gauge,
                               Histogram, MetricsRegistry)
from repro.obs.trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "KINDS", "STAGES", "TOP_LEVEL", "RequestBreakdown",
    "StageAttribution", "DEFAULT_BUCKETS_MS", "Counter", "Gauge",
    "Histogram", "MetricsRegistry", "NULL_TRACER", "NullTracer",
    "Span", "Tracer",
]
