"""SLO latency attribution: per-request stage breakdowns.

The serving stack marks every request with a contiguous top-level stage
chain — ``queue_wait → admission → prefill → decode → harvest`` — whose
durations sum to the end-to-end latency *by construction* (each stage
ends where the next begins).  ``retrieval`` is a child interval inside
``admission`` (the gateway performs retrieval while preparing the
submit), so it attributes without double-counting.

``SLOBudgetTracker`` consumes ``RequestBreakdown`` rows so a burn-rate
report can name the dominant stage: "p99 is burning and 70% of it is
queue_wait" is actionable where a bare end-to-end reservoir is not.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Tuple

# Top-level stages are contiguous and sum to end-to-end latency.
TOP_LEVEL: Tuple[str, ...] = (
    "queue_wait", "admission", "prefill", "decode", "harvest")
# All stage names a breakdown may carry (retrieval nests in admission).
STAGES: Tuple[str, ...] = TOP_LEVEL + ("retrieval",)

# Terminal kinds a breakdown can describe.
KINDS: Tuple[str, ...] = ("completed", "shed", "timed_out", "faulted")


@dataclass(slots=True)
class RequestBreakdown:
    """Per-request latency + token-cost attribution.  Treat as
    immutable — rows are shared between the tracer's deque and the
    budget tracker's window.  (Not ``frozen=True``: hot-path
    construction cost; frozen fields init via object.__setattr__.)"""

    qid: int
    kind: str                      # one of KINDS
    e2e_ms: float
    stages: Dict[str, float]       # stage -> duration ms
    cost_tokens: float = 0.0

    @property
    def stage_sum_ms(self) -> float:
        return sum(self.stages.get(s, 0.0) for s in TOP_LEVEL)

    @property
    def dominant_stage(self) -> str:
        """Largest attributed interval.  retrieval competes directly:
        its parent (admission) is reduced by the nested retrieval time
        so one of them wins on its own merits."""
        weights = {s: self.stages.get(s, 0.0) for s in TOP_LEVEL}
        retr = self.stages.get("retrieval", 0.0)
        if retr > 0.0:
            weights["admission"] = max(
                0.0, weights.get("admission", 0.0) - retr)
            weights["retrieval"] = retr
        if not any(weights.values()):
            return "queue_wait"
        return max(weights, key=lambda s: (weights[s], s))

    def as_dict(self) -> Dict[str, object]:
        return {"qid": self.qid, "kind": self.kind,
                "e2e_ms": round(self.e2e_ms, 4),
                "stages": {k: round(v, 4)
                           for k, v in sorted(self.stages.items())},
                "cost_tokens": self.cost_tokens,
                "dominant_stage": self.dominant_stage}


@dataclass
class StageAttribution:
    """Windowed aggregate of breakdowns for burn-rate reporting."""

    window: int = 512
    _rows: Deque[RequestBreakdown] = field(default_factory=deque)

    def record(self, bd: RequestBreakdown) -> None:
        self._rows.append(bd)
        while len(self._rows) > self.window:
            self._rows.popleft()

    def __len__(self) -> int:
        return len(self._rows)

    def report(self) -> Dict[str, object]:
        """Mean per-stage ms + share of total attributed time, plus the
        stage that dominates the window (admission net of retrieval)."""
        if not self._rows:
            return {"n": 0, "dominant_stage": None,
                    "stage_ms": {}, "stage_share": {}}
        sums: Dict[str, float] = {s: 0.0 for s in STAGES}
        for bd in self._rows:
            for s in STAGES:
                sums[s] += bd.stages.get(s, 0.0)
        n = len(self._rows)
        retr = sums["retrieval"]
        weights = {s: sums[s] for s in TOP_LEVEL}
        weights["admission"] = max(0.0, weights["admission"] - retr)
        weights["retrieval"] = retr
        total = sum(weights.values()) or 1.0
        dominant = max(weights, key=lambda s: (weights[s], s))
        return {
            "n": n,
            "dominant_stage": dominant,
            "stage_ms": {s: round(sums[s] / n, 4) for s in STAGES
                         if sums[s] > 0.0},
            "stage_share": {s: round(w / total, 4)
                            for s, w in weights.items() if w > 0.0},
        }
