"""Per-request span tracer with Chrome trace-event export.

Mark-based API: instrumentation sites record *completed* intervals
(``mark(qid, stage, t0, t1)``) against an open request started by
``begin_request``; ``finish_request`` closes the request, computes its
:class:`RequestBreakdown`, and (bounded, seeded) samples the span tree
for export.  The clock is injected (RPL007 / RPL001): under the
virtual-time pump every timestamp is virtual, and a Chrome trace of a
virtual run opens in Perfetto like any wall-clock trace.

Why marks instead of begin/end pairs: the serving stack already stamps
the interesting instants (arrival, pop, dispatch, ``admitted_at``,
``finished_at``, account time) on its own structures, so handing the
tracer closed intervals avoids a parallel begin/end bookkeeping state
machine on the hot path and makes "every span closed" trivially true
for everything but the root.

The ``note``/``adopt`` pair handles the one spot where the instrumented
layer does not know the request id: the backend's retrieval step runs
keyed by *question* id while the gateway tracks *request* qids.  The
backend notes an anonymous span; the gateway — single-threaded under
the pump lock — adopts pending notes onto the qid it just submitted.

``NULL_TRACER`` is the disabled path: every method is a constant-return
no-op (no clock reads, no allocation), so instrumented code never
branches on "is tracing on" and the healthy-path parity test can assert
token-identical outputs either way.
"""
from __future__ import annotations

import json
import math
import random
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.obs.attribution import (KINDS, STAGES, TOP_LEVEL,
                                   RequestBreakdown, StageAttribution)

_EPS_S = 1e-9


@dataclass(slots=True)
class Span:
    """One closed interval inside a request tree (seconds, clock domain)."""

    name: str
    t0: float
    t1: float
    attrs: Dict[str, object] = field(default_factory=dict)


@dataclass(slots=True)
class RequestTree:
    """Root request span plus its child stage spans."""

    qid: int
    start: float
    end: Optional[float] = None      # None while the request is open
    kind: str = "open"
    spans: List[Span] = field(default_factory=list)


class _Reservoir:
    """Algorithm-R sample of floats (stdlib RNG; obs stays numpy-free)."""

    __slots__ = ("capacity", "count", "samples", "_rng")

    def __init__(self, capacity: int, rng: random.Random) -> None:
        self.capacity = capacity
        self.count = 0
        self.samples: List[float] = []
        self._rng = rng

    def record(self, v: float) -> None:
        self.count += 1
        if len(self.samples) < self.capacity:
            self.samples.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self.capacity:
                self.samples[j] = v

    def percentile(self, q: float) -> float:
        if not self.samples:
            return math.nan
        xs = sorted(self.samples)
        idx = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
        return xs[idx]


class Tracer:
    """Span-tree tracer; one instance per gateway, injected clock."""

    enabled = True

    def __init__(self, clock: Callable[[], float], *,
                 max_trees: int = 512, max_breakdowns: int = 4096,
                 stage_reservoir: int = 4096, seed: int = 0) -> None:
        if not callable(clock):
            raise TypeError("Tracer requires an injectable clock "
                            "callable as its first argument")
        self.clock = clock
        self.max_trees = max_trees
        self._rng = random.Random(seed)
        self._active: Dict[int, RequestTree] = {}
        self._trees: List[RequestTree] = []
        self._n_finished = 0           # drives algorithm-R tree sampling
        self._pending: List[Span] = []
        self.engine_spans: Deque[Span] = deque(maxlen=4096)
        self.breakdowns: Deque[RequestBreakdown] = deque(
            maxlen=max_breakdowns)
        self._stage_res: Dict[str, _Reservoir] = {
            s: _Reservoir(stage_reservoir, self._rng) for s in STAGES}
        self._e2e_res = _Reservoir(stage_reservoir, self._rng)

    # -- hot-path API ----------------------------------------------------
    def now(self) -> float:
        return self.clock()

    def begin_request(self, qid: int, t: float) -> None:
        """Open the root span (idempotent: a retry re-begin is a no-op)."""
        if qid not in self._active:
            self._active[qid] = RequestTree(qid=qid, start=t)

    def mark(self, qid: int, stage: str, t0: float, t1: float,
             **attrs: object) -> None:
        """Record stage ``[t0, t1]`` on an open request.  Re-marking a
        stage overwrites it (retries re-enter admission); marking an
        unknown qid is a silent no-op (already-failed victims)."""
        tree = self._active.get(qid)
        if tree is None:
            return
        # kwargs arrive as a fresh dict — no defensive copy needed
        for sp in tree.spans:
            if sp.name == stage:
                sp.t0, sp.t1, sp.attrs = t0, t1, attrs
                return
        tree.spans.append(Span(stage, t0, t1, attrs))

    def note(self, stage: str, t0: float, t1: float,
             **attrs: object) -> None:
        """Record an anonymous span for the next ``adopt`` (backend
        layers that don't know the request qid)."""
        self._pending.append(Span(stage, t0, t1, attrs))

    def adopt(self, qid: int) -> None:
        """Attach all pending noted spans to ``qid`` (or drop them if
        the request is unknown).  Caller serialises note→adopt."""
        pending, self._pending = self._pending, []
        tree = self._active.get(qid)
        if tree is None:
            return
        for sp in pending:
            self.mark(qid, sp.name, sp.t0, sp.t1, **sp.attrs)

    def discard_pending(self) -> None:
        """Drop noted spans that cannot be attributed (batched closed-
        loop execution interleaves notes across requests)."""
        self._pending = []

    def engine_span(self, name: str, t0: float, t1: float,
                    **attrs: object) -> None:
        """Engine-level span not tied to one request (prefill dispatch,
        decode chunk).  Bounded deque; rendered on its own track."""
        self.engine_spans.append(Span(name, t0, t1, attrs))

    def finish_request(self, qid: int, kind: str,
                       t: Optional[float] = None,
                       cost_tokens: float = 0.0,
                       ) -> Optional[RequestBreakdown]:
        """Close the request, compute its breakdown, sample the tree."""
        tree = self._active.pop(qid, None)
        if tree is None:
            return None
        if kind not in KINDS:
            raise ValueError(f"unknown terminal kind {kind!r}")
        end = self.clock() if t is None else t
        tree.end = max(end, tree.start)
        tree.kind = kind
        stages: Dict[str, float] = {}
        for sp in tree.spans:
            dur_ms = max(0.0, sp.t1 - sp.t0) * 1e3
            stages[sp.name] = stages.get(sp.name, 0.0) + dur_ms
        e2e_ms = (tree.end - tree.start) * 1e3
        bd = RequestBreakdown(qid=qid, kind=kind, e2e_ms=e2e_ms,
                              stages=stages, cost_tokens=cost_tokens)
        self.breakdowns.append(bd)
        for s, v in stages.items():
            self._stage_res[s].record(v)
        self._e2e_res.record(e2e_ms)
        # algorithm R over finished trees keeps export bounded at high
        # rate while every request still gets a breakdown above
        self._n_finished += 1
        if len(self._trees) < self.max_trees:
            self._trees.append(tree)
        else:
            j = self._rng.randrange(self._n_finished)
            if j < self.max_trees:
                self._trees[j] = tree
        return bd

    # -- export / inspection --------------------------------------------
    @property
    def n_open(self) -> int:
        return len(self._active)

    @property
    def n_finished(self) -> int:
        return self._n_finished

    @property
    def sampled_trees(self) -> List[RequestTree]:
        return list(self._trees)

    def stage_percentiles(self) -> Dict[str, Dict[str, float]]:
        """Per-stage {n, p50, p99} ms over the seeded reservoirs."""
        out: Dict[str, Dict[str, float]] = {}
        for s in STAGES:
            res = self._stage_res[s]
            if res.count == 0:
                continue
            out[s] = {"n": res.count,
                      "p50_ms": round(res.percentile(0.50), 4),
                      "p99_ms": round(res.percentile(0.99), 4)}
        if self._e2e_res.count:
            out["e2e"] = {"n": self._e2e_res.count,
                          "p50_ms": round(self._e2e_res.percentile(0.50), 4),
                          "p99_ms": round(self._e2e_res.percentile(0.99), 4)}
        return out

    def chrome_trace(self) -> Dict[str, object]:
        """Chrome trace-event JSON (open in Perfetto / chrome://tracing).
        Request trees render as pid 1 with one tid per qid; engine spans
        share pid 0 / tid 0.  ts/dur are microseconds of the injected
        clock domain."""
        events: List[Dict[str, object]] = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "engine"}},
            {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
             "args": {"name": "requests"}},
        ]

        def ev(name, t0, t1, pid, tid, args):
            return {"name": name, "ph": "X", "cat": "repro",
                    "ts": round(t0 * 1e6, 3),
                    "dur": round(max(0.0, t1 - t0) * 1e6, 3),
                    "pid": pid, "tid": tid, "args": args}

        for sp in self.engine_spans:
            events.append(ev(sp.name, sp.t0, sp.t1, 0, 0, sp.attrs))
        for tree in self._trees:
            end = tree.end if tree.end is not None else tree.start
            events.append(ev(f"request[{tree.kind}]", tree.start, end,
                             1, tree.qid, {"qid": tree.qid}))
            for sp in tree.spans:
                events.append(ev(sp.name, sp.t0, sp.t1, 1, tree.qid,
                                 sp.attrs))
        events.sort(key=lambda e: (e["pid"], e["tid"],
                                   e.get("ts", -1.0)))
        # otherData is the trace-event format's free-form top-level
        # slot (viewers ignore it): ship the well-formedness audit with
        # the artifact so consumers (the CI obs-smoke job) can assert
        # problems == [] without re-driving the tracer
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"n_finished": self._n_finished,
                              "n_open": len(self._active),
                              "problems": self.problems()}}

    def chrome_trace_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.chrome_trace(), indent=indent)

    def problems(self) -> List[str]:
        """Well-formedness audit: every sampled span closed and inside
        its root interval; no requests left open.  Empty list == clean
        (asserted by the CI obs-smoke job)."""
        out: List[str] = []
        for qid in sorted(self._active):
            out.append(f"request {qid} never finished (span left open)")
        for tree in self._trees:
            if tree.end is None:
                out.append(f"request {tree.qid} sampled while open")
                continue
            for sp in tree.spans:
                if sp.t1 < sp.t0 - _EPS_S:
                    out.append(f"request {tree.qid} span {sp.name} "
                               f"ends before it starts")
                if (sp.t0 < tree.start - _EPS_S
                        or sp.t1 > tree.end + _EPS_S):
                    out.append(f"request {tree.qid} span {sp.name} "
                               f"escapes root interval")
        for sp in self.engine_spans:
            if sp.t1 < sp.t0 - _EPS_S:
                out.append(f"engine span {sp.name} ends before it starts")
        return out


class NullTracer:
    """Disabled tracer: every method is a constant-return no-op.  Kept
    signature-compatible with :class:`Tracer` so hot paths never branch
    on enablement."""

    enabled = False
    engine_spans: Tuple[()] = ()
    breakdowns: Tuple[()] = ()

    def now(self) -> float:
        return 0.0

    def begin_request(self, qid, t) -> None:
        pass

    def mark(self, qid, stage, t0, t1, **attrs) -> None:
        pass

    def note(self, stage, t0, t1, **attrs) -> None:
        pass

    def adopt(self, qid) -> None:
        pass

    def discard_pending(self) -> None:
        pass

    def engine_span(self, name, t0, t1, **attrs) -> None:
        pass

    def finish_request(self, qid, kind, t=None, cost_tokens=0.0) -> None:
        return None

    def stage_percentiles(self) -> Dict[str, Dict[str, float]]:
        return {}

    def problems(self) -> List[str]:
        return []


NULL_TRACER = NullTracer()
