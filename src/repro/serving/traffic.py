"""Open-loop load generation: seeded arrival processes, a virtual
clock, and the discrete-event driver for :class:`AsyncGateway`.

Closed-loop benchmarking (submit a batch, wait, submit the next) hides
queueing: the client politely waits for the server, so latency never
compounds.  Open-loop load — the industry-standard serving methodology —
draws arrival times from a stochastic process *independent of service
progress* and holds the server to per-request deadlines, so an
over-offered system visibly melts (queues grow without bound) unless
admission control sheds.  This module provides:

* :class:`PoissonProcess` / :class:`OnOffProcess` — seeded arrival
  processes (exponential inter-arrivals; bursty on-off modulation);
* :class:`VirtualClock` — simulated time, so a sweep over offered loads
  is deterministic and runs as fast as the engine can step, not in
  wall-clock real time;
* :class:`LoadGenerator` — drives an :class:`AsyncGateway` through a
  trace in either virtual time (deterministic: interleaves arrivals
  with ``pump`` calls, no background thread) or real time (the thread
  serves while this sleeps between arrivals);
* :class:`LoadReport` — offered vs completed vs shed, goodput under
  SLO, and latency percentiles from the gateway's reservoir.

Same seed + virtual clock => bit-identical completions, sheds, and
latencies across runs; that's what makes shedding behaviour testable.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.data.synthetic_squad import Question
from repro.routing.gateway import Request
from repro.serving.slo_budget import LatencyReservoir


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

class PoissonProcess:
    """Homogeneous Poisson arrivals: exponential inter-arrival times at
    ``rate`` requests/second, seeded."""

    def __init__(self, rate: float, *, seed: int = 0):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.rate = float(rate)
        self.rng = np.random.default_rng(seed)

    def inter_arrivals(self) -> Iterator[float]:
        while True:
            yield float(self.rng.exponential(1.0 / self.rate))


class OnOffProcess:
    """Bursty on-off (interrupted Poisson) arrivals: alternate between
    an ON phase arriving at ``burst_rate`` and an OFF phase of silence,
    with exponentially distributed phase durations.  Mean offered rate
    is ``burst_rate * on_s / (on_s + off_s)`` — the same average load
    as a Poisson process stresses admission control far harder because
    arrivals clump."""

    def __init__(self, burst_rate: float, *, on_s: float = 0.5,
                 off_s: float = 0.5, seed: int = 0):
        if burst_rate <= 0:
            raise ValueError(f"burst_rate must be > 0, got {burst_rate}")
        self.burst_rate = float(burst_rate)
        self.on_s = float(on_s)
        self.off_s = float(off_s)
        self.rng = np.random.default_rng(seed)

    @property
    def mean_rate(self) -> float:
        return self.burst_rate * self.on_s / (self.on_s + self.off_s)

    def inter_arrivals(self) -> Iterator[float]:
        while True:
            # one ON phase of Poisson arrivals...
            phase = float(self.rng.exponential(self.on_s))
            t = 0.0
            while True:
                gap = float(self.rng.exponential(1.0 / self.burst_rate))
                if t + gap > phase:
                    break
                t += gap
                yield gap
            # ...then the residual ON time plus a silent OFF phase is
            # one long gap before the next burst's first arrival
            yield (phase - t) + float(self.rng.exponential(self.off_s))


@dataclass(frozen=True)
class Arrival:
    """One request in a trace: its absolute arrival time (seconds from
    trace start) and payload."""

    t: float
    request: Request


def build_trace(questions: Sequence[Question], process, n: int, *,
                slo: str = "quality_first",
                deadline_ms: float = 0.0) -> List[Arrival]:
    """Materialise ``n`` arrivals from an arrival process, cycling
    through ``questions``.  The trace is a plain list, so the same
    trace can be replayed against different gateways/configs."""
    if not questions:
        raise ValueError("build_trace needs at least one question")
    gaps = process.inter_arrivals()
    t = 0.0
    out: List[Arrival] = []
    for i in range(n):
        t += next(gaps)
        q = questions[i % len(questions)]
        out.append(Arrival(t=t, request=Request(
            qid=i, question=q, slo=slo, deadline_ms=deadline_ms)))
    return out


# ---------------------------------------------------------------------------
# virtual time
# ---------------------------------------------------------------------------

class VirtualClock:
    """Simulated monotonic time.  Pass ``clock.now`` wherever a
    ``time.perf_counter``-style callable is accepted (AsyncGateway,
    ContinuousEngine, SimulatorBackend) and every latency stamp in the
    system becomes virtual-time-consistent and deterministic."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards (dt={dt})")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        if t > self._t:
            self._t = t
        return self._t


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

@dataclass
class LoadReport:
    """What an open-loop run offered and what the service delivered."""

    offered: int = 0
    completed: int = 0           # got a terminal outcome of any kind
    answered: int = 0            # completed, not refused/shed
    refused: int = 0             # policy or forced refusals
    shed: int = 0                # rejected at the queue by admission
    forced_refusals: int = 0
    depth_clamped: int = 0
    deadline_met: int = 0        # answered within their deadline
    degraded: int = 0            # served on the fallback retriever
    retries: int = 0             # transient-fault resubmissions
    timed_out: int = 0           # cancelled mid-stream past deadline
    faulted: int = 0             # transient failures after retry budget
    duration_s: float = 0.0      # arrival-span of the trace (virtual)
    latency: LatencyReservoir = field(
        default_factory=lambda: LatencyReservoir())
    first_token: LatencyReservoir = field(
        default_factory=lambda: LatencyReservoir())
    # per-stage latency percentiles from the gateway's tracer, keyed
    # stage -> {n, p50_ms, p99_ms}; empty when tracing is disabled
    stages: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def offered_rate(self) -> float:
        return self.offered / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def goodput(self) -> float:
        """Goodput under SLO: answered-within-deadline per second of
        trace time — the paper-grade serving metric (raw throughput
        counts late and refused work; goodput doesn't)."""
        return (self.deadline_met / self.duration_s
                if self.duration_s > 0 else 0.0)

    @property
    def goodput_fraction(self) -> float:
        return self.deadline_met / max(self.offered, 1)

    @property
    def shed_fraction(self) -> float:
        return self.shed / max(self.offered, 1)

    def as_dict(self) -> Dict[str, float]:
        d = {
            "offered": self.offered, "completed": self.completed,
            "answered": self.answered, "refused": self.refused,
            "shed": self.shed, "forced_refusals": self.forced_refusals,
            "depth_clamped": self.depth_clamped,
            "deadline_met": self.deadline_met,
            "degraded": self.degraded, "retries": self.retries,
            "timed_out": self.timed_out, "faulted": self.faulted,
            "duration_s": round(self.duration_s, 4),
            "offered_rate": round(self.offered_rate, 3),
            "goodput": round(self.goodput, 3),
            "goodput_fraction": round(self.goodput_fraction, 4),
            "shed_fraction": round(self.shed_fraction, 4),
        }
        for k, v in self.latency.percentiles().items():
            d[f"latency_{k}"] = v
        for k, v in self.first_token.percentiles().items():
            d[f"first_token_{k}"] = v
        if self.stages:
            d["stages"] = self.stages
        return d


# ---------------------------------------------------------------------------
# the load generator
# ---------------------------------------------------------------------------

class LoadGenerator:
    """Replays a trace of arrivals against an :class:`AsyncGateway`.

    Two drive modes:

    * :meth:`run_virtual` — discrete-event: no background thread; the
      generator owns the gateway's :class:`VirtualClock`, submits each
      arrival at its trace time, and charges ``service_quantum_s`` of
      virtual time per ``pump``.  Deterministic (same seed, same
      everything) and as fast as the backend can step.
    * :meth:`run_realtime` — the gateway's serving thread runs; the
      generator sleeps out real inter-arrival gaps.  This is the
      honest-wall-clock mode the benchmark's timing rows use.
    """

    def __init__(self, gateway, trace: Sequence[Arrival]):
        self.gateway = gateway
        self.trace = list(trace)
        if not self.trace:
            raise ValueError("empty trace")
        # handles of the most recent run — benches read per-request
        # detail (e.g. recovery time) the aggregate report drops
        self.last_handles: List = []

    # -- shared bookkeeping -------------------------------------------

    def _report(self, handles) -> LoadReport:
        self.last_handles = list(handles)
        rep = LoadReport(offered=len(handles),
                         duration_s=self.trace[-1].t)
        st = self.gateway.stats
        rep.forced_refusals = st.forced_refusals
        rep.depth_clamped = st.depth_clamped
        rep.degraded = getattr(st, "degraded", 0)
        rep.retries = getattr(st, "retries", 0)
        rep.timed_out = getattr(st, "timed_out", 0)
        rep.faulted = getattr(st, "faulted", 0)
        for h in handles:
            if not h.done():
                continue
            rep.completed += 1
            if h.shed:
                rep.shed += 1
                continue
            lat = h.latency_ms
            if lat is not None:
                rep.latency.record(lat)
            ft = h.first_token_ms
            if ft is not None:
                rep.first_token.record(ft)
            if h.outcome.refused:
                rep.refused += 1
            else:
                rep.answered += 1
                if h.deadline_met:
                    rep.deadline_met += 1
        tracer = getattr(self.gateway, "tracer", None)
        if tracer is not None and tracer.enabled:
            rep.stages = tracer.stage_percentiles()
        return rep

    # -- virtual-time (deterministic) ---------------------------------

    def run_virtual(self, clock: VirtualClock, *,
                    service_quantum_s: float = 0.01) -> LoadReport:
        """Discrete-event replay: between arrivals the gateway pumps,
        each pump costing ``service_quantum_s`` virtual seconds; when
        the gateway goes idle the clock jumps to the next arrival.
        Caller must have built the gateway (and its backend/engine)
        with ``clock.now`` so all stamps agree."""
        gw = self.gateway
        handles = []
        i = 0
        n = len(self.trace)
        while i < n or gw.in_flight:
            # submit everything whose arrival time has come
            while i < n and self.trace[i].t <= clock.now():
                handles.append(gw.submit_stream(self.trace[i].request))
                i += 1
            progressed = gw.pump()
            clock.advance(service_quantum_s)
            if progressed == 0 and not gw.in_flight and i < n:
                # idle: jump straight to the next arrival
                clock.advance_to(self.trace[i].t)
        return self._report(handles)

    # -- real-time (background serving thread) ------------------------

    def run_realtime(self, *, timeout_s: float = 120.0) -> LoadReport:
        """Replay the trace in wall-clock time against the gateway's
        background serving thread (started/stopped here)."""
        gw = self.gateway
        handles = []
        gw.start()
        try:
            t0 = time.perf_counter()
            for arr in self.trace:
                lag = arr.t - (time.perf_counter() - t0)
                if lag > 0:
                    # repro: allow[RPL001] real-time pacing IS this method's contract; run() replays on the virtual clock
                    time.sleep(lag)
                handles.append(gw.submit_stream(arr.request))
            deadline = time.perf_counter() + timeout_s
            while gw.in_flight and time.perf_counter() < deadline:
                # repro: allow[RPL001] real-time pacing IS this method's contract; run() replays on the virtual clock
                time.sleep(1e-3)
        finally:
            gw.stop(drain=False)
        return self._report(handles)


def sweep_offered_load(make_gateway, questions: Sequence[Question],
                       rates: Sequence[float], *, n_requests: int = 200,
                       deadline_ms: float = 200.0, seed: int = 0,
                       slo: str = "quality_first",
                       service_quantum_s: float = 0.01
                       ) -> List[Dict[str, float]]:
    """Offered-load sweep: for each rate, build a fresh gateway (via
    ``make_gateway(clock)``), replay a seeded Poisson trace in virtual
    time, and collect one report row.  Fresh gateway per rate so budget
    state never leaks across operating points."""
    rows: List[Dict[str, float]] = []
    for rate in rates:
        clock = VirtualClock()
        gw = make_gateway(clock)
        trace = build_trace(questions, PoissonProcess(rate, seed=seed),
                            n_requests, slo=slo, deadline_ms=deadline_ms)
        rep = LoadGenerator(gw, trace).run_virtual(
            clock, service_quantum_s=service_quantum_s)
        row = {"rate": rate, **rep.as_dict()}
        rows.append(row)
    return rows
