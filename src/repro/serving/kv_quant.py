"""Int8 KV-cache quantization (§Perf H5, beyond paper).

Keys/values are stored int8 with per-(batch, position, head) float16
scales (absmax symmetric).  Halves decode-cache HBM residency + read
traffic vs bf16 — the decode roofline's memory term — at the cost of a
dequant multiply per read.  Equivalence is tolerance-tested in
tests/test_kv_quant.py.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.schema import ParamSpec


def quantize(x: jax.Array, axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    """absmax-symmetric int8 quantization along `axis`.

    Returns (q int8, scale f16) with x ≈ q * scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = (amax / 127.0 + 1e-8).astype(jnp.float16)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale.astype(jnp.float32)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def quant_kv_cache_schema(batch: int, max_len: int, n_kv: int,
                          head_dim: int) -> Dict[str, ParamSpec]:
    """Schema for one layer's quantized KV cache."""
    axes = ("batch", "seq", "kv_heads", "head_dim")
    saxes = ("batch", "seq", "kv_heads", "")
    return {
        "k_q": ParamSpec((batch, max_len, n_kv, head_dim), axes, "int8", "zeros"),
        "v_q": ParamSpec((batch, max_len, n_kv, head_dim), axes, "int8", "zeros"),
        "k_s": ParamSpec((batch, max_len, n_kv, 1), saxes, "float16", "zeros"),
        "v_s": ParamSpec((batch, max_len, n_kv, 1), saxes, "float16", "zeros"),
    }


def insert_step(cache: Dict[str, jax.Array], k: jax.Array, v: jax.Array,
                pos: jax.Array) -> Dict[str, jax.Array]:
    """Insert one decode step's (B, 1, Hkv, Dh) k/v at per-request pos."""
    B = k.shape[0]
    bidx = jnp.arange(B)
    kq, ks = quantize(k[:, 0])
    vq, vs = quantize(v[:, 0])
    return {
        "k_q": cache["k_q"].at[bidx, pos].set(kq),
        "v_q": cache["v_q"].at[bidx, pos].set(vq),
        "k_s": cache["k_s"].at[bidx, pos].set(ks),
        "v_s": cache["v_s"].at[bidx, pos].set(vs),
    }


def read(cache: Dict[str, jax.Array], dtype=jnp.bfloat16):
    """Dequantized (k, v) views for attention."""
    return (dequantize(cache["k_q"], cache["k_s"], dtype),
            dequantize(cache["v_q"], cache["v_s"], dtype))


def cache_bytes(batch: int, max_len: int, n_kv: int, head_dim: int,
                quantized: bool) -> int:
    if quantized:
        return batch * max_len * n_kv * (2 * head_dim + 2 * 2)
    return batch * max_len * n_kv * head_dim * 2 * 2
