"""SLO compliance tracking with SRE-style error budgets.

The paper frames routing against service-level *objectives* and cites
Beyer et al.'s SRE book; this module closes that loop operationally:
each SLO becomes a target + window + error budget, the serving layer
records per-request outcomes, and the budget state can drive the router
(e.g. tighten the refusal cap when the refusal budget burns hot).

Two consumers actuate on the state:

* :class:`repro.routing.gateway.Gateway` owns a tracker instance and
  threads ``refusal_cap_adjustment`` into every ``RoutingPolicy.route``
  call as the batch's refusal cap (closed-loop back-pressure).
* :class:`repro.serving.streaming.AsyncGateway` additionally watches
  the short-window **burn rate** (:meth:`SLOBudgetTracker.burn_rate`)
  and actuates *admission*: load-shedding at the queue, forced
  refusals, and retrieval-depth clamping when the latency/cost budgets
  burn hot (the SLA-reconfiguration loop of arXiv:2412.06832).

:class:`LatencyReservoir` lives here too: the bounded reservoir sample
behind ``GatewayStats`` latency percentiles (p50/p95/p99), shared by
the serving benchmarks instead of per-bench percentile math.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.serving_types import RequestOutcome
from repro.obs.attribution import RequestBreakdown, StageAttribution

# ``refusal_cap_adjustment`` shape constants (previously inline magic
# numbers) — overridable per tracker:
#   burn <= KNEE            : cap untouched
#   KNEE < burn <= CLIP     : cap scaled by (1 - SLOPE * (burn - KNEE))
#   burn clipped at CLIP, and the cap never drops below FLOOR.
REFUSAL_CAP_FLOOR = 0.05
BURN_KNEE = 0.5
BURN_SLOPE = 0.5
BURN_CLIP = 2.0

# default short window (requests) for burn-rate actuation signals — a
# fraction of the budget window so admission control reacts to the
# last few micro-batches, not the whole sliding history
DEFAULT_BURN_WINDOW = 64


@dataclass(frozen=True)
class SLOTarget:
    name: str
    metric: str              # refusal | hallucination | cost_tokens | error | latency
    threshold: float         # per-request bad-event definition for costs
    objective: float         # e.g. 0.95 = "≤5% of requests may violate"
    window: int = 500        # sliding window (requests)

    @property
    def error_budget(self) -> float:
        return 1.0 - self.objective


@dataclass(frozen=True)
class BudgetReport:
    """One target's budget state — a typed row, not a loosely-typed
    dict mixing bools into float values."""

    name: str
    violation_rate: float
    budget_consumed: float   # >1 = SLO breached
    burn_rate: float         # short-window budget_consumed (actuation signal)
    window_n: int            # events currently in the sliding window
    healthy: bool

    def as_dict(self) -> Dict[str, object]:
        """JSON-ready row (drivers print these)."""
        return {"violation_rate": round(self.violation_rate, 4),
                "budget_consumed": round(self.budget_consumed, 3),
                "burn_rate": round(self.burn_rate, 3),
                "window_n": self.window_n,
                "healthy": self.healthy}


@dataclass
class BudgetState:
    target: SLOTarget
    events: Deque[bool] = field(default_factory=deque)  # True = violation

    def record(self, outcome: RequestOutcome) -> None:
        m = self.target.metric
        if m == "refusal":
            bad = outcome.refused and outcome.answerable
        elif m == "hallucination":
            bad = outcome.hallucinated
        elif m == "cost_tokens":
            bad = outcome.cost_tokens > self.target.threshold
        elif m == "error":
            bad = (not outcome.correct) and (not outcome.refused)
        elif m == "latency":
            bad = outcome.latency_ms > self.target.threshold
        else:
            raise ValueError(m)
        self.events.append(bool(bad))
        while len(self.events) > self.target.window:
            self.events.popleft()

    @property
    def violation_rate(self) -> float:
        return (sum(self.events) / len(self.events)) if self.events else 0.0

    @property
    def budget_consumed(self) -> float:
        """Fraction of the error budget burned (>1 = SLO breached)."""
        eb = self.target.error_budget
        return self.violation_rate / eb if eb > 0 else float("inf")

    def burn_rate(self, window: int = DEFAULT_BURN_WINDOW) -> float:
        """Budget consumption over only the most recent ``window``
        events — the fast signal admission control actuates on.  0.0
        with an empty window (no traffic = no burn)."""
        if not self.events or window <= 0:
            return 0.0
        recent = list(self.events)[-window:]
        rate = sum(recent) / len(recent)
        eb = self.target.error_budget
        return rate / eb if eb > 0 else float("inf")

    @property
    def healthy(self) -> bool:
        return self.budget_consumed <= 1.0


class SLOBudgetTracker:
    """Tracks several targets; exposes router back-pressure signals.

    The refusal-cap shape constants are configurable (defaults are the
    module-level named constants, previously inline literals)."""

    def __init__(self, targets: List[SLOTarget], *,
                 burn_window: int = DEFAULT_BURN_WINDOW,
                 refusal_cap_floor: float = REFUSAL_CAP_FLOOR,
                 burn_knee: float = BURN_KNEE,
                 burn_slope: float = BURN_SLOPE,
                 burn_clip: float = BURN_CLIP):
        self.states: Dict[str, BudgetState] = {
            t.name: BudgetState(t) for t in targets}
        self.burn_window = burn_window
        self.refusal_cap_floor = refusal_cap_floor
        self.burn_knee = burn_knee
        self.burn_slope = burn_slope
        self.burn_clip = burn_clip
        # windowed per-stage latency attribution (fed by the tracer):
        # lets a burn-rate report say WHERE the latency went, not only
        # that the budget burned
        self.attribution = StageAttribution()

    def record(self, outcome: RequestOutcome) -> None:
        for s in self.states.values():
            s.record(outcome)

    def record_breakdown(self, bd: Optional[RequestBreakdown]) -> None:
        """Attach one request's per-stage breakdown (None-safe: the
        disabled tracer produces no breakdowns)."""
        if bd is not None:
            self.attribution.record(bd)

    def report(self) -> Dict[str, BudgetReport]:
        return {name: BudgetReport(
                    name=name,
                    violation_rate=s.violation_rate,
                    budget_consumed=s.budget_consumed,
                    burn_rate=s.burn_rate(self.burn_window),
                    window_n=len(s.events),
                    healthy=s.healthy)
                for name, s in self.states.items()}

    def report_dict(self) -> Dict[str, Dict[str, object]]:
        """JSON-serializable form of :meth:`report`, plus the windowed
        latency attribution (which stage dominates recent requests)
        when any breakdowns have been recorded."""
        out = {name: rep.as_dict() for name, rep in self.report().items()}
        if len(self.attribution):
            out["latency_attribution"] = self.attribution.report()
        return out

    def burn_rate(self, name: str, window: Optional[int] = None) -> float:
        """Short-window burn for one target (0.0 if untracked)."""
        s = self.states.get(name)
        if s is None:
            return 0.0
        return s.burn_rate(self.burn_window if window is None else window)

    def refusal_cap_adjustment(self, base_cap: float) -> float:
        """Back-pressure hook: tighten the policy's refusal cap as the
        wrong-refusal budget burns (the §7.1 mitigation made adaptive).
        Piecewise-linear in the clipped burn; monotonically
        non-increasing in burn, floored at ``refusal_cap_floor``."""
        s = self.states.get("refusal")
        if s is None or not s.events:
            return base_cap
        burn = min(s.budget_consumed, self.burn_clip)
        scale = 1.0 - self.burn_slope * max(0.0, burn - self.burn_knee)
        return max(self.refusal_cap_floor, base_cap * scale)


def latency_target(deadline_ms: float, *, objective: float = 0.90,
                   window: int = 500) -> SLOTarget:
    """A per-request completion-latency SLO: at most ``1 - objective``
    of requests may finish later than ``deadline_ms``."""
    return SLOTarget("latency", "latency", float(deadline_ms),
                     objective=objective, window=window)


DEFAULT_TARGETS = [
    SLOTarget("refusal", "refusal", 0.0, objective=0.90),
    SLOTarget("hallucination", "hallucination", 0.0, objective=0.70),
    SLOTarget("cost", "cost_tokens", 800.0, objective=0.95),
    SLOTarget("error", "error", 0.0, objective=0.60),
]


class LatencyReservoir:
    """Bounded uniform reservoir of latency samples (Vitter algorithm
    R, seeded — deterministic for a given insert sequence).

    Keeps percentile estimates O(capacity) in arbitrarily long serving
    runs; below capacity it is exact.  This is the one home for the
    p50/p95/p99 math that used to be re-derived ad hoc per benchmark.
    """

    __slots__ = ("capacity", "count", "_samples", "_rng")

    def __init__(self, capacity: int = 2048, seed: int = 0):
        self.capacity = int(capacity)
        self.count = 0
        self._samples: List[float] = []
        self._rng = np.random.default_rng(seed)

    def record(self, value_ms: float) -> None:
        self.count += 1
        if len(self._samples) < self.capacity:
            self._samples.append(float(value_ms))
            return
        j = int(self._rng.integers(0, self.count))
        if j < self.capacity:
            self._samples[j] = float(value_ms)

    def extend(self, values_ms) -> None:
        for v in values_ms:
            self.record(float(v))

    def __len__(self) -> int:
        return len(self._samples)

    def percentile(self, p: float) -> float:
        if not self._samples:
            return float("nan")
        return float(np.percentile(self._samples, p))

    def percentiles(self) -> Dict[str, float]:
        """The standard serving latency row: p50/p95/p99 (+ mean/max)."""
        if not self._samples:
            return {"n": 0, "mean_ms": float("nan"), "p50_ms": float("nan"),
                    "p95_ms": float("nan"), "p99_ms": float("nan"),
                    "max_ms": float("nan")}
        arr = np.asarray(self._samples)
        return {"n": self.count,
                "mean_ms": round(float(arr.mean()), 2),
                "p50_ms": round(float(np.percentile(arr, 50)), 2),
                "p95_ms": round(float(np.percentile(arr, 95)), 2),
                "p99_ms": round(float(np.percentile(arr, 99)), 2),
                "max_ms": round(float(arr.max()), 2)}
