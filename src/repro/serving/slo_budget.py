"""SLO compliance tracking with SRE-style error budgets.

The paper frames routing against service-level *objectives* and cites
Beyer et al.'s SRE book; this module closes that loop operationally:
each SLO becomes a target + window + error budget, the serving layer
records per-request outcomes, and the budget state can drive the router
(e.g. tighten the refusal cap when the refusal budget burns hot).

The :class:`repro.routing.gateway.Gateway` owns a tracker instance and
threads ``refusal_cap_adjustment`` into every ``RoutingPolicy.route``
call as the batch's refusal cap.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.core.serving_types import RequestOutcome


@dataclass(frozen=True)
class SLOTarget:
    name: str
    metric: str              # refusal | hallucination | cost_tokens | error
    threshold: float         # per-request bad-event definition for costs
    objective: float         # e.g. 0.95 = "≤5% of requests may violate"
    window: int = 500        # sliding window (requests)

    @property
    def error_budget(self) -> float:
        return 1.0 - self.objective


@dataclass
class BudgetState:
    target: SLOTarget
    events: Deque[bool] = field(default_factory=deque)  # True = violation

    def record(self, outcome: RequestOutcome) -> None:
        m = self.target.metric
        if m == "refusal":
            bad = outcome.refused and outcome.answerable
        elif m == "hallucination":
            bad = outcome.hallucinated
        elif m == "cost_tokens":
            bad = outcome.cost_tokens > self.target.threshold
        elif m == "error":
            bad = (not outcome.correct) and (not outcome.refused)
        else:
            raise ValueError(m)
        self.events.append(bool(bad))
        while len(self.events) > self.target.window:
            self.events.popleft()

    @property
    def violation_rate(self) -> float:
        return (sum(self.events) / len(self.events)) if self.events else 0.0

    @property
    def budget_consumed(self) -> float:
        """Fraction of the error budget burned (>1 = SLO breached)."""
        eb = self.target.error_budget
        return self.violation_rate / eb if eb > 0 else float("inf")

    @property
    def healthy(self) -> bool:
        return self.budget_consumed <= 1.0


class SLOBudgetTracker:
    """Tracks several targets; exposes router back-pressure signals."""

    def __init__(self, targets: List[SLOTarget]):
        self.states: Dict[str, BudgetState] = {
            t.name: BudgetState(t) for t in targets}

    def record(self, outcome: RequestOutcome) -> None:
        for s in self.states.values():
            s.record(outcome)

    def report(self) -> Dict[str, Dict[str, float]]:
        return {name: {"violation_rate": round(s.violation_rate, 4),
                       "budget_consumed": round(s.budget_consumed, 3),
                       "healthy": s.healthy}
                for name, s in self.states.items()}

    def refusal_cap_adjustment(self, base_cap: float) -> float:
        """Back-pressure hook: tighten the policy's refusal cap as the
        wrong-refusal budget burns (the §7.1 mitigation made adaptive)."""
        s = self.states.get("refusal")
        if s is None or not s.events:
            return base_cap
        burn = min(s.budget_consumed, 2.0)
        return max(0.05, base_cap * (1.0 - 0.5 * max(0.0, burn - 0.5)))


DEFAULT_TARGETS = [
    SLOTarget("refusal", "refusal", 0.0, objective=0.90),
    SLOTarget("hallucination", "hallucination", 0.0, objective=0.70),
    SLOTarget("cost", "cost_tokens", 800.0, objective=0.95),
    SLOTarget("error", "error", 0.0, objective=0.60),
]
