"""Deterministic chaos injection for the serving plane.

The paper's routing story is about *graceful degradation*: under
pressure the router should refuse, clamp depth, or cheapen the action
rather than fail.  PR 6 gave the gateway that behaviour under *load*;
this module gives the rest of the stack the same behaviour under
*faults* — and makes every failure scenario reproducible, so the
fault-tolerance tests and the chaos benchmark are as deterministic as
the greedy decode they wrap.

* :class:`FaultSpec` / :class:`FaultPlan` — declarative fault
  schedules: each spec names a **site** (an injection seam, e.g.
  ``"retriever.dense"`` or ``"executor.decode"``), a fault **kind**,
  and an invocation window ``[start, start+count)`` of that site's
  call counter, optionally thinned by a seeded per-invocation
  probability.  Same plan + same call sequence ⇒ bit-identical faults.
* :class:`ChaosInjector` — the runtime: owns the per-site counters and
  the seeded RNG, answers ``fire(site)`` with the matching spec (or
  ``None``).  When no plan is armed the seams are **never installed**
  (the wrappers below are only constructed for an armed injector), so
  the no-fault serving path is byte-identical to pre-chaos code.
* :class:`ChaosRetriever` — wraps any retrieval-protocol object; fault
  kinds ``raise`` / ``timeout`` (both surface as transient errors the
  circuit breaker records) and ``latency`` (sleeps, virtual or real).
* :class:`ChaosExecutor` — wraps a
  :class:`~repro.serving.executor.DeviceExecutor`; ``raise``/``timeout``
  on ``executor.admit`` / ``executor.decode``, ``stall`` (the decode
  chunk silently makes no progress — the scheduler's watchdog must
  catch it), and ``nan`` (marks slots poisoned via ``slot_faults`` —
  the same signal the real executors raise from device-side
  NaN/inf detection on decode logits).

Retry policy lives here too (:class:`RetryPolicy`): the gateway-level
knob for bounded, deadline-aware retries of transient faults.

Only stdlib + numpy — importable from the retrieval layer and the host
scheduler without dragging JAX in.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

# Canonical home is repro.core.errors (shared with the retrieval layer
# without a serving<->retrieval import cycle); re-exported here because
# this module is the chaos API surface.
from repro.core.errors import (CircuitOpenError, FaultError,
                               FaultTimeoutError, TransientFaultError)

FAULT_KINDS = ("raise", "timeout", "latency", "nan", "stall")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: *what* happens *where*, and *when*.

    ``site`` is matched exactly against the seam's ``fire`` site
    string.  The fault is eligible on invocations ``start <= n <
    start + count`` of that site's counter (``count=-1`` = open-ended),
    and actually fires with probability ``prob`` (seeded draw in the
    injector, taken only on eligible invocations — so the schedule is
    replayable)."""

    site: str
    kind: str                       # one of FAULT_KINDS
    start: int = 0
    count: int = 1                  # -1 = every invocation from start
    prob: float = 1.0
    latency_s: float = 0.0          # for kind == "latency"
    slots: Optional[Tuple[int, ...]] = None   # for kind == "nan"
    message: str = ""

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.count == 0 or self.count < -1:
            raise ValueError(f"count must be >= 1 or -1, got {self.count}")
        if not (0.0 < self.prob <= 1.0):
            raise ValueError(f"prob must be in (0, 1], got {self.prob}")

    def eligible(self, n: int) -> bool:
        if n < self.start:
            return False
        return self.count == -1 or n < self.start + self.count


@dataclass(frozen=True)
class FaultPlan:
    """A seeded set of fault specs — the unit the chaos bench and the
    chaos tests are parameterised by."""

    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))


class ChaosInjector:
    """Deterministic fault scheduler over a :class:`FaultPlan`.

    ``fire(site)`` increments the site's invocation counter and returns
    the first spec whose window covers this invocation (and whose
    seeded coin came up), else ``None``.  ``clock`` (optional,
    ``perf_counter``-style) timestamps ``fire_log`` rows so benches can
    measure recovery time; ``sleep`` (optional, defaults to
    ``time.sleep``) is what ``latency`` faults call — pass a
    :class:`~repro.serving.traffic.VirtualClock`'s ``advance`` for
    virtual-time chaos runs.
    """

    def __init__(self, plan: Optional[FaultPlan] = None, *,
                 clock: Optional[Callable[[], float]] = None,
                 sleep: Optional[Callable[[float], None]] = None):
        self.plan = plan
        self.clock = clock
        if sleep is None:
            import time
            sleep = time.sleep
        self.sleep = sleep
        self._rng = np.random.default_rng(plan.seed if plan else 0)
        self._counters: Dict[str, int] = {}
        # (site, kind, invocation_index, clock_t) per fired fault
        self.fire_log: List[Tuple[str, str, int, float]] = []

    @property
    def armed(self) -> bool:
        return self.plan is not None and len(self.plan.specs) > 0

    def calls(self, site: str) -> int:
        return self._counters.get(site, 0)

    def fire(self, site: str) -> Optional[FaultSpec]:
        if not self.armed:
            return None
        n = self._counters.get(site, 0)
        self._counters[site] = n + 1
        for spec in self.plan.specs:
            if spec.site != site or not spec.eligible(n):
                continue
            if spec.prob < 1.0 and self._rng.random() >= spec.prob:
                continue
            t = self.clock() if self.clock is not None else 0.0
            self.fire_log.append((site, spec.kind, n, t))
            return spec
        return None

    def last_fire_t(self) -> Optional[float]:
        return self.fire_log[-1][3] if self.fire_log else None

    # -- shared kind application --------------------------------------

    def apply_error_kind(self, spec: FaultSpec, site: str) -> bool:
        """Handle the kinds every seam supports.  Raises for ``raise``/
        ``timeout``; sleeps and returns True (proceed) for ``latency``;
        returns False for kinds the caller must handle itself."""
        msg = spec.message or f"injected {spec.kind} at {site}"
        if spec.kind == "raise":
            raise TransientFaultError(msg)
        if spec.kind == "timeout":
            raise FaultTimeoutError(msg)
        if spec.kind == "latency":
            self.sleep(spec.latency_s)
            return True
        return False


# ---------------------------------------------------------------------------
# injection seams
# ---------------------------------------------------------------------------


class ChaosRetriever:
    """Fault seam around one retrieval-protocol object.  Site:
    ``retriever.<name>`` (topk and passages share the counter — one
    logical lookup, one fault opportunity)."""

    def __init__(self, inner, injector: ChaosInjector):
        self.inner = inner
        self.name = inner.name
        self.injector = injector
        self.site = f"retriever.{self.name}"

    def _maybe_fault(self) -> None:
        spec = self.injector.fire(self.site)
        if spec is None:
            return
        if not self.injector.apply_error_kind(spec, self.site):
            raise ValueError(
                f"fault kind {spec.kind!r} not supported at {self.site}")

    def topk(self, query: str, k: int):
        self._maybe_fault()
        return self.inner.topk(query, k)

    def passages(self, query: str, k: int):
        self._maybe_fault()
        return self.inner.passages(query, k)


class ChaosExecutor:
    """Fault seam around the :class:`DeviceExecutor` protocol.

    Sites: ``executor.admit`` (``raise``/``timeout``/``latency``) and
    ``executor.decode`` (those plus ``stall`` — the chunk call is
    swallowed, so no slot makes progress and the scheduler watchdog
    must fire — and ``nan`` — the spec's slots are flagged in
    ``slot_faults``, the same poisoned-slot signal real executors
    produce from device-side NaN/inf detection)."""

    def __init__(self, inner, injector: ChaosInjector):
        self._inner = inner
        self._injector = injector
        S = inner.num_slots
        self._injected_bad = np.zeros(S, bool)

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def admit(self, tokens, slot_idx, limits) -> None:
        spec = self._injector.fire("executor.admit")
        if spec is not None:
            self._injector.apply_error_kind(spec, "executor.admit")
        self._inner.admit(tokens, slot_idx, limits)

    def admit_paged(self, tokens, slot_idx, limits, pos0, tables,
                    write_mask, gather_src) -> None:
        # same seam/counter as admit: one admission, one fault chance
        spec = self._injector.fire("executor.admit")
        if spec is not None:
            self._injector.apply_error_kind(spec, "executor.admit")
        self._inner.admit_paged(tokens, slot_idx, limits, pos0, tables,
                                write_mask, gather_src)

    def decode_chunk(self) -> None:
        spec = self._injector.fire("executor.decode")
        if spec is not None:
            if spec.kind == "stall":
                return                    # silently no progress
            if spec.kind == "nan":
                slots = (spec.slots if spec.slots is not None
                         else range(self._inner.num_slots))
                for s in slots:
                    self._injected_bad[s] = True
                self._inner.decode_chunk()
                return
            self._injector.apply_error_kind(spec, "executor.decode")
        self._inner.decode_chunk()

    def sync_control(self):
        return self._inner.sync_control()

    def fetch_outputs(self):
        return self._inner.fetch_outputs()

    def slot_faults(self) -> Optional[np.ndarray]:
        inner = getattr(self._inner, "slot_faults", None)
        bad = self._injected_bad.copy()
        if inner is not None:
            got = inner()
            if got is not None:
                bad |= got
        return bad

    def clear_slot_faults(self, slots: Sequence[int]) -> None:
        for s in slots:
            self._injected_bad[s] = False
        inner = getattr(self._inner, "clear_slot_faults", None)
        if inner is not None:
            inner(slots)

    def deactivate(self, slots: Sequence[int]) -> None:
        inner = getattr(self._inner, "deactivate", None)
        if inner is not None:
            inner(slots)


def chaos_wrap_retrievers(retrievers: Dict[str, object],
                          injector: Optional[ChaosInjector]
                          ) -> Dict[str, object]:
    """Install retriever fault seams (innermost — inside breakers and
    the cache, so injected failures trip breakers and are never
    cached).  No-op (same dict) when the injector is unarmed."""
    if injector is None or not injector.armed:
        return dict(retrievers)
    return {name: ChaosRetriever(r, injector)
            for name, r in retrievers.items()}


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for transient faults.  The gateways
    never retry past a request's deadline, and every retry is counted
    (``GatewayStats.retries``).  ``max_retries=0`` disables."""

    max_retries: int = 1
    backoff_s: float = 0.05
    multiplier: float = 2.0

    def backoff(self, attempt: int) -> float:
        """Backoff before retry number ``attempt + 1`` (0-based)."""
        return self.backoff_s * (self.multiplier ** attempt)
