"""The RAG pipeline executor: action -> retrieve -> generate -> score.

This is the system under control: a routing policy (see
``repro.routing``) picks an action, the pipeline executes it against
the retrieval index and a generation backend, and emits the per-query
metrics the reward (eq. 1) consumes.  In the Gateway serve path this
pipeline sits behind ``repro.routing.backends.SimulatorBackend``.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core.actions import Action
from repro.routing.registry import ActionSpace, get_action_space
from repro.data.synthetic_squad import Question
from repro.generation.simulator import SimulatedGenerator
from repro.obs import NULL_TRACER
from repro.retrieval.bm25 import BM25Index
from repro.retrieval.hybrid import (Retriever, resolve_retrievers,
                                    retrieve_with_fallback)


@dataclass
class ActionOutcome:
    qid: int
    action: int
    correct: bool
    refused: bool
    hallucinated: bool
    cost_tokens: float
    hit: bool                 # gold answer string in retrieved set
    answerable: bool
    answer: str
    # engine capacity rejection (e.g. over-length prompt), not a policy
    # refusal — refused is still True so reward/error-budget accounting
    # treats the unserved request as an SLO violation, but downstream
    # consumers can tell the two apart (Gateway counts them separately)
    rejected: bool = False
    # fault-tolerance facets (all default False — healthy outcomes are
    # unchanged).  degraded: the action was served but rewritten to a
    # fallback (e.g. dense breaker open -> bm25 passages).  timed_out:
    # the request's deadline passed mid-flight and it was cancelled.
    # transient: the request failed on a retryable fault — the gateway
    # may resubmit it (bounded, deadline-aware) before accounting.
    degraded: bool = False
    timed_out: bool = False
    transient: bool = False
    # engine-clock stamps (0.0 = backend doesn't stamp): when the
    # continuous engine served this request, prefill completion and
    # generation finish — the Gateway's tracer slices its dispatch
    # window into prefill/decode spans with these instead of smearing
    # batch wall time across requests
    admitted_at: float = 0.0
    finished_at: float = 0.0

    def to_row(self) -> dict:
        return asdict(self)


class RAGPipeline:
    # telemetry: the owning backend installs the Gateway's tracer here
    # so retrieval spans are noted into the request trace (no-op default)
    tracer = NULL_TRACER

    def __init__(self, index: BM25Index, generator: SimulatedGenerator,
                 retrievers: Optional[Mapping[str, Retriever]] = None,
                 *, retrieval_cache_size: int = 0):
        self.index = index
        self.generator = generator
        # named retrievers behind the shared protocol; None = the
        # bm25-only seed behaviour (bit-for-bit).  cache_size > 0 puts
        # one bounded LRU in front of every retriever.
        self.retrievers, self.retrieval_cache = resolve_retrievers(
            retrievers, index, cache_size=retrieval_cache_size)

    def retrieve(self, question: str, k: int,
                 retriever: str = "bm25") -> Sequence[str]:
        if k <= 0:
            return []
        try:
            r = self.retrievers[retriever]
        except KeyError:
            raise KeyError(
                f"action retriever {retriever!r} not configured; "
                f"available: {sorted(self.retrievers)}") from None
        return r.passages(question, k)

    def retrieve_degradable(self, question: str, k: int,
                            retriever: str = "bm25"
                            ) -> tuple:
        """(passages, degraded) — like :meth:`retrieve`, but an open
        breaker or failing retriever degrades to the bm25 fallback
        instead of raising (raises TransientFaultError only when the
        fallback path fails too)."""
        if k <= 0:
            return [], False
        if retriever not in self.retrievers:
            raise KeyError(
                f"action retriever {retriever!r} not configured; "
                f"available: {sorted(self.retrievers)}")
        return retrieve_with_fallback(self.retrievers, retriever,
                                      question, k, tracer=self.tracer)

    def execute(self, q: Question, action: Action) -> ActionOutcome:
        if action.mode == "refuse":
            out = self.generator.refuse(q.qid, q.text)
            hit = False
            degraded = False
        else:
            passages, degraded = self.retrieve_degradable(
                q.text, action.k, action.retriever)
            out = self.generator.generate(
                q.qid, action.idx, action.mode, q.text, passages,
                answerable=q.answerable, gold_answer=q.gold_answer)
            hit = bool(q.gold_answer) and any(
                q.gold_answer in p for p in passages)
        return ActionOutcome(
            qid=q.qid, action=action.idx, correct=out.correct,
            refused=out.refused, hallucinated=out.hallucinated,
            cost_tokens=float(out.cost_tokens), hit=hit,
            answerable=q.answerable, answer=out.answer,
            degraded=degraded)

    def sweep(self, q: Question,
              space: Optional[ActionSpace] = None) -> list:
        """Full action sweep (paper §4.1) — one outcome per action of
        the given (default: paper) action space."""
        space = space if space is not None else get_action_space()
        return [self.execute(q, a) for a in space]
