"""Continuous-batching decode engine: slot-based KV cache, mid-stream
admission, K-step jitted decode chunks.

The padded-bucket :class:`~repro.serving.engine.Engine` allocates a
fresh KV cache per ``generate`` call, re-traces per ``(B, plen)`` shape,
and round-trips to host every decode token; each routed action bucket
runs as its own serial prefill+decode pass, so the decode batch drains
to nothing before the next bucket starts.  This engine replaces that
with the standard continuous-batching serving pattern:

* **One slot cache per engine lifetime.**  ``num_slots x max_len`` KV
  cache allocated once at construction; requests are *admitted* into
  free slots — up to ``prefill_batch`` equal-length queued prompts are
  prefilled together through a reusable scratch cache and their rows
  scattered into their slots (the JetStream prefill->insert pattern,
  with batched prefill).  No per-call or per-step allocation.
* **One decode trace.**  A single jitted K-step ``lax.scan`` advances
  *all* slots together; per-slot positions already live in the cache
  (``cache["pos"]``), so heterogeneous prompt lengths and admission
  times decode in the same batch.  Slot state (next token, done-mask,
  generated counts, output buffer) is device-resident; a sync every
  ``sync_every`` steps downloads only the two tiny control arrays, and
  the output buffer moves to host only when a slot finishes — nothing
  is uploaded per chunk and there is no per-token host round-trip.
* **Mid-stream admission.**  Finished slots free immediately at the
  next sync and queued requests are prefilled into them while other
  slots keep decoding — the batch never drains to serve a new action
  bucket, which is what lets the Gateway interleave deep-k and
  shallow-k routed requests in one stream.

Greedy semantics match the padded engine exactly: prefill emits the
first token (argmax of the last prompt logit), decode feeds the
previous token back, and a request stops after emitting EOS or
``max_new_tokens`` tokens.  ``prefill_pad_multiple`` right-pads prompts
to a length bucket with PAD tokens that attend — the same quirk as the
padded engine's right-padded buckets — trading exactness-of-trace-count
for numerics; the default (1) prefills at the exact prompt length.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS, PAD
from repro.models.registry import Model


@dataclass
class SlotRequest:
    rid: int
    prompt: List[int]
    max_new_tokens: int


@dataclass
class CompletedGeneration:
    rid: int
    tokens: np.ndarray        # (n,) generated tokens, incl. EOS if emitted
    n_steps: int              # == len(tokens)
    prompt_len: int
    finished_at: float = 0.0  # host wall clock at harvest (latency calc)


@dataclass
class EngineStats:
    n_admitted: int = 0
    n_completed: int = 0
    n_prefills: int = 0
    n_decode_chunks: int = 0
    n_decode_steps: int = 0
    cache_allocations: int = 0
    max_concurrent: int = 0
    # recent per-admission concurrency trace (bounded) — lets tests
    # assert requests from different action buckets were in flight
    # together without growing in long serving runs
    concurrency_trace: Deque[int] = field(
        default_factory=lambda: deque(maxlen=512))


class ContinuousEngine:
    """Slot-based continuous-batching greedy decoder."""

    def __init__(self, model: Model, params, *, num_slots: int = 8,
                 max_len: int = 512, max_new_cap: int = 64,
                 sync_every: int = 4, prefill_pad_multiple: int = 1,
                 prefill_batch: int = 1,
                 moe_fn: Optional[Callable] = None,
                 mla_absorb: bool = False):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.max_new_cap = max_new_cap
        self.sync_every = sync_every
        self.prefill_pad_multiple = max(1, prefill_pad_multiple)
        # admit up to this many equal-length queued prompts per prefill
        # dispatch (JetStream-style batched prefill); rows are
        # row-independent, so greedy outputs do not depend on grouping
        self.prefill_batch = max(1, min(prefill_batch, num_slots))
        self.moe_fn = moe_fn
        self.mla_absorb = mla_absorb
        self.stats = EngineStats()

        # the ONLY cache allocations in the engine's lifetime: the slot
        # cache and the prefill scratch (both reused forever)
        self._cache = model.init_cache(num_slots, max_len)
        self._pcache = model.init_cache(self.prefill_batch, max_len)
        self.stats.cache_allocations = 2

        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(1,))
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))
        self._decode_chunk = jax.jit(self._decode_chunk_fn,
                                     donate_argnums=(1, 2, 3, 4, 6))
        self._admit_update = jax.jit(self._admit_update_fn,
                                     donate_argnums=(0, 1, 2, 3, 4))

        # slot state lives ON DEVICE between chunks — a sync downloads
        # only the two tiny control arrays (active, gen); the output
        # buffer is fetched when a slot finishes, and nothing is
        # uploaded per chunk
        S, cap = num_slots, max_new_cap
        self._dtok = jnp.zeros(S, jnp.int32)    # next input token
        self._dactive = jnp.zeros(S, bool)
        self._dgen = jnp.zeros(S, jnp.int32)    # tokens generated so far
        self._dlimit = jnp.zeros(S, jnp.int32)  # per-slot max_new_tokens
        self._dout = jnp.zeros((S, cap), jnp.int32)
        # host mirrors for control flow / harvest
        self._active = np.zeros(S, bool)
        self._gen = np.zeros(S, np.int32)
        self._out = np.zeros((S, cap), np.int32)
        self._plen = np.zeros(S, np.int32)
        self._rid = [None] * S                  # slot -> request id
        self._free: Deque[int] = deque(range(S))
        self._queue: Deque[SlotRequest] = deque()
        self._results: Dict[int, CompletedGeneration] = {}
        self._auto_rid = 0

    # -- jitted bodies -------------------------------------------------

    def _prefill_fn(self, params, pcache, tokens):
        logits, pcache = self.model.prefill(params, {"tokens": tokens},
                                            pcache, moe_fn=self.moe_fn,
                                            mla_absorb=self.mla_absorb)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), pcache

    def _insert_fn(self, cache, pcache, slots):
        """Scatter the prefilled scratch rows into their slots.

        ``slots`` is (prefill_batch,) int32; unused scratch rows carry
        slot index ``num_slots`` and are dropped by the scatter."""
        def ins(bdim):
            def f(big, small):
                idx = (slice(None),) * bdim + (slots,)
                return big.at[idx].set(small.astype(big.dtype),
                                       mode="drop")
            return f
        new = dict(cache)
        new["pos"] = cache["pos"].at[slots].set(pcache["pos"], mode="drop")
        # prefix leaves are (B, ...); block leaves are (n_blocks, B, ...)
        new["prefix"] = jax.tree_util.tree_map(ins(0), cache["prefix"],
                                               pcache["prefix"])
        new["blocks"] = jax.tree_util.tree_map(ins(1), cache["blocks"],
                                               pcache["blocks"])
        return new

    def _admit_update_fn(self, tok, active, gen, limit, out,
                         slot_idx, firsts, limits):
        """Write the prefill results of one admission group into the
        device slot state (unused rows carry index num_slots -> drop)."""
        flags = (firsts != EOS) & (limits > 1)
        tok = tok.at[slot_idx].set(firsts, mode="drop")
        active = active.at[slot_idx].set(flags, mode="drop")
        gen = gen.at[slot_idx].set(1, mode="drop")
        limit = limit.at[slot_idx].set(limits, mode="drop")
        out = out.at[slot_idx, 0].set(firsts, mode="drop")
        return tok, active, gen, limit, out

    def _decode_chunk_fn(self, params, cache, tok, active, gen, limit, out):
        """`sync_every` decode steps over all slots, done-mask on device."""
        S, cap = out.shape
        sidx = jnp.arange(S)

        def step(carry, _):
            cache, tok, active, gen, out = carry
            pos0 = cache["pos"]
            inp = jnp.where(active, tok, PAD)
            logits, cache = self.model.decode(
                params, {"tokens": inp[:, None]}, cache, moe_fn=self.moe_fn,
                mla_absorb=self.mla_absorb)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            # hold position for idle slots (their kv write lands one past
            # their valid length and is masked / overwritten on admit)
            cache["pos"] = jnp.where(active, cache["pos"], pos0)
            # idle slots scatter out of bounds -> dropped
            wr = jnp.where(active, gen, cap)
            out = out.at[sidx, wr].set(nxt, mode="drop")
            gen = gen + active.astype(jnp.int32)
            active = active & (nxt != EOS) & (gen < limit)
            tok = jnp.where(active, nxt, tok)
            return (cache, tok, active, gen, out), None

        carry, _ = jax.lax.scan(step, (cache, tok, active, gen, out),
                                None, length=self.sync_every)
        return carry

    # -- host driver ---------------------------------------------------

    def reserve_rid(self) -> int:
        """Fresh request id, unique for this engine's lifetime."""
        rid = self._auto_rid
        self._auto_rid += 1
        return rid

    def submit(self, rid: int, prompt: Sequence[int],
               max_new_tokens: int = 16) -> None:
        if not prompt:
            raise ValueError("empty prompt")
        max_new = min(max_new_tokens, self.max_new_cap)
        plen = self._padded_len(len(prompt))
        if plen + max_new > self.max_len:
            raise ValueError(
                f"prompt len {plen} + max_new {max_new} exceeds "
                f"max_len {self.max_len}")
        self._queue.append(SlotRequest(rid, list(prompt), max_new))

    def _padded_len(self, n: int) -> int:
        m = self.prefill_pad_multiple
        return ((n + m - 1) // m) * m

    def _admit(self) -> None:
        PB = self.prefill_batch
        while self._free and self._queue:
            # group up to prefill_batch queued requests with the same
            # padded prompt length into one prefill dispatch
            group = [self._queue.popleft()]
            plen = self._padded_len(len(group[0].prompt))
            while (len(group) < min(PB, len(self._free)) and self._queue
                   and self._padded_len(len(self._queue[0].prompt)) == plen):
                group.append(self._queue.popleft())
            slots = [self._free.popleft() for _ in group]
            toks = np.full((PB, plen), PAD, np.int32)
            for i, req in enumerate(group):
                toks[i, :len(req.prompt)] = req.prompt
            # unused scratch rows scatter to index num_slots -> dropped
            slot_idx = np.full(PB, self.num_slots, np.int32)
            slot_idx[:len(group)] = slots
            limits = np.zeros(PB, np.int32)
            limits[:len(group)] = [req.max_new_tokens for req in group]
            firsts, self._pcache = self._prefill(self.params, self._pcache,
                                                 jnp.asarray(toks))
            self._cache = self._insert(self._cache, self._pcache,
                                       jnp.asarray(slot_idx))
            (self._dtok, self._dactive, self._dgen, self._dlimit,
             self._dout) = self._admit_update(
                self._dtok, self._dactive, self._dgen, self._dlimit,
                self._dout, jnp.asarray(slot_idx), firsts,
                jnp.asarray(limits))
            # the only per-group host sync: the first tokens (to mirror
            # active/gen for the host-side scheduler)
            firsts = np.asarray(firsts)
            self.stats.n_prefills += 1
            for i, (req, slot) in enumerate(zip(group, slots)):
                self.stats.n_admitted += 1
                self._rid[slot] = req.rid
                self._plen[slot] = plen
                self._gen[slot] = 1
                self._active[slot] = (int(firsts[i]) != EOS) and \
                    (req.max_new_tokens > 1)
            n_live = sum(r is not None for r in self._rid)
            self.stats.concurrency_trace.append(n_live)
            self.stats.max_concurrent = max(self.stats.max_concurrent, n_live)

    def _decode_and_sync(self) -> None:
        (self._cache, self._dtok, self._dactive, self._dgen,
         self._dout) = self._decode_chunk(
            self.params, self._cache, self._dtok, self._dactive,
            self._dgen, self._dlimit, self._dout)
        # the every-K host sync: only the two tiny control arrays come
        # back (np.array copies — device views are read-only)
        self._active = np.array(self._dactive)
        self._gen = np.array(self._dgen)
        self.stats.n_decode_chunks += 1
        self.stats.n_decode_steps += self.sync_every

    def _harvest(self) -> None:
        done_slots = [s for s in range(self.num_slots)
                      if self._rid[s] is not None and not self._active[s]]
        if not done_slots:
            return
        # fetch the output buffer only when something actually finished
        self._out = np.array(self._dout)
        now = time.time()
        for slot in done_slots:
            n = int(self._gen[slot])
            self._results[self._rid[slot]] = CompletedGeneration(
                rid=self._rid[slot], tokens=self._out[slot, :n].copy(),
                n_steps=n, prompt_len=int(self._plen[slot]),
                finished_at=now)
            self.stats.n_completed += 1
            self._rid[slot] = None
            self._free.append(slot)

    def run(self) -> Dict[int, CompletedGeneration]:
        """Drain the queue; returns {rid: CompletedGeneration} for every
        request completed since the last call."""
        while self._queue or any(r is not None for r in self._rid):
            self._admit()
            self._harvest()          # requests finished at prefill time
            if self._active.any():
                self._decode_and_sync()
                self._harvest()
        done, self._results = self._results, {}
        return done

    def generate_many(self, prompts: Sequence[Sequence[int]],
                      max_new_tokens: int = 16) -> List[CompletedGeneration]:
        """Batch convenience API (aligned with `prompts` order)."""
        rids = [self.reserve_rid() for _ in prompts]
        for rid, p in zip(rids, prompts):
            self.submit(rid, p, max_new_tokens)
        done = self.run()
        return [done[rid] for rid in rids]
