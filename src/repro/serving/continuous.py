"""Continuous-batching serving: a device-agnostic host scheduler over a
pluggable :class:`~repro.serving.executor.DeviceExecutor`.

The engine is split into two layers:

* **Host scheduler** (this module, pure numpy — no JAX import): request
  queue, admission grouping, slot ownership, host mirrors of the tiny
  control arrays, and harvest of finished generations.  It talks to the
  device exclusively through the executor protocol (``admit`` /
  ``decode_chunk`` / ``sync_control`` / ``fetch_outputs``), so it can be
  unit-tested with a pure numpy fake executor.
* **Device executor** (:mod:`repro.serving.executor`): the jitted
  prefill / fused insert+commit / K-step decode-chunk programs and the
  once-per-lifetime slot cache.  ``SingleDeviceExecutor`` runs on the
  default device; ``ShardedExecutor`` lays the slot dimension out over
  a ``jax.sharding.Mesh`` (slots on the data axis, params
  tensor-parallel on the model axis when ``mp>1``) so the same
  scheduler drives N devices.

**Prefill/decode overlap.**  Executor calls are async dispatch; the
scheduler exploits that by dispatching the decode chunk for resident
slots FIRST, then planning and dispatching the next admission groups'
prefills while that chunk is in flight, and only then blocking on the
control-array sync.  Admission therefore no longer stalls the decode
stream: the prefill program (which touches only the scratch cache)
overlaps with the chunk, and the insert/commit serializes behind it via
its data dependency on the slot cache.  Newly admitted slots join the
next chunk — greedy outputs are row-independent, so outputs are
token-identical to the serial schedule.

**Admission grouping.**  Up to ``prefill_batch`` queued prompts with
the same padded length prefill as one dispatch (JetStream's batched
prefill->insert pattern).  Grouping scans a bounded
``admission_lookahead`` window of the queue, so one odd-length prompt
at the head no longer degrades batched prefill to singletons
(head-of-line blocking); skipped prompts keep their relative order.

Greedy semantics match the padded engine exactly: prefill emits the
first token (argmax of the last prompt logit), decode feeds the
previous token back, and a request stops after emitting EOS or
``max_new_tokens`` tokens.  ``prefill_pad_multiple`` right-pads prompts
to a length bucket with PAD tokens that attend — the same quirk as the
padded engine's right-padded buckets — trading exactness-of-trace-count
for numerics; the default (1) prefills at the exact prompt length.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Set

import numpy as np

from repro.core.errors import TransientFaultError
from repro.data.tokenizer import PAD
from repro.obs import NULL_TRACER


@dataclass
class SlotRequest:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    # engine-clock instant after which the request is worthless; 0 = no
    # deadline.  Enforced mid-stream: a resident slot past its deadline
    # is cancelled and freed at the next control sync.
    deadline_at: float = 0.0


@dataclass
class CompletedGeneration:
    rid: int
    tokens: np.ndarray        # (n,) generated tokens, incl. EOS if emitted
    n_steps: int              # == len(tokens)
    prompt_len: int
    finished_at: float = 0.0  # engine clock at harvest (latency)
    # engine clock when the prefill was dispatched — the prefill emits
    # the request's first token, so this is the time-to-first-token
    # stamp open-loop serving reports against per-request deadlines
    admitted_at: float = 0.0
    failed: str = ""          # non-empty: not served (reason)
    # failed on a retryable fault (quarantined slot, executor fault) —
    # the gateway may resubmit within the request's deadline
    transient: bool = False
    # cancelled mid-stream because its deadline passed (distinct from
    # transient: retrying a timed-out request cannot help)
    timed_out: bool = False


@dataclass
class EngineStats:
    n_admitted: int = 0
    n_completed: int = 0
    n_rejected: int = 0       # refused at submit (over-length / empty)
    n_prefills: int = 0
    n_decode_chunks: int = 0
    n_decode_steps: int = 0
    cache_allocations: int = 0
    max_concurrent: int = 0
    # fault-tolerance counters (all zero on a healthy run)
    n_quarantined: int = 0    # slots pulled from service (nan + watchdog)
    n_nan_trips: int = 0      # quarantines from device NaN/inf detection
    n_watchdog_trips: int = 0  # quarantines from the no-progress watchdog
    n_exec_faults: int = 0    # executor admit/decode calls that raised
    n_requeued: int = 0       # faulted requests re-admitted by the engine
    n_timed_out: int = 0      # requests cancelled past their deadline
    # paged-KV-cache counters (all zero on dense engines)
    n_deferred_admissions: int = 0   # page pool exhausted -> retried later
    n_pages_evicted: int = 0         # prefix-cache LRU evictions
    n_cow_forks: int = 0             # mid-page suffix copy-on-write forks
    prefill_tokens_avoided: int = 0  # prompt tokens served from shared pages
    prompt_tokens_total: int = 0     # all admitted (padded) prompt tokens
    # recent per-admission concurrency trace (bounded) — lets tests
    # assert requests from different action buckets were in flight
    # together without growing in long serving runs
    concurrency_trace: Deque[int] = field(
        default_factory=lambda: deque(maxlen=512))


class ContinuousEngine:
    """Slot-based continuous-batching greedy decoder (host scheduler).

    Construct either from ``(model, params)`` — which builds a
    :class:`~repro.serving.executor.SingleDeviceExecutor`, or a
    :class:`~repro.serving.executor.ShardedExecutor` when ``mesh`` is
    given — or from an explicit ``executor`` (any object implementing
    the executor protocol; the fake in the scheduler tests is numpy).
    """

    # telemetry: the Gateway's tracer lands here via the backend's
    # install_tracer (engine decode-chunk / prefill-dispatch spans);
    # the default is the zero-overhead no-op
    tracer = NULL_TRACER

    def __init__(self, model=None, params=None, *, num_slots: int = 8,
                 max_len: int = 512, max_new_cap: int = 64,
                 sync_every: int = 4, prefill_pad_multiple: int = 1,
                 prefill_batch: int = 1, admission_lookahead: int = 16,
                 moe_fn=None, mla_absorb: bool = False,
                 mesh=None, executor=None, clock=None,
                 watchdog_syncs: int = 8, max_requeues: int = 0,
                 chaos=None, paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefix_sharing: bool = True, metrics=None):
        if executor is None:
            if model is None:
                raise ValueError("ContinuousEngine needs model+params or "
                                 "an explicit executor")
            from repro.serving.executor import (ShardedExecutor,
                                                SingleDeviceExecutor)
            kw = dict(num_slots=num_slots, max_len=max_len,
                      max_new_cap=max_new_cap, sync_every=sync_every,
                      prefill_batch=prefill_batch, moe_fn=moe_fn,
                      mla_absorb=mla_absorb, paged=paged,
                      page_size=page_size, num_pages=num_pages,
                      metrics=metrics)
            executor = (ShardedExecutor(model, params, mesh=mesh, **kw)
                        if mesh is not None
                        else SingleDeviceExecutor(model, params, **kw))
        if chaos is not None and getattr(chaos, "armed", False):
            from repro.serving.faults import ChaosExecutor
            executor = ChaosExecutor(executor, chaos)
        self.executor = executor
        self.model = model
        self.params = params
        self.num_slots = executor.num_slots
        self.max_len = executor.max_len
        self.max_new_cap = executor.max_new_cap
        self.sync_every = executor.sync_every
        self.prefill_batch = executor.prefill_batch
        self.prefill_pad_multiple = max(1, prefill_pad_multiple)
        self.admission_lookahead = max(0, admission_lookahead)
        # timestamp source for admitted_at / finished_at.  Injectable so
        # the open-loop traffic harness can drive the engine on a
        # virtual clock (deterministic latency accounting); default is
        # the host monotonic clock.
        self._clock = clock if clock is not None else time.perf_counter
        # watchdog: quarantine a slot after this many consecutive syncs
        # with an active slot making zero token progress (0 = off)
        self.watchdog_syncs = max(0, watchdog_syncs)
        # how many times a faulted (quarantined / executor-fault)
        # request is re-admitted before failing as transient (0 = fail
        # immediately; the gateway layer owns deadline-aware retries)
        self.max_requeues = max(0, max_requeues)
        self.stats = EngineStats()
        self.stats.cache_allocations = executor.cache_allocations

        # paged KV cache: host-side allocator + prefix cache mirroring
        # the executor's device page pool.  `_slot_plan[s]` holds the
        # resident request's PagePlan (its page references) until the
        # slot is released on harvest / quarantine / expiry / abort.
        self._pages = None
        self._slot_plan: List[Optional[object]] = [None] * self.num_slots
        if getattr(executor, "paged", False):
            from repro.serving.paged import PagePool
            self._pages = PagePool(
                executor.num_pages, executor.page_size,
                partitions=getattr(executor, "page_partitions", 1),
                prefix_sharing=prefix_sharing)

        S = self.num_slots
        # host mirrors of the device control arrays (refreshed at sync)
        self._active = np.zeros(S, bool)
        self._gen = np.zeros(S, np.int32)
        self._plen = np.zeros(S, np.int32)
        self._rid: List[Optional[int]] = [None] * S
        # the resident request per slot (needed to requeue on fault and
        # to enforce its deadline mid-stream)
        self._slot_req: List[Optional[SlotRequest]] = [None] * S
        # slots admitted since the last sync: their host mirrors are
        # stale, so harvest must not touch them until the next sync
        self._dirty: Set[int] = set()
        # poisoned slots pulled from service — never re-admitted until
        # reset_quarantine() clears their fault flags
        self._quarantined: Set[int] = set()
        self._stall = np.zeros(S, np.int32)      # consecutive no-progress
        self._last_gen = np.full(S, -1, np.int32)  # -1 = just admitted
        self._requeues: Dict[int, int] = {}
        self._free: Deque[int] = deque(range(S))
        self._queue: Deque[SlotRequest] = deque()
        self._results: Dict[int, CompletedGeneration] = {}
        self._admitted_at: Dict[int, float] = {}
        self._auto_rid = 0
        self._bound_registries: Set[int] = set()
        if metrics is not None:
            self.bind_metrics(metrics)

    def bind_metrics(self, registry) -> None:
        """Register :class:`EngineStats` (and the page pool, when
        paged) as scrape-time views over ``registry``.  Idempotent per
        registry so the Gateway's bind and a constructor-passed
        registry don't double-register the names."""
        if id(registry) in self._bound_registries:
            return
        self._bound_registries.add(id(registry))
        fields = ("n_admitted", "n_completed", "n_rejected", "n_prefills",
                  "n_decode_chunks", "n_decode_steps", "n_quarantined",
                  "n_nan_trips", "n_watchdog_trips", "n_exec_faults",
                  "n_requeued", "n_timed_out", "n_deferred_admissions",
                  "n_pages_evicted", "n_cow_forks",
                  "prefill_tokens_avoided", "prompt_tokens_total")
        counters = {f: registry.counter(f"engine_{f}_total")
                    for f in fields}
        concur_g = registry.gauge("engine_concurrent_slots",
                                  "resident requests right now")
        max_concur_g = registry.gauge("engine_max_concurrent",
                                      "peak resident requests")
        queue_g = registry.gauge("engine_queue_depth",
                                 "requests queued for admission")

        def scrape() -> None:
            st = self.stats
            for f, inst in counters.items():
                inst.set_total(getattr(st, f))
            concur_g.set(self.n_resident)
            max_concur_g.set(st.max_concurrent)
            queue_g.set(len(self._queue))

        registry.register_collector(scrape)
        if self._pages is not None:
            self._pages.bind_metrics(registry)

    # -- submission ----------------------------------------------------

    def reserve_rid(self) -> int:
        """Fresh request id, unique for this engine's lifetime."""
        rid = self._auto_rid
        self._auto_rid += 1
        return rid

    def submit(self, rid: int, prompt: Sequence[int],
               max_new_tokens: int = 16, *, strict: bool = True,
               deadline_at: float = 0.0) -> bool:
        """Enqueue one request.  Returns True when accepted.

        An over-length prompt (padded length + generation budget beyond
        ``max_len``) or an empty prompt cannot be admitted.  With
        ``strict=True`` (default) that raises ``ValueError``; with
        ``strict=False`` the request is rejected PER-REQUEST instead:
        it completes immediately as a failed :class:`CompletedGeneration`
        (``failed`` holds the reason) returned by the next ``run()``,
        and the rest of the stream — other requests' resident slots
        included — keeps serving.  The serving Gateway uses the
        non-strict path so one long prompt in a routed batch can't kill
        the whole micro-batch mid-flight.
        """
        reason = ""
        plen = len(prompt)
        if not prompt:
            reason = "empty prompt"
        else:
            max_new = min(max_new_tokens, self.max_new_cap)
            plen = self._padded_len(len(prompt))
            if plen + max_new > self.max_len:
                reason = (f"prompt len {plen} + max_new {max_new} exceeds "
                          f"max_len {self.max_len}")
        if reason:
            if strict:
                raise ValueError(reason)
            self.stats.n_rejected += 1
            now = self._clock()
            self._results[rid] = CompletedGeneration(
                rid=rid, tokens=np.zeros(0, np.int32), n_steps=0,
                prompt_len=plen, finished_at=now, admitted_at=now,
                failed=reason)
            return False
        self._queue.append(SlotRequest(rid, list(prompt), max_new,
                                       deadline_at=deadline_at))
        return True

    def _padded_len(self, n: int) -> int:
        m = self.prefill_pad_multiple
        return ((n + m - 1) // m) * m

    # -- admission planning --------------------------------------------

    def _partition(self, slot: int) -> int:
        """Page-pool partition owning ``slot``'s pages: slots and pages
        both shard contiguously over the mesh data axis."""
        return slot * self._pages.partitions // self.num_slots

    def _preview_p0(self, req: SlotRequest, slot: int, plen: int) -> int:
        row = list(req.prompt) + [PAD] * (plen - len(req.prompt))
        return self._pages.preview_hit_tokens(row, self._partition(slot))

    def _next_group(self) -> List[SlotRequest]:
        """Pop the next admission group off the queue: the head plus up
        to ``prefill_batch - 1`` more prompts with the same padded
        length from a bounded lookahead window (skipped prompts keep
        their relative queue order).  A paged engine additionally
        requires the same previewed prefix-hit depth ``p0`` — the whole
        group prefills one uniform suffix ``[p0, plen)`` — previewing
        each candidate against the partition of the free slot it would
        actually receive (members take free slots in deque order)."""
        cap = min(self.prefill_batch, len(self._free))
        head = self._queue.popleft()
        group = [head]
        if cap > 1 and self.admission_lookahead > 0:
            plen = self._padded_len(len(head.prompt))
            head_p0 = (self._preview_p0(head, self._free[0], plen)
                       if self._pages is not None else 0)
            picked: List[int] = []
            for i in range(min(len(self._queue), self.admission_lookahead)):
                if 1 + len(picked) >= cap:
                    break
                req = self._queue[i]
                if self._padded_len(len(req.prompt)) != plen:
                    continue
                if (self._pages is not None and self._preview_p0(
                        req, self._free[1 + len(picked)], plen) != head_p0):
                    continue
                picked.append(i)
            group += [self._queue[i] for i in picked]
            for i in reversed(picked):
                del self._queue[i]
        return group

    def _plan_group(self, toks: np.ndarray, group: List[SlotRequest],
                    slots: List[int]):
        """Reserve pages for every row of an admission group.  Returns
        the plans, or ``None`` — with every reserved reference released
        — when the pool cannot serve the group (back-pressure) or an
        eviction during planning changed a later row's hit depth (the
        deferred group re-previews consistently on the next step)."""
        plans = []
        p0: Optional[int] = None
        for row, req, slot in zip(toks, group, slots):
            pl = self._pages.plan([int(t) for t in row],
                                  int(req.max_new_tokens),
                                  self._partition(slot))
            if pl is None or (p0 is not None and pl.p0 != p0):
                if pl is not None:
                    self._pages.release(pl)
                for q in plans:
                    self._pages.release(q)
                return None
            p0 = pl.p0
            plans.append(pl)
        return plans

    def _dispatch_paged(self, toks: np.ndarray, slot_idx: np.ndarray,
                        limits: np.ndarray, plans) -> None:
        """Build the device-side admission arrays from the plans and
        dispatch the gather + suffix-prefill + paged commit."""
        ex = self.executor
        PB = self.prefill_batch
        MB, MBs, NP = ex.max_blocks, ex.mb_scratch, ex.num_pages
        p0 = plans[0].p0
        tables = np.zeros((PB, MB), np.int32)
        wmask = np.zeros((PB, MBs), bool)
        gsrc = np.full((PB, MBs), NP, np.int32)
        pos0 = np.zeros(PB, np.int32)
        for i, pl in enumerate(plans):
            tables[i, :len(pl.pages)] = pl.pages
            wm = pl.write_mask[:MBs]
            wmask[i, :len(wm)] = wm
            gsrc[i, :len(pl.gather_src)] = pl.gather_src
            pos0[i] = pl.p0
        ex.admit_paged(np.ascontiguousarray(toks[:, p0:]), slot_idx,
                       limits, pos0, tables, wmask, gsrc)

    def _start_admissions(self) -> None:
        """Dispatch prefill+insert for every admittable group — async,
        no host sync; the admitted slots stay ``dirty`` until the next
        control sync reveals their device state.

        A transient executor fault on ``admit`` fails (or requeues)
        only that group's requests, returns its slots to the free pool,
        and stops admitting for this step — the decode stream and the
        rest of the queue keep serving."""
        PB = self.prefill_batch
        while self._free and self._queue:
            group = self._next_group()
            slots = [self._free.popleft() for _ in group]
            plen = self._padded_len(len(group[0].prompt))
            toks = np.full((PB, plen), PAD, np.int32)
            for i, req in enumerate(group):
                toks[i, :len(req.prompt)] = req.prompt
            # unused scratch rows scatter to index num_slots -> dropped
            slot_idx = np.full(PB, self.num_slots, np.int32)
            slot_idx[:len(group)] = slots
            limits = np.zeros(PB, np.int32)
            limits[:len(group)] = [req.max_new_tokens for req in group]
            plans = None
            if self._pages is not None:
                plans = self._plan_group(toks, group, slots)
                if plans is None:
                    # pool exhausted (or plan/preview divergence): put
                    # the group back and retry after decode frees pages
                    for slot in reversed(slots):
                        self._free.appendleft(slot)
                    for req in reversed(group):
                        self._queue.appendleft(req)
                    self.stats.n_deferred_admissions += 1
                    break
            t_adm0 = self.tracer.now()
            try:
                if plans is not None:
                    self._dispatch_paged(toks, slot_idx, limits, plans)
                else:
                    self.executor.admit(toks, slot_idx, limits)
            except TransientFaultError as exc:
                self.stats.n_exec_faults += 1
                if plans is not None:
                    for pl in plans:
                        self._pages.release(pl)
                for slot in reversed(slots):
                    self._free.appendleft(slot)
                for req in reversed(group):
                    self._fail_or_requeue(req, f"admit fault: {exc}",
                                          prompt_len=plen)
                break
            if plans is not None:
                # register AFTER the successful dispatch: pages become
                # sharable only once the commit that fills them is in
                # program order (same-group twins never share)
                for slot, pl in zip(slots, plans):
                    self._pages.commit(pl)
                    self._slot_plan[slot] = pl
                self.stats.prefill_tokens_avoided += plans[0].p0 * len(group)
                self.stats.prompt_tokens_total += plen * len(group)
                self.stats.n_cow_forks = self._pages.n_cow_forks
                self.stats.n_pages_evicted = self._pages.n_evicted
            self.stats.n_prefills += 1
            self.tracer.engine_span("prefill_dispatch", t_adm0,
                                    self.tracer.now(), n=len(group),
                                    plen=int(plen))
            now = self._clock()
            for req, slot in zip(group, slots):
                self.stats.n_admitted += 1
                self._rid[slot] = req.rid
                self._slot_req[slot] = req
                self._plen[slot] = plen
                self._admitted_at[req.rid] = now
                self._dirty.add(slot)
                self._stall[slot] = 0
                self._last_gen[slot] = -1
            n_live = sum(r is not None for r in self._rid)
            self.stats.concurrency_trace.append(n_live)
            self.stats.max_concurrent = max(self.stats.max_concurrent,
                                            n_live)

    # -- sync + harvest ------------------------------------------------

    def _sync(self) -> None:
        self._active, self._gen = self.executor.sync_control()
        self._dirty.clear()

    def _harvest(self) -> None:
        done_slots = [s for s in range(self.num_slots)
                      if self._rid[s] is not None and not self._active[s]
                      and s not in self._dirty]
        if not done_slots:
            return
        # fetch the output buffer only when something actually finished
        out = self.executor.fetch_outputs()
        now = self._clock()
        for slot in done_slots:
            n = int(self._gen[slot])
            rid = self._rid[slot]
            self._results[rid] = CompletedGeneration(
                rid=rid, tokens=out[slot, :n].copy(),
                n_steps=n, prompt_len=int(self._plen[slot]),
                finished_at=now,
                admitted_at=self._admitted_at.pop(rid, now))
            self.stats.n_completed += 1
            self._requeues.pop(rid, None)
            self._rid[slot] = None
            self._slot_req[slot] = None
            self._release_slot_pages(slot)
            self._free.append(slot)

    # -- fault tolerance -----------------------------------------------

    def _release_slot_pages(self, slot: int) -> None:
        """Drop a released slot's page references (paged engines only).
        Safe at harvest/quarantine/expiry: any in-flight program that
        could read the pages was dispatched before the commit that may
        later overwrite them, and an idle slot's decode write parks at
        a sentinel position past its block table."""
        if self._pages is None:
            return
        pl = self._slot_plan[slot]
        if pl is not None:
            self._pages.release(pl)
            self._slot_plan[slot] = None

    def _fail_or_requeue(self, req: SlotRequest, reason: str, *,
                         prompt_len: int = 0) -> None:
        """A request hit a transient fault: put it back at the queue
        head (up to ``max_requeues`` times) or complete it failed with
        ``transient=True`` so the gateway's retry path can take over."""
        self._admitted_at.pop(req.rid, None)
        if self._requeues.get(req.rid, 0) < self.max_requeues:
            self._requeues[req.rid] = self._requeues.get(req.rid, 0) + 1
            self.stats.n_requeued += 1
            self._queue.appendleft(req)
            return
        self._requeues.pop(req.rid, None)
        now = self._clock()
        self._results[req.rid] = CompletedGeneration(
            rid=req.rid, tokens=np.zeros(0, np.int32), n_steps=0,
            prompt_len=prompt_len or self._padded_len(len(req.prompt)),
            finished_at=now, admitted_at=now, failed=reason,
            transient=True)

    def _quarantine(self, slot: int, reason: str) -> None:
        """Pull a poisoned slot from service: deactivate it on device,
        fail/requeue ONLY its request, and keep the slot out of the
        free pool until :meth:`reset_quarantine` — its peers in the
        batch keep decoding untouched."""
        self._quarantined.add(slot)
        self.stats.n_quarantined += 1
        deact = getattr(self.executor, "deactivate", None)
        if deact is not None:
            deact([slot])
        self._active[slot] = False
        req = self._slot_req[slot]
        self._rid[slot] = None
        self._slot_req[slot] = None
        self._release_slot_pages(slot)
        if req is not None:
            self._fail_or_requeue(req, reason)

    def _check_health(self) -> None:
        """Post-sync health pass: device-detected NaN/inf poison flags,
        then the no-progress watchdog.  Runs BEFORE harvest so a
        poisoned slot (deactivated on device by the executor) is
        quarantined rather than harvested as a normal completion."""
        sf = getattr(self.executor, "slot_faults", None)
        if sf is not None:
            bad = sf()
            if bad is not None:
                for s in np.flatnonzero(bad):
                    s = int(s)
                    if (self._rid[s] is not None and s not in self._dirty
                            and s not in self._quarantined):
                        self.stats.n_nan_trips += 1
                        self._quarantine(s, "nan/inf decode logits")
        if self.watchdog_syncs <= 0:
            return
        for s in range(self.num_slots):
            if (self._rid[s] is None or s in self._dirty
                    or not self._active[s]):
                continue
            if self._last_gen[s] >= 0 and self._gen[s] == self._last_gen[s]:
                self._stall[s] += 1
                if self._stall[s] >= self.watchdog_syncs:
                    self.stats.n_watchdog_trips += 1
                    self._quarantine(s, "watchdog: no token progress")
                    continue
            else:
                self._stall[s] = 0
            self._last_gen[s] = self._gen[s]

    def _expire_residents(self) -> None:
        """Cancel resident requests whose deadline has passed: the slot
        is deactivated and freed immediately (a slow generation cannot
        hold a slot past its SLO) and the request completes as a
        distinct timed-out failure.  Queued requests past deadline are
        timed out before wasting a prefill."""
        now = self._clock()
        expired = [s for s in range(self.num_slots)
                   if self._slot_req[s] is not None and s not in self._dirty
                   and s not in self._quarantined
                   and 0 < self._slot_req[s].deadline_at < now]
        if expired:
            deact = getattr(self.executor, "deactivate", None)
            if deact is not None:
                deact(expired)
        for s in expired:
            req = self._slot_req[s]
            self._time_out(req, admitted_at=self._admitted_at.pop(
                req.rid, now))
            self._active[s] = False
            self._rid[s] = None
            self._slot_req[s] = None
            self._release_slot_pages(s)
            self._free.append(s)
        if self._queue:
            keep = deque()
            for req in self._queue:
                if 0 < req.deadline_at < now:
                    self._time_out(req, admitted_at=now)
                else:
                    keep.append(req)
            self._queue = keep

    def _time_out(self, req: SlotRequest, *, admitted_at: float) -> None:
        self.stats.n_timed_out += 1
        self._requeues.pop(req.rid, None)
        self._results[req.rid] = CompletedGeneration(
            rid=req.rid, tokens=np.zeros(0, np.int32), n_steps=0,
            prompt_len=self._padded_len(len(req.prompt)),
            finished_at=self._clock(), admitted_at=admitted_at,
            failed="deadline exceeded", timed_out=True)

    def _abort_residents(self, reason: str) -> None:
        """A decode chunk raised: every resident request aborts (requeue
        or transient failure), slots return to the free pool, and the
        serving loop stays alive."""
        slots = [s for s in range(self.num_slots)
                 if self._rid[s] is not None]
        deact = getattr(self.executor, "deactivate", None)
        if deact is not None and slots:
            deact(slots)
        for s in slots:
            req = self._slot_req[s]
            self._rid[s] = None
            self._slot_req[s] = None
            self._active[s] = False
            self._stall[s] = 0
            self._last_gen[s] = -1
            self._release_slot_pages(s)
            self._free.append(s)
            if req is not None:
                self._fail_or_requeue(req, reason)
        self._dirty.clear()

    @property
    def quarantined_slots(self) -> Set[int]:
        return set(self._quarantined)

    def reset_quarantine(self) -> List[int]:
        """Return quarantined slots to service (operator/bench action
        after the underlying fault clears): fault flags are reset on
        the device and the slots rejoin the free pool."""
        slots = sorted(self._quarantined)
        if not slots:
            return []
        clear = getattr(self.executor, "clear_slot_faults", None)
        if clear is not None:
            clear(slots)
        for s in slots:
            self._stall[s] = 0
            self._last_gen[s] = -1
            self._free.append(s)
        self._quarantined.clear()
        return slots

    # -- driver --------------------------------------------------------

    @property
    def has_work(self) -> bool:
        """Queued or slot-resident requests exist (rejected/finished
        results awaiting a ``poll``/``run`` don't count as work)."""
        return bool(self._queue) or any(r is not None for r in self._rid)

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_resident(self) -> int:
        return sum(r is not None for r in self._rid)

    def step(self) -> None:
        """ONE scheduling iteration: harvest, then either a decode
        chunk (with the next admission groups' prefills overlapped) or,
        with no resident work, just admissions.  This is ``run()``'s
        loop body split out so an always-on serving thread can
        interleave engine progress with new submissions instead of
        draining to empty.

        Fault handling: a transient executor fault on the decode chunk
        aborts (requeues or fails) the resident requests and returns —
        the loop survives and keeps admitting.  After every control
        sync a health pass quarantines poisoned slots (device NaN/inf
        flags, no-progress watchdog) and a deadline pass cancels
        expired requests, both BEFORE harvest."""
        self._harvest()
        if self._active.any():
            # decode chunk first (async), then overlap the next
            # admission groups' prefills with it; block only at the
            # control sync
            tr = self.tracer
            t_chunk0 = tr.now()
            try:
                self.executor.decode_chunk()
            except TransientFaultError as exc:
                self.stats.n_exec_faults += 1
                self._abort_residents(f"decode fault: {exc}")
                return
            self.stats.n_decode_chunks += 1
            self.stats.n_decode_steps += self.sync_every
            self._start_admissions()
            self._sync()
            # dispatch→post-sync wall of this K-step chunk (the prefills
            # overlapped above render as nested engine-track spans)
            tr.engine_span("decode_chunk", t_chunk0, tr.now(),
                           steps=self.sync_every)
            self._check_health()
            self._expire_residents()
            self._harvest()
        else:
            self._start_admissions()
            if self._dirty:
                self._sync()
                self._check_health()
                self._expire_residents()
                self._harvest()
            elif self._queue:
                self._expire_residents()
                if not self._free and self.n_resident == 0:
                    # every slot is quarantined: nothing can ever be
                    # admitted — fail the queue transiently rather than
                    # spinning forever (callers see resolved requests)
                    while self._queue:
                        req = self._queue.popleft()
                        now = self._clock()
                        self._requeues.pop(req.rid, None)
                        self._results[req.rid] = CompletedGeneration(
                            rid=req.rid, tokens=np.zeros(0, np.int32),
                            n_steps=0,
                            prompt_len=self._padded_len(len(req.prompt)),
                            finished_at=now, admitted_at=now,
                            failed="all slots quarantined",
                            transient=True)

    def poll(self) -> Dict[int, CompletedGeneration]:
        """Advance the engine by one ``step`` (when it has work) and
        return every request completed since the last ``poll``/``run``
        — including submit-time rejections.  Never blocks waiting for
        the stream to drain: the open-loop serving thread calls this
        between submission bursts."""
        if self.has_work:
            self.step()
        done, self._results = self._results, {}
        return done

    def run(self) -> Dict[int, CompletedGeneration]:
        """Drain the queue; returns {rid: CompletedGeneration} for every
        request completed since the last call."""
        while self.has_work:
            self.step()
        done, self._results = self._results, {}
        return done

    def generate_many(self, prompts: Sequence[Sequence[int]],
                      max_new_tokens: int = 16) -> List[CompletedGeneration]:
        """Batch convenience API (aligned with `prompts` order)."""
        rids = [self.reserve_rid() for _ in prompts]
        for rid, p in zip(rids, prompts):
            self.submit(rid, p, max_new_tokens)
        done = self.run()
        return [done[rid] for rid in rids]
