"""Host-side paged KV-cache bookkeeping: block allocator, refcounted
pages, and RAG prefix sharing (pure numpy/stdlib — no JAX import).

The device side (:mod:`repro.serving.executor` in paged mode) holds one
global pool of ``num_pages`` fixed-size K/V pages per layer plus a
per-slot block table.  Everything about *which* page holds *what* is
decided here, on the host, by :class:`PagePool`:

* **Free-list allocator with refcounts.**  Pages are partitioned when
  the pool is sharded (a slot on data-shard ``d`` may only use pages
  resident on ``d``); each partition keeps its own free list.  A page's
  refcount counts the slots using it plus (for registered prefix pages)
  one cache reference.
* **Prefix sharing.**  RAG traffic re-prefills the same guarded
  template and the same retrieved passages over and over.  Admission
  hashes the prompt's token pages with a *cumulative chain hash*
  (K/V at position ``i`` depend on every token ``<= i``, so a page is
  only reusable when its entire prefix matches).  Cache-hot full pages
  are mapped into the new slot's block table instead of re-prefilled —
  only the unique suffix goes through the prefill program.
* **Copy-on-write fork.**  The suffix usually starts mid-page.  That
  page's shared K/V (refcount > 1 — the cache and/or other slots hold
  it) must not be written, so the plan gathers the source page into the
  prefill scratch and commits the combined prefix+suffix content to a
  *fresh* page: copy-before-write, the writer gets its own fork.
* **Back-pressure.**  When a partition cannot supply the pages a
  request needs — even after evicting unreferenced cache entries
  (LRU) — :meth:`PagePool.plan` returns ``None`` and the engine defers
  the admission instead of OOMing.

Page-table row layout for a planned request (page size ``ps``)::

    blocks [0, shared)                -> borrowed cache pages (read-only)
    block  shared (iff p0 % ps != 0)  -> CoW fork: gathered + rewritten
    blocks [shared+cow, total)        -> fresh pages (prefill + decode)

where ``p0`` is the suffix start in tokens, capped at ``plen - 1`` so
prefill always sees at least one token (it must emit the first output
token from real logits).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


def hash_prefix_pages(tokens: Sequence[int], page_size: int) -> List[bytes]:
    """Cumulative chain hash per FULL token page.

    ``out[i]`` identifies tokens ``[0, (i+1)*page_size)`` — not just
    page ``i``'s tokens — because a page's K/V depend on the whole
    prefix.  Deterministic across processes (blake2b over the raw
    int token bytes; no Python ``hash()`` randomization).
    """
    out: List[bytes] = []
    h = b"\x00" * 16
    for i in range(len(tokens) // page_size):
        chunk = tokens[i * page_size:(i + 1) * page_size]
        payload = h + b"".join(int(t).to_bytes(8, "little", signed=True)
                               for t in chunk)
        h = hashlib.blake2b(payload, digest_size=16).digest()
        out.append(h)
    return out


@dataclass
class PagePlan:
    """One admitted request's page assignment (engine keeps it until the
    slot is released; every page in ``pages`` holds one reference)."""
    pages: List[int]            # full table row: blocks [0, total)
    p0: int                     # suffix start (tokens); prefill covers
    #                             [p0, plen) at absolute positions
    shared: int                 # leading blocks borrowed from the cache
    cow: bool                   # block `shared` is a copy-on-write fork
    gather_src: List[int]       # source page per block < ceil(p0/ps)
    write_mask: List[bool]      # per block: commit from prefill scratch
    register: List[Tuple[bytes, int]] = field(default_factory=list)
    partition: int = 0


class PagePool:
    """Allocator + prefix cache over a partitioned page pool."""

    def __init__(self, num_pages: int, page_size: int, *,
                 partitions: int = 1, prefix_sharing: bool = True):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        if num_pages % max(partitions, 1) != 0:
            raise ValueError(
                f"num_pages={num_pages} must be divisible by "
                f"partitions={partitions} (pages shard with the slots)")
        self.num_pages = num_pages
        self.page_size = page_size
        self.partitions = max(1, partitions)
        self.per_partition = num_pages // self.partitions
        self.prefix_sharing = prefix_sharing
        self._ref = [0] * num_pages
        self._free: List[List[int]] = [
            list(range((p + 1) * self.per_partition - 1,
                       p * self.per_partition - 1, -1))
            for p in range(self.partitions)]
        # per-partition prefix cache: chain hash -> page id, LRU-ordered
        # (move_to_end on hit).  Every entry holds one cache reference;
        # eviction only touches entries no slot is using (refcount 1).
        self._prefix: List[OrderedDict] = [OrderedDict()
                                           for _ in range(self.partitions)]
        self._hash_of_page: Dict[int, bytes] = {}
        # counters (engine folds these into EngineStats)
        self.n_evicted = 0
        self.n_cow_forks = 0

    def bind_metrics(self, registry) -> None:
        """Register pool occupancy / prefix-cache / eviction gauges as
        scrape-time views over a :class:`repro.obs.MetricsRegistry`."""
        in_use_g = registry.gauge("pagepool_pages_in_use",
                                  "pool pages with a live reference")
        free_g = registry.gauge("pagepool_pages_free",
                                "free pages across all partitions")
        cached_g = registry.gauge("pagepool_prefix_cached_pages",
                                  "pages held by the prefix cache")
        evicted_c = registry.counter("pagepool_evictions_total",
                                     "prefix-cache LRU evictions")
        cow_c = registry.counter("pagepool_cow_forks_total",
                                 "mid-page copy-on-write forks")

        def scrape() -> None:
            in_use_g.set(self.pages_in_use)
            free_g.set(sum(len(f) for f in self._free))
            cached_g.set(sum(len(c) for c in self._prefix))
            evicted_c.set_total(self.n_evicted)
            cow_c.set_total(self.n_cow_forks)

        registry.register_collector(scrape)

    # -- allocator core -------------------------------------------------

    def n_free(self, partition: int = 0) -> int:
        return len(self._free[partition])

    @property
    def pages_in_use(self) -> int:
        return sum(1 for r in self._ref if r > 0)

    def _alloc(self, partition: int) -> int:
        page = self._free[partition].pop()
        assert self._ref[page] == 0, "allocated a referenced page"
        self._ref[page] = 1
        return page

    def _ref_page(self, page: int) -> None:
        assert self._ref[page] > 0, "ref on a free page"
        self._ref[page] += 1

    def _deref(self, page: int) -> None:
        assert self._ref[page] > 0, "deref on a free page"
        self._ref[page] -= 1
        if self._ref[page] == 0:
            self._free[page // self.per_partition].append(page)

    def _evict_one(self, partition: int) -> bool:
        """Drop the least-recently-used cache entry whose page no slot
        references (refcount == 1: the cache's own ref)."""
        cache = self._prefix[partition]
        for h, page in cache.items():
            if self._ref[page] == 1:
                del cache[h]
                self._hash_of_page.pop(page, None)
                self._deref(page)
                self.n_evicted += 1
                return True
        return False

    # -- prefix lookup --------------------------------------------------

    def _hits(self, hashes: List[bytes], partition: int) -> List[int]:
        """Longest run of consecutive cached prefix pages."""
        if not self.prefix_sharing:
            return []
        cache = self._prefix[partition]
        pages: List[int] = []
        for h in hashes:
            page = cache.get(h)
            if page is None:
                break
            pages.append(page)
        return pages

    def preview_hit_tokens(self, tokens: Sequence[int],
                           partition: int = 0) -> int:
        """Side-effect-free p0 preview — the engine groups admissions by
        (padded length, p0) so one prefill dispatch shares a uniform
        suffix start."""
        hashes = hash_prefix_pages(tokens, self.page_size)
        n = len(self._hits(hashes, partition))
        return min(n * self.page_size, max(len(tokens) - 1, 0))

    # -- admission planning ---------------------------------------------

    def plan(self, tokens: Sequence[int], limit: int,
             partition: int = 0) -> Optional[PagePlan]:
        """Plan pages for a request with ``plen`` prompt tokens and up
        to ``limit`` generated tokens.  Returns ``None`` when the
        partition cannot supply enough pages (caller defers admission).

        The plan covers ``plen + limit + 1`` positions: an idle slot's
        masked decode write may land one past its final position, and
        the executor drops it only when the block index is in range.
        """
        ps = self.page_size
        plen = len(tokens)
        if plen <= 0:
            raise ValueError("empty prompt cannot be planned")
        total_blocks = -(-(plen + limit + 1) // ps)
        hashes = hash_prefix_pages(tokens, ps)
        hit_pages = self._hits(hashes, partition)
        p0 = min(len(hit_pages) * ps, plen - 1)
        shared = p0 // ps
        cow = (p0 % ps) != 0
        n_fresh = total_blocks - shared
        while self.n_free(partition) < n_fresh:
            if not self._evict_one(partition):
                return None
        fresh = [self._alloc(partition) for _ in range(n_fresh)]
        for page in hit_pages[:shared]:
            self._ref_page(page)
        pages = hit_pages[:shared] + fresh
        # prefill scratch needs the WHOLE prefix [0, p0) resident: the
        # suffix attends over it.  Shared full pages gather as-is; the
        # CoW block gathers from its source and recommits to its fork.
        gather_src = hit_pages[:shared + (1 if cow else 0)]
        n_prompt_blocks = -(-plen // ps)
        write_mask = [shared <= i < n_prompt_blocks
                      for i in range(total_blocks)]
        register = [(hashes[i], pages[i]) for i in range(len(hashes))
                    if i >= shared and hashes[i] not in
                    self._prefix[partition]]
        if cow:
            self.n_cow_forks += 1
        return PagePlan(pages=pages, p0=p0, shared=shared, cow=cow,
                        gather_src=gather_src, write_mask=write_mask,
                        register=register, partition=partition)

    def commit(self, plan: PagePlan) -> None:
        """The plan's prefill+commit was dispatched: its fresh FULL
        prompt pages are now (in program order) valid K/V, so register
        them for future sharing.  First writer wins on hash collision
        within a race-free host loop — identical prompts in the SAME
        admission group intentionally do not share (their gathers would
        be dispatched before the commit that fills the pages)."""
        cache = self._prefix[plan.partition]
        for h, page in plan.register:
            if h in cache:
                continue
            cache[h] = page
            self._hash_of_page[page] = h
            self._ref_page(page)
        for page in plan.pages[:plan.shared]:
            h = self._hash_of_page.get(page)
            if h is not None and h in cache:
                cache.move_to_end(h)

    def release(self, plan: PagePlan) -> None:
        """Drop the plan's references (slot freed, admission rolled
        back, or request aborted).  Registered pages keep their cache
        reference and stay sharable until evicted."""
        for page in plan.pages:
            self._deref(page)

    # -- introspection ---------------------------------------------------

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def cached_pages(self, partition: int = 0) -> int:
        return len(self._prefix[partition])
