"""Batched generation engine: prefill + decode loop over a KV cache.

Used by the local-model generation backend and the serve driver.  The
decode step is jitted once per (batch, max_len) bucket; requests are
left-padded into fixed buckets — the standard static-shape TPU serving
pattern.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS, PAD
from repro.models.registry import Model


@dataclass
class GenerationResult:
    tokens: np.ndarray       # (B, T_out)
    n_steps: int


class Engine:
    def __init__(self, model: Model, params, *, max_len: int = 512,
                 moe_fn: Optional[Callable] = None, mla_absorb: bool = False):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.moe_fn = moe_fn
        self.mla_absorb = mla_absorb
        self._prefill = jax.jit(self._prefill_fn)
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))

    def _prefill_fn(self, params, cache, tokens):
        logits, cache = self.model.prefill(params, {"tokens": tokens}, cache,
                                           moe_fn=self.moe_fn,
                                           mla_absorb=self.mla_absorb)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    def _decode_fn(self, params, cache, tokens):
        logits, cache = self.model.decode(params, {"tokens": tokens}, cache,
                                          moe_fn=self.moe_fn,
                                          mla_absorb=self.mla_absorb)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 16
                 ) -> GenerationResult:
        B = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.full((B, plen), PAD, np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p  # right-pad; simple bucket
        cache = self.model.init_cache(B, plen + max_new_tokens)
        nxt, cache = self._prefill(self.params, cache, jnp.asarray(toks))
        out = [np.asarray(nxt)]
        # per-sequence finished flags: a sequence is done once it has
        # emitted EOS at least once; stop when every sequence has
        done = out[0].reshape(B) == EOS
        tok = nxt[:, None]
        steps = 1
        for _ in range(max_new_tokens - 1):
            if done.all():
                break
            tok, cache = self._decode(self.params, cache, tok)
            out.append(np.asarray(tok))
            done |= out[-1].reshape(B) == EOS
            tok = tok[:, None]
            steps += 1
        return GenerationResult(np.stack([o.reshape(B) for o in out], axis=1),
                                steps)
