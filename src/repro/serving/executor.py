"""Device executors for the continuous engine.

The host scheduler in :mod:`repro.serving.continuous` is device-agnostic:
it plans admissions, tracks slot ownership, and harvests finished
requests — all in numpy.  Everything that touches device buffers lives
behind the :class:`DeviceExecutor` protocol implemented here:

* :class:`SingleDeviceExecutor` — the original single-device path: slot
  cache + prefill scratch allocated once, jitted prefill / fused
  insert+state-commit / K-step decode chunk, donated buffers.
* :class:`ShardedExecutor` — the same jitted programs laid out over a
  ``dp×mp`` ``jax.sharding.Mesh``.  The SLOT dimension partitions on
  the data axis(es): KV cache, slot control arrays, and output buffer
  are all ``NamedSharding``-placed and the jits carry matching
  ``out_shardings``, so each device owns ``num_slots / dp`` slot rows
  end-to-end — decode never moves a slot row across devices.  Params
  place via :func:`repro.sharding.shardings_for_schema` over the model
  schema's logical axes (``fsdp=False`` — inference wants weights
  resident, not ZeRO-gathered), so on an ``mp>1`` mesh attention heads
  / FFN / vocab dims shard over the ``model`` axis and every jitted
  program runs tensor-parallel; KV-cache ``kv_heads`` dims ride the
  same axis, keeping each model shard's cache writes local.  The
  prefill scratch shards its rows over ``data`` when ``prefill_batch``
  divides the data-axis size (large admission groups no longer
  replicate prefill work; the insert scatter all-gathers the few
  scratch rows), and falls back to replicated rows otherwise.  On a
  ``mp=1`` mesh every param spec degenerates to replicated — the
  original slot-data-parallel layout.

Both executors dispatch asynchronously (JAX async dispatch): ``admit``
and ``decode_chunk`` return as soon as the work is enqueued, and the
host only blocks in ``sync_control`` / ``fetch_outputs``.  That is what
lets the scheduler overlap the next admission group's prefill with the
decode chunk already in flight.

Protocol (duck-typed; see ``tests/test_host_scheduler.py`` for a pure
numpy fake):

    admit(tokens (PB, plen) i32, slot_idx (PB,) i32, limits (PB,) i32)
        prefill the padded prompt rows, scatter them into their slots,
        and commit first-token / active / limit state.  Rows whose
        ``slot_idx == num_slots`` are unused scratch rows and dropped.
    decode_chunk()
        advance every slot ``sync_every`` greedy steps (async).
    sync_control() -> (active (S,) bool, gen (S,) i32)
        block and download the two tiny control arrays.
    fetch_outputs() -> (S, max_new_cap) i32
        block and download the output buffer.
    attrs: num_slots, max_len, max_new_cap, sync_every, prefill_batch,
        cache_allocations.

    Optional health extensions (the scheduler probes via ``getattr`` so
    pure-numpy fakes without them keep working):

    slot_faults() -> (S,) bool
        per-slot poison flags: a slot goes bad when any of its decode
        logits turn NaN/inf (detected on-device inside the chunk scan —
        the slot is immediately deactivated there so it stops writing
        tokens, and stays flagged until cleared).
    deactivate(slots)
        clear the active bits for the given slots (quarantine/cancel).
    clear_slot_faults(slots)
        reset poison flags (scheduler quarantine reset).

    Health checks are on by default; ``health_checks=False`` removes
    the isfinite test from the decode scan entirely.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.tokenizer import EOS, PAD
from repro.sharding import (batch_axes, input_sharding, mesh_axis_sizes,
                            shardings_for_schema)


class SingleDeviceExecutor:
    """Slot cache + jitted prefill/commit/decode on the default device."""

    def __init__(self, model, params, *, num_slots: int = 8,
                 max_len: int = 512, max_new_cap: int = 64,
                 sync_every: int = 4, prefill_batch: int = 1,
                 moe_fn: Optional[Callable] = None,
                 mla_absorb: bool = False, health_checks: bool = True,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None, metrics=None):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.max_new_cap = max_new_cap
        self.sync_every = sync_every
        self.prefill_batch = max(1, min(prefill_batch, num_slots))
        self.moe_fn = moe_fn
        self.mla_absorb = mla_absorb
        self.health_checks = health_checks
        self.paged = paged
        self.page_partitions = 1

        # the ONLY cache allocations in the executor's lifetime: the
        # slot cache (dense per-slot rows, or the global page pool +
        # block tables) and the dense prefill scratch (reused forever)
        if paged:
            if max_len % page_size != 0:
                raise ValueError(f"max_len={max_len} must be a multiple "
                                 f"of page_size={page_size}")
            self.page_size = page_size
            # scratch rows reshape to mb_scratch pages; tables carry one
            # extra write-overflow block (an idle slot's held-position
            # write may land one past max_len-1 — see _decode_chunk_fn)
            self.mb_scratch = max_len // page_size
            self.max_blocks = self.mb_scratch + 1
            self.num_pages = (num_pages if num_pages is not None
                              else num_slots * self.max_blocks)
            self._validate_pages()
            self._cache = model.init_paged_cache(
                num_slots, self.num_pages, page_size, self.max_blocks)
        else:
            self._cache = model.init_cache(num_slots, max_len)
        self._pcache = model.init_cache(self.prefill_batch, max_len)
        self.cache_allocations = 2

        S, cap = num_slots, max_new_cap
        self._dtok = jnp.zeros(S, jnp.int32)    # next input token
        self._dactive = jnp.zeros(S, bool)
        self._dgen = jnp.zeros(S, jnp.int32)    # tokens generated so far
        self._dlimit = jnp.zeros(S, jnp.int32)  # per-slot max_new_tokens
        self._dout = jnp.zeros((S, cap), jnp.int32)
        self._dbad = jnp.zeros(S, bool)         # NaN/inf poison flags

        self._place()
        self._compile()

        # device-dispatch wall histograms (repro.obs) — None keeps the
        # hot path at a single attribute check per dispatch
        self._m_admit = None
        self._m_decode = None
        if metrics is not None:
            self.bind_metrics(metrics)

    def bind_metrics(self, registry) -> None:
        """Register admit / decode-chunk host dispatch walls.  These
        are genuine wall-clock measurements of async dispatch overhead
        (not virtual-time), hence perf_counter rather than the engine
        clock."""
        bounds = (0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0,
                  10.0, 20.0, 50.0, 100.0)
        self._m_admit = registry.histogram(
            "executor_admit_dispatch_ms",
            "host wall of one prefill+commit dispatch", bounds)
        self._m_decode = registry.histogram(
            "executor_decode_dispatch_ms",
            "host wall of one K-step decode-chunk dispatch", bounds)

    def _validate_pages(self) -> None:
        per = self.num_pages // max(self.page_partitions, 1)
        if per < self.max_blocks:
            raise ValueError(
                f"num_pages={self.num_pages} over {self.page_partitions} "
                f"partition(s) leaves {per} pages per partition — fewer "
                f"than the {self.max_blocks} blocks one max_len request "
                f"needs; admission could never make progress")

    # -- layout hooks (overridden by ShardedExecutor) -------------------

    def _place(self) -> None:
        pass

    def _compile(self) -> None:
        if self.paged:
            self._gather = jax.jit(self._gather_fn, donate_argnums=(1,))
            self._prefill = jax.jit(self._prefill_paged_fn,
                                    donate_argnums=(1,))
            self._commit = jax.jit(self._commit_paged_fn,
                                   donate_argnums=(0, 2, 3, 4, 5, 6))
        else:
            self._prefill = jax.jit(self._prefill_fn, donate_argnums=(1,))
            self._commit = jax.jit(self._commit_fn,
                                   donate_argnums=(0, 2, 3, 4, 5, 6))
        self._decode = jax.jit(self._decode_chunk_fn,
                               donate_argnums=(1, 2, 3, 4, 6, 7))
        self._clear_flags = jax.jit(self._clear_flags_fn,
                                    donate_argnums=(0,))

    def _host_to_device(self, x: np.ndarray):
        return jnp.asarray(x)

    def _tokens_to_device(self, x: np.ndarray):
        """Upload one admission group's padded token rows (PB, plen).
        Split from `_host_to_device` so the sharded executor can lay
        the rows out like the prefill scratch."""
        return jnp.asarray(x)

    # -- jitted bodies --------------------------------------------------

    def _prefill_fn(self, params, pcache, tokens):
        logits, pcache = self.model.prefill(params, {"tokens": tokens},
                                            pcache, moe_fn=self.moe_fn,
                                            mla_absorb=self.mla_absorb)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), pcache

    def _commit_fn(self, cache, pcache, tok, active, gen, limit, out,
                   slots, firsts, limits):
        """Scatter the prefilled scratch rows into their slots and write
        the admission group's slot state.  Unused scratch rows carry
        slot index ``num_slots`` and are dropped by the scatter."""
        def ins(bdim):
            def f(big, small):
                idx = (slice(None),) * bdim + (slots,)
                return big.at[idx].set(small.astype(big.dtype),
                                       mode="drop")
            return f
        new = dict(cache)
        new["pos"] = cache["pos"].at[slots].set(pcache["pos"], mode="drop")
        # prefix leaves are (B, ...); block leaves are (n_blocks, B, ...)
        new["prefix"] = jax.tree_util.tree_map(ins(0), cache["prefix"],
                                               pcache["prefix"])
        new["blocks"] = jax.tree_util.tree_map(ins(1), cache["blocks"],
                                               pcache["blocks"])
        flags = (firsts != EOS) & (limits > 1)
        tok = tok.at[slots].set(firsts, mode="drop")
        active = active.at[slots].set(flags, mode="drop")
        gen = gen.at[slots].set(1, mode="drop")
        limit = limit.at[slots].set(limits, mode="drop")
        out = out.at[slots, 0].set(firsts, mode="drop")
        return new, tok, active, gen, limit, out

    # -- paged jitted bodies --------------------------------------------

    def _gather_fn(self, cache, pcache, src):
        """Copy shared prefix pages from the pool into the prefill
        scratch rows (copy-on-write borrow).  ``src`` is
        ``(PB, mb_scratch)`` int32 pool page ids; the sentinel
        ``num_pages`` leaves that scratch block untouched.  Reads the
        slot cache's pools, so it serializes behind any in-flight
        decode chunk — shared pages are never read mid-write."""
        NP, ps = self.num_pages, self.page_size
        PB, MBs = self.prefill_batch, self.mb_scratch
        flat = src.reshape(-1)
        valid = flat < NP
        safe = jnp.minimum(flat, NP - 1)

        def g(bdim):
            def f(scratch, pool):
                got = jnp.take(pool, safe, axis=bdim)
                lead = scratch.shape[:bdim]
                rest = scratch.shape[bdim + 2:]
                cur = scratch.reshape(lead + (PB * MBs, ps) + rest)
                m = valid.reshape((1,) * bdim + (PB * MBs,)
                                  + (1,) * (1 + len(rest)))
                return jnp.where(m, got.astype(scratch.dtype),
                                 cur).reshape(scratch.shape)
            return f
        new = dict(pcache)
        new["prefix"] = jax.tree_util.tree_map(g(0), pcache["prefix"],
                                               cache["prefix"])
        new["blocks"] = jax.tree_util.tree_map(g(1), pcache["blocks"],
                                               cache["blocks"])
        return new

    def _prefill_paged_fn(self, params, pcache, tokens, pos0):
        """Suffix prefill: rows start at absolute position ``pos0``
        (their shared prefix is already in the scratch via the page
        gather), so only the unique suffix runs through the model."""
        logits, pcache = self.model.prefill(
            params, {"tokens": tokens, "pos0": pos0}, pcache,
            moe_fn=self.moe_fn, mla_absorb=self.mla_absorb)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), pcache

    def _commit_paged_fn(self, cache, pcache, tok, active, gen, limit, out,
                         slots, firsts, limits, tables, wmask):
        """Scatter the prefilled scratch rows into their allocated
        pages and write the admission group's slot state + block
        tables.  ``wmask`` masks out shared (borrowed) blocks — only
        freshly written blocks land in the pool; masked / unused rows
        scatter to page id ``num_pages`` and are dropped."""
        NP, ps = self.num_pages, self.page_size
        PB, MBs = self.prefill_batch, self.mb_scratch
        new = dict(cache)
        new["pos"] = cache["pos"].at[slots].set(pcache["pos"], mode="drop")
        new["table"] = cache["table"].at[slots].set(tables, mode="drop")
        pages = jnp.where(wmask, tables[:, :MBs], NP).reshape(-1)

        def ins(bdim):
            def f(pool, scratch):
                lead = scratch.shape[:bdim]
                rest = scratch.shape[bdim + 2:]
                resh = scratch.reshape(lead + (PB * MBs, ps) + rest)
                idx = (slice(None),) * bdim + (pages,)
                return pool.at[idx].set(resh.astype(pool.dtype),
                                        mode="drop")
            return f
        new["prefix"] = jax.tree_util.tree_map(ins(0), cache["prefix"],
                                               pcache["prefix"])
        new["blocks"] = jax.tree_util.tree_map(ins(1), cache["blocks"],
                                               pcache["blocks"])
        flags = (firsts != EOS) & (limits > 1)
        tok = tok.at[slots].set(firsts, mode="drop")
        active = active.at[slots].set(flags, mode="drop")
        gen = gen.at[slots].set(1, mode="drop")
        limit = limit.at[slots].set(limits, mode="drop")
        out = out.at[slots, 0].set(firsts, mode="drop")
        return new, tok, active, gen, limit, out

    def _decode_chunk_fn(self, params, cache, tok, active, gen, limit, out,
                         bad):
        """`sync_every` decode steps over all slots, done-mask on device.

        With ``health_checks`` on, each step tests the step's final
        logits row for NaN/inf: a poisoned slot is deactivated in the
        same step (its garbage token is never written, ``gen`` does not
        advance) and its ``bad`` flag latches until the scheduler
        clears it — the rest of the batch decodes on untouched."""
        S, cap = out.shape
        sidx = jnp.arange(S)

        def step(carry, _):
            cache, tok, active, gen, out, bad = carry
            pos0 = cache["pos"]
            if self.paged:
                # idle slots must not scribble into pages that may have
                # been released and reassigned: park them at a position
                # past the block table so the paged write drops
                cache = dict(cache)
                cache["pos"] = jnp.where(
                    active, pos0, self.max_blocks * self.page_size)
            inp = jnp.where(active, tok, PAD)
            logits, cache = self.model.decode(
                params, {"tokens": inp[:, None]}, cache, moe_fn=self.moe_fn,
                mla_absorb=self.mla_absorb)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            if self.health_checks:
                row_bad = active & ~jnp.isfinite(logits[:, -1]).all(axis=-1)
                bad = bad | row_bad
                active = active & ~row_bad
            # hold position for idle slots (their kv write lands one past
            # their valid length and is masked / overwritten on admit)
            cache["pos"] = jnp.where(active, cache["pos"], pos0)
            # idle slots scatter out of bounds -> dropped
            wr = jnp.where(active, gen, cap)
            out = out.at[sidx, wr].set(nxt, mode="drop")
            gen = gen + active.astype(jnp.int32)
            active = active & (nxt != EOS) & (gen < limit)
            tok = jnp.where(active, nxt, tok)
            return (cache, tok, active, gen, out, bad), None

        carry, _ = jax.lax.scan(step, (cache, tok, active, gen, out, bad),
                                None, length=self.sync_every)
        return carry

    @staticmethod
    def _clear_flags_fn(arr, idx):
        """Clear boolean slot flags (active bits / poison flags)."""
        return arr.at[idx].set(False, mode="drop")

    # -- protocol -------------------------------------------------------

    def admit(self, tokens: np.ndarray, slot_idx: np.ndarray,
              limits: np.ndarray) -> None:
        """Prefill + insert + state commit for one admission group —
        pure async dispatch, no host sync.  The prefill program only
        touches the scratch cache, so it runs concurrently with any
        decode chunk already in flight; the insert/commit is serialized
        behind that chunk by its data dependency on the slot cache."""
        if self.paged:
            raise RuntimeError("paged executor: use admit_paged()")
        t0 = time.perf_counter() if self._m_admit is not None else 0.0
        firsts, self._pcache = self._prefill(
            self.params, self._pcache, self._tokens_to_device(tokens))
        (self._cache, self._dtok, self._dactive, self._dgen, self._dlimit,
         self._dout) = self._commit(
            self._cache, self._pcache, self._dtok, self._dactive,
            self._dgen, self._dlimit, self._dout,
            self._host_to_device(slot_idx), firsts,
            self._host_to_device(limits))
        if self._m_admit is not None:
            self._m_admit.observe((time.perf_counter() - t0) * 1e3)

    def admit_paged(self, tokens: np.ndarray, slot_idx: np.ndarray,
                    limits: np.ndarray, pos0: np.ndarray,
                    tables: np.ndarray, write_mask: np.ndarray,
                    gather_src: np.ndarray) -> None:
        """Paged admission: optional shared-page gather, suffix-only
        prefill from ``pos0``, then scatter the written pages into the
        pool and install the block tables.  ``tokens`` holds only the
        unique suffixes ``(PB, plen - p0)``; ``tables`` is
        ``(PB, max_blocks)``; ``write_mask`` ``(PB, mb_scratch)`` marks
        freshly written blocks; ``gather_src`` ``(PB, mb_scratch)``
        holds source pool pages (sentinel ``num_pages`` = no gather).
        Still pure async dispatch — but a gather reads the slot
        cache's pools, so cache-hit admissions serialize behind the
        in-flight decode chunk (miss admissions overlap as before)."""
        if not self.paged:
            raise RuntimeError("dense executor: use admit()")
        t0 = time.perf_counter() if self._m_admit is not None else 0.0
        if int(gather_src.min(initial=self.num_pages)) < self.num_pages:
            self._pcache = self._gather(
                self._cache, self._pcache,
                self._host_to_device(np.ascontiguousarray(gather_src)))
        firsts, self._pcache = self._prefill(
            self.params, self._pcache, self._tokens_to_device(tokens),
            self._host_to_device(pos0))
        (self._cache, self._dtok, self._dactive, self._dgen, self._dlimit,
         self._dout) = self._commit(
            self._cache, self._pcache, self._dtok, self._dactive,
            self._dgen, self._dlimit, self._dout,
            self._host_to_device(slot_idx), firsts,
            self._host_to_device(limits),
            self._host_to_device(np.ascontiguousarray(tables)),
            self._host_to_device(np.ascontiguousarray(write_mask)))
        if self._m_admit is not None:
            self._m_admit.observe((time.perf_counter() - t0) * 1e3)

    def decode_chunk(self) -> None:
        t0 = time.perf_counter() if self._m_decode is not None else 0.0
        (self._cache, self._dtok, self._dactive, self._dgen,
         self._dout, self._dbad) = self._decode(
            self.params, self._cache, self._dtok, self._dactive,
            self._dgen, self._dlimit, self._dout, self._dbad)
        if self._m_decode is not None:
            self._m_decode.observe((time.perf_counter() - t0) * 1e3)

    def sync_control(self):
        """The every-K host sync: only the two tiny control arrays come
        back (np.array copies — device views are read-only)."""
        jax.block_until_ready((self._dactive, self._dgen))
        return np.array(self._dactive), np.array(self._dgen)

    def fetch_outputs(self) -> np.ndarray:
        return np.array(self._dout)

    # -- health / quarantine control ------------------------------------

    def slot_faults(self) -> np.ndarray:
        """Per-slot NaN/inf poison flags (host copy; blocks briefly —
        call right after ``sync_control``, when the chunk is done)."""
        return np.array(self._dbad)

    def deactivate(self, slots) -> None:
        """Clear active bits for the given slots (quarantine or
        mid-stream cancel) without touching their cache rows."""
        idx = np.asarray(list(slots), np.int32)
        if idx.size == 0:
            return
        self._dactive = self._clear_flags(self._dactive,
                                          self._host_to_device(idx))

    def clear_slot_faults(self, slots) -> None:
        idx = np.asarray(list(slots), np.int32)
        if idx.size == 0:
            return
        self._dbad = self._clear_flags(self._dbad,
                                       self._host_to_device(idx))


class ShardedExecutor(SingleDeviceExecutor):
    """dp×mp mesh executor: slots on ``data``, params on ``model``.

    The slot cache schema tags the slot dimension as the ``batch``
    logical axis, so :func:`repro.sharding.shardings_for_schema`
    resolves every cache leaf to a slot-on-``data`` placement (and, on
    an ``mp>1`` mesh, its ``kv_heads`` dim to the ``model`` axis); the
    control arrays and output buffer get the matching ``P("data")`` /
    ``P("data", None)`` layouts.  ``num_slots`` must divide the data
    axis size so every device owns the same number of slot rows.

    Params resolve through the same schema machinery (``fsdp=False``):
    attention heads, FFN, and vocab dims partition over the ``model``
    axis, so the prefill / insert+commit / decode-chunk programs run
    tensor-parallel under GSPMD — the fix for ``mp>1`` serve meshes
    silently replicating the full model per device.  The prefill
    scratch shards its rows over ``data`` when ``prefill_batch``
    divides the data-axis size, so batched prefill work partitions
    instead of replicating; the insert scatter all-gathers the scratch
    rows (each device writes only its own slots).

    Greedy decode is row-independent, so a 1-device mesh is
    token-identical to :class:`SingleDeviceExecutor`; dp-only and
    dp×mp meshes are token-identical by construction (verified by the
    forced-8-device ``dp=8`` and ``dp=4,mp=2`` parity tests).
    """

    def __init__(self, model, params, *, mesh: Mesh, **kw):
        self.mesh = mesh
        super().__init__(model, params, **kw)

    def _place(self) -> None:
        sizes = mesh_axis_sizes(self.mesh)
        dp = int(np.prod([sizes[a] for a in batch_axes(self.mesh)]) or 1)
        if self.num_slots % max(dp, 1) != 0:
            raise ValueError(
                f"num_slots={self.num_slots} must be divisible by the "
                f"mesh data-axis size {dp} to shard the slot dimension")
        self._rep = NamedSharding(self.mesh, P())
        # params: model-axis tensor parallel from the schema's logical
        # axes; slot cache + prefill scratch: batch dims on data,
        # kv-head dims on model (cache leaves carry "batch", so the
        # FSDP pass never touches them)
        self._param_sh = shardings_for_schema(self.model.schema, self.mesh,
                                              fsdp=False)
        if self.paged:
            # the page pool shards its page dim over data (each device
            # owns num_pages/dp pages) and kv-heads over model; the
            # host-side allocator partitions its free lists to match so
            # a slot's pages stay on the devices that own the slot row
            if self.num_pages % max(dp, 1) != 0:
                raise ValueError(
                    f"num_pages={self.num_pages} must be divisible by "
                    f"the mesh data-axis size {dp} to shard the pool")
            self.page_partitions = max(dp, 1)
            self._validate_pages()
            self._cache_sh = shardings_for_schema(
                self.model.paged_cache_schema(
                    self.num_slots, self.num_pages, self.page_size,
                    self.max_blocks), self.mesh)
        else:
            self._cache_sh = shardings_for_schema(
                self.model.cache_schema(self.num_slots, self.max_len),
                self.mesh)
        self._pcache_sh = shardings_for_schema(
            self.model.cache_schema(self.prefill_batch, self.max_len),
            self.mesh)
        # one tuple entry: the slot dim shards over ALL batch axes
        # (("pod","data") on multi-pod meshes — P("pod","data") would
        # wrongly assign them to two dims of a 1-D array)
        self._slot_sh = NamedSharding(self.mesh, P(batch_axes(self.mesh)))
        self._out_sh = NamedSharding(self.mesh,
                                     P(batch_axes(self.mesh), None))
        # admitted token rows + the prefill's first-token output ride
        # the scratch's row layout (replicated when PB doesn't divide)
        self._row2_sh = input_sharding(self.mesh, self.prefill_batch, 2)
        self._row1_sh = input_sharding(self.mesh, self.prefill_batch, 1)
        self.params = jax.device_put(self.params, self._param_sh)
        self._cache = jax.device_put(self._cache, self._cache_sh)
        self._pcache = jax.device_put(self._pcache, self._pcache_sh)
        self._dtok = jax.device_put(self._dtok, self._slot_sh)
        self._dactive = jax.device_put(self._dactive, self._slot_sh)
        self._dgen = jax.device_put(self._dgen, self._slot_sh)
        self._dlimit = jax.device_put(self._dlimit, self._slot_sh)
        self._dout = jax.device_put(self._dout, self._out_sh)
        self._dbad = jax.device_put(self._dbad, self._slot_sh)

    def _compile(self) -> None:
        s = self._slot_sh
        if self.paged:
            self._gather = jax.jit(
                self._gather_fn, donate_argnums=(1,),
                out_shardings=self._pcache_sh)
            self._prefill = jax.jit(
                self._prefill_paged_fn, donate_argnums=(1,),
                out_shardings=(self._row1_sh, self._pcache_sh))
            self._commit = jax.jit(
                self._commit_paged_fn, donate_argnums=(0, 2, 3, 4, 5, 6),
                out_shardings=(self._cache_sh, s, s, s, s, self._out_sh))
        else:
            self._prefill = jax.jit(
                self._prefill_fn, donate_argnums=(1,),
                out_shardings=(self._row1_sh, self._pcache_sh))
            self._commit = jax.jit(
                self._commit_fn, donate_argnums=(0, 2, 3, 4, 5, 6),
                out_shardings=(self._cache_sh, s, s, s, s, self._out_sh))
        self._decode = jax.jit(
            self._decode_chunk_fn, donate_argnums=(1, 2, 3, 4, 6, 7),
            out_shardings=(self._cache_sh, s, s, s, self._out_sh, s))
        self._clear_flags = jax.jit(self._clear_flags_fn,
                                    donate_argnums=(0,), out_shardings=s)

    def _host_to_device(self, x: np.ndarray):
        # small host control inputs (slot ids, limits) ride replicated
        return jax.device_put(x, self._rep)

    def _tokens_to_device(self, x: np.ndarray):
        return jax.device_put(x, self._row2_sh)
