"""Legacy scheduler facade over the unified routing Gateway.

The production loop the paper's controller lives in:

  queue -> route (policy, per-request SLO) -> group by action bucket
        -> execute buckets (retrieval batched per depth, generation
           batched per mode) -> record outcomes -> error budgets
        -> (adaptive mitigation) budget burn tightens the refusal share.

That loop now lives in :class:`repro.routing.gateway.Gateway`, behind
the pluggable :class:`~repro.routing.policy.RoutingPolicy` /
:class:`~repro.routing.backends.GenerationBackend` protocols.
:class:`Scheduler` is kept as a thin backward-compatible wrapper for
callers that hold raw MLP params + a simulator pipeline; new code
should construct a ``Gateway`` directly.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.config import RouterConfig
from repro.routing.backends import SimulatorBackend
from repro.routing.gateway import Gateway, GatewayStats, Request
from repro.routing.policy import MLPPolicy
from repro.serving.pipeline import RAGPipeline

# Backward-compatible aliases: the scheduler's request/stats types ARE
# the gateway's.
SchedulerStats = GatewayStats

__all__ = ["Request", "Scheduler", "SchedulerStats"]


class Scheduler:
    """Micro-batching scheduler with adaptive refusal back-pressure.

    Thin wrapper: ``Scheduler(pipe, params, cfg)`` ==
    ``Gateway(MLPPolicy(params, cfg), SimulatorBackend(pipe), ...)``.
    """

    def __init__(self, pipeline: RAGPipeline, policy_params, router_cfg:
                 RouterConfig, *, index=None, max_batch: int = 16,
                 adaptive_refusal: bool = True, base_refusal_share: float = 0.6):
        self.pipe = pipeline
        self.params = policy_params
        self.rcfg = router_cfg
        self.index = index if index is not None else pipeline.index
        self.gateway = Gateway(
            MLPPolicy(policy_params, router_cfg),
            SimulatorBackend(pipeline),
            router_cfg=router_cfg, index=self.index, max_batch=max_batch,
            adaptive_refusal=adaptive_refusal,
            base_refusal_share=base_refusal_share)

    # ------------------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        return self.gateway.queue

    @property
    def stats(self) -> GatewayStats:
        return self.gateway.stats

    @property
    def budget(self):
        return self.gateway.budget

    def submit(self, reqs: Sequence[Request]) -> None:
        self.gateway.submit(reqs)

    def step(self) -> Optional[GatewayStats]:
        return self.gateway.step()

    def drain(self) -> GatewayStats:
        return self.gateway.drain()
