"""Request scheduler: SLO-routed batched serving with budget feedback.

The production loop the paper's controller lives in:

  queue -> route (policy, per-request SLO) -> group by action bucket
        -> execute buckets (retrieval batched per depth, generation
           batched per mode) -> record outcomes -> error budgets
        -> (adaptive mitigation) budget burn tightens the refusal share.

Generation executes through the RAGPipeline backend (simulator or local
JAX model); batching here is the control-plane batching — the engine's
prefill/decode batching is exercised by examples/serve_rag_slo.py.
"""
from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.actions import ACTIONS, REFUSE_ACTION, SLO_PROFILES, reward
from repro.core.config import RouterConfig, SLOProfile
from repro.core.features import state_vector
from repro.core.policy import policy_logits
from repro.core.serving_types import RequestOutcome
from repro.data.synthetic_squad import Question
from repro.serving.pipeline import RAGPipeline
from repro.serving.slo_budget import DEFAULT_TARGETS, SLOBudgetTracker

import jax.numpy as jnp


@dataclass
class Request:
    qid: int
    question: Question
    slo: str = "quality_first"
    arrival_ms: float = 0.0


@dataclass
class SchedulerStats:
    served: int = 0
    total_reward: float = 0.0
    action_counts: Dict[int, int] = field(default_factory=lambda: defaultdict(int))
    refusal_cap_history: List[float] = field(default_factory=list)

    @property
    def avg_reward(self) -> float:
        return self.total_reward / max(self.served, 1)


class Scheduler:
    """Micro-batching scheduler with adaptive refusal back-pressure."""

    def __init__(self, pipeline: RAGPipeline, policy_params, router_cfg:
                 RouterConfig, *, index=None, max_batch: int = 16,
                 adaptive_refusal: bool = True, base_refusal_share: float = 0.6):
        self.pipe = pipeline
        self.params = policy_params
        self.rcfg = router_cfg
        self.index = index if index is not None else pipeline.index
        self.max_batch = max_batch
        self.adaptive = adaptive_refusal
        self.base_share = base_refusal_share
        self.budget = SLOBudgetTracker(DEFAULT_TARGETS)
        self.stats = SchedulerStats()
        self.queue: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, reqs: Sequence[Request]) -> None:
        self.queue.extend(reqs)

    def _route(self, batch: List[Request]) -> np.ndarray:
        states = np.stack([state_vector(r.question.text, self.index,
                                        self.rcfg) for r in batch])
        logits = np.asarray(policy_logits(self.params, jnp.asarray(states),
                                          self.rcfg))
        acts = logits.argmax(axis=-1)
        if self.adaptive:
            # budget back-pressure: cap the refuse share of this batch;
            # demote the least-confident refusals to the runner-up action
            cap = self.budget.refusal_cap_adjustment(self.base_share)
            self.stats.refusal_cap_history.append(cap)
            is_ref = acts == REFUSE_ACTION
            n_allowed = int(cap * len(batch))
            if is_ref.sum() > n_allowed:
                margin = logits[:, REFUSE_ACTION] - np.partition(
                    logits, -2, axis=1)[:, -2]
                order = np.argsort(np.where(is_ref, margin, np.inf))
                for i in order[: int(is_ref.sum()) - n_allowed]:
                    runner = np.argsort(logits[i])[-2]
                    acts[i] = runner
        return acts

    def step(self) -> Optional[SchedulerStats]:
        """Serve one micro-batch off the queue."""
        if not self.queue:
            return None
        batch, self.queue = self.queue[: self.max_batch], \
            self.queue[self.max_batch:]
        acts = self._route(batch)

        # bucket by action so each retrieval depth runs as one batch
        buckets: Dict[int, List[int]] = defaultdict(list)
        for i, a in enumerate(acts):
            buckets[int(a)].append(i)

        for a, idxs in sorted(buckets.items()):
            action = ACTIONS[a]
            for i in idxs:
                r = batch[i]
                t0 = time.time()
                out = self.pipe.execute(r.question, action)
                profile = SLO_PROFILES[r.slo]
                rew = reward(profile, correct=out.correct,
                             cost_tokens=out.cost_tokens,
                             hallucinated=out.hallucinated,
                             refused=out.refused,
                             answerable=out.answerable,
                             pre_retrieval=(a == REFUSE_ACTION))
                outcome = RequestOutcome(
                    qid=r.qid, action=a, correct=out.correct,
                    refused=out.refused, hallucinated=out.hallucinated,
                    cost_tokens=out.cost_tokens,
                    answerable=out.answerable,
                    latency_ms=(time.time() - t0) * 1e3)
                self.budget.record(outcome)
                self.stats.served += 1
                self.stats.total_reward += rew
                self.stats.action_counts[a] += 1
        return self.stats

    def drain(self) -> SchedulerStats:
        while self.queue:
            self.step()
        return self.stats
