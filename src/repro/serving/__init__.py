"""Serving layer: the RAG executor, the unified Gateway facade (see
``repro.routing``), the legacy Scheduler wrapper, SLO error budgets,
and the KV-cache generation engine."""
from repro.serving.pipeline import RAGPipeline, ActionOutcome

__all__ = ["RAGPipeline", "ActionOutcome"]
