from repro.serving.pipeline import RAGPipeline, ActionOutcome

__all__ = ["RAGPipeline", "ActionOutcome"]
