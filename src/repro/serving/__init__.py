"""Serving layer: the RAG executor, the unified Gateway facade (see
``repro.routing``), the legacy Scheduler wrapper, SLO error budgets,
and the KV-cache generation engines (padded-bucket and
continuous-batching).

Engine symbols resolve lazily via module ``__getattr__`` so the engine
modules only import when actually used.
"""
from __future__ import annotations

import importlib

from repro.serving.pipeline import RAGPipeline, ActionOutcome

_LAZY = {
    "Engine": "repro.serving.engine",
    "GenerationResult": "repro.serving.engine",
    "ContinuousEngine": "repro.serving.continuous",
    "CompletedGeneration": "repro.serving.continuous",
    "EngineStats": "repro.serving.continuous",
    "SingleDeviceExecutor": "repro.serving.executor",
    "ShardedExecutor": "repro.serving.executor",
    "PagePool": "repro.serving.paged",
    "PagePlan": "repro.serving.paged",
    "hash_prefix_pages": "repro.serving.paged",
    "AsyncGateway": "repro.serving.streaming",
    "StreamHandle": "repro.serving.streaming",
    "AdmissionConfig": "repro.serving.streaming",
    "FaultSpec": "repro.serving.faults",
    "FaultPlan": "repro.serving.faults",
    "ChaosInjector": "repro.serving.faults",
    "ChaosRetriever": "repro.serving.faults",
    "ChaosExecutor": "repro.serving.faults",
    "RetryPolicy": "repro.serving.faults",
    "FaultError": "repro.serving.faults",
    "TransientFaultError": "repro.serving.faults",
    "FaultTimeoutError": "repro.serving.faults",
    "LoadGenerator": "repro.serving.traffic",
    "PoissonProcess": "repro.serving.traffic",
    "OnOffProcess": "repro.serving.traffic",
    "VirtualClock": "repro.serving.traffic",
    "build_trace": "repro.serving.traffic",
    "sweep_offered_load": "repro.serving.traffic",
}

__all__ = ["RAGPipeline", "ActionOutcome", *sorted(_LAZY)]


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
