"""Open-loop streaming gateway: always-on serving over the continuous
engine, with SLO-actuated admission control.

Every serving path before this module was closed-loop: the
:class:`~repro.routing.gateway.Gateway` routes a finished micro-batch,
blocks in ``execute_mixed`` until the engine drains, and harvests.
Real traffic is open-loop — requests arrive whenever they arrive, and
the service's obligation (the SLO) is per-request latency, not batch
throughput.  :class:`AsyncGateway` makes the engine's mid-stream
admission and prefill/decode overlap *always-on*:

* clients call :meth:`AsyncGateway.submit_stream` at any time from any
  thread and get a :class:`StreamHandle` (future) back;
* a background host serving thread (or an external driver calling
  :meth:`AsyncGateway.pump` — the deterministic path the virtual-time
  load harness uses) continuously drains the arrival queue, routes
  admitted requests, feeds them into the backend's shared in-flight
  stream, and completes handles as the engine harvests them.

**The control loop.**  The SLO budget tracker stops being a passive
observer here: :class:`AdmissionConfig` maps short-window budget burn
(:meth:`~repro.serving.slo_budget.SLOBudgetTracker.burn_rate`) to three
actuations, applied at the queue in escalating order of severity and
counted separately from policy refusals in ``GatewayStats``:

1. **load-shed** — reject at the queue (typed ``shed`` outcome, the
   request is never routed): backlog beyond ``max_backlog``, the
   request's deadline already expired while queued, or the latency
   budget burning past ``shed_burn``;
2. **force-refuse** — the policy routed an answer but the latency/cost
   budgets burn past ``force_refuse_burn``: the request is served the
   cheap refusal instead (the paper's refusal action as a *load* tool,
   the reconfiguration loop of the SLA-management RAG paper);
3. **depth-clamp** — cost burn past ``clamp_burn``: the routed action
   is swapped for the shallowest same-mode/same-retriever action, so
   retrieval depth (the paper's main cost lever) sheds work without
   refusing anyone.

Determinism: ``pump`` holds one lock and consumes the arrival queue in
submission order; with a virtual clock (see
:mod:`repro.serving.traffic`) and no background thread, the same seed
reproduces the same completions, sheds, and latencies bit-for-bit.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from collections import deque

from repro.core.errors import TransientFaultError
from repro.obs import RequestBreakdown
from repro.routing.gateway import Gateway, GatewayStats, Request
from repro.routing.registry import Action, ActionSpace
from repro.serving.faults import RetryPolicy
from repro.serving.pipeline import ActionOutcome
from repro.serving.slo_budget import BudgetState, latency_target

SHED_TEXT = "<shed: admission control rejected this request>"

# sentinel: "caller didn't say" vs an explicit retry=None (disabled)
_DEFAULT_RETRY = object()


@dataclass(frozen=True)
class AdmissionConfig:
    """Thresholds mapping budget burn to queue-level actuation.

    Burn rates are short-window ``budget_consumed`` values (1.0 = the
    recent window alone is eating exactly the full error budget); the
    defaults engage shedding only under sustained violation."""

    max_backlog: int = 64            # shed beyond this many in flight
    shed_burn: float = 2.0           # latency burn-rate => shed at queue
    force_refuse_burn: float = 1.5   # latency/cost burn => forced refusal
    clamp_burn: float = 1.0          # cost burn => clamp retrieval depth
    burn_window: int = 64            # events in the actuation window
    min_events: int = 16             # no burn actuation before this many
    shed_expired: bool = True        # shed requests already past deadline

    def __post_init__(self):
        if self.max_backlog < 1:
            raise ValueError("max_backlog must be >= 1")


@dataclass
class StreamHandle:
    """Future for one open-loop request.

    ``outcome`` is an :class:`ActionOutcome`; ``shed=True`` marks a
    request admission control rejected at the queue (it was never
    routed or served — typed apart from policy refusals).  Timestamps
    are gateway-clock seconds."""

    request: Request
    arrival_t: float
    outcome: Optional[ActionOutcome] = None
    shed: bool = False
    forced_refusal: bool = False
    first_token_t: Optional[float] = None
    completed_t: Optional[float] = None
    retries: int = 0                  # transient-fault resubmissions
    # set when the gateway itself died (backend raised a non-transient
    # exception): result() re-raises it instead of returning an outcome
    error: Optional[BaseException] = None
    # per-stage latency attribution (queue_wait/admission/retrieval/
    # prefill/decode/harvest) — set at completion when tracing is on
    breakdown: Optional[RequestBreakdown] = None
    _event: threading.Event = field(default_factory=threading.Event)
    # gateway-internal: routed action + whether burn forced the refusal
    _action: int = -1
    _forced: bool = False
    # gateway-internal trace stamps: popped off the arrival queue /
    # handed to the backend stream (gateway-clock seconds; 0 = not yet)
    _pop_t: float = 0.0
    _dispatch_t: float = 0.0

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> ActionOutcome:
        """Block until completed (or raise TimeoutError).  Raises the
        gateway's fatal error if serving died while this was in
        flight — a hung ``wait`` is never the failure mode."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request qid={self.request.qid} still in flight")
        if self.error is not None:
            raise self.error
        return self.outcome

    @property
    def latency_ms(self) -> Optional[float]:
        if self.completed_t is None:
            return None
        return (self.completed_t - self.arrival_t) * 1e3

    @property
    def first_token_ms(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return (self.first_token_t - self.arrival_t) * 1e3

    @property
    def deadline_met(self) -> bool:
        """Completed, answered (not shed/refused), within deadline."""
        if self.outcome is None or self.shed or self.outcome.refused:
            return False
        if self.request.deadline_ms <= 0:
            return True
        return self.latency_ms <= self.request.deadline_ms

    def _complete(self, outcome: ActionOutcome, t: float, *,
                  shed: bool = False, forced: bool = False,
                  first_token_t: Optional[float] = None) -> None:
        self.outcome = outcome
        self.shed = shed
        self.forced_refusal = forced
        self.first_token_t = first_token_t
        self.completed_t = t
        self._event.set()


class AsyncGateway(Gateway):
    """Open-loop serving: thread-safe submission + an always-on pump.

    Subclasses :class:`Gateway`, so the closed-loop ``serve`` /
    ``step`` paths (and all their routing, refusal-cap back-pressure,
    and accounting) are untouched — this class adds the streaming
    entry points on top.  The backend must implement the streaming
    protocol (``stream_submit`` / ``stream_poll`` / ``stream_backlog``
    — :class:`~repro.routing.engine_backend.ContinuousEngineBackend`
    over the real engine, :class:`~repro.routing.backends
    .SimulatorBackend` for the synthetic service model).

    ``clock`` is injectable: pass a virtual clock's ``now`` (and build
    the backend's engine with the same clock) for deterministic
    simulated-time serving; the default is the host monotonic clock.
    """

    def __init__(self, policy, backend, *, admission: Optional[
                     AdmissionConfig] = None,
                 clock: Optional[Callable[[], float]] = None,
                 deadline_ms: float = 0.0,
                 latency_objective: float = 0.90,
                 route_batch: int = 16, retry=_DEFAULT_RETRY,
                 **gateway_kw):
        if not hasattr(backend, "stream_submit"):
            raise TypeError(
                f"AsyncGateway needs a streaming backend (stream_submit/"
                f"stream_poll); {type(backend).__name__} has neither — "
                f"use ContinuousEngineBackend or SimulatorBackend")
        # streaming retries default ON (one deadline-aware resubmission
        # per request): with no faults in play the transient path never
        # fires, so this is parity-safe; pass retry=None to disable
        if retry is _DEFAULT_RETRY:
            retry = RetryPolicy(max_retries=1)
        # the clock goes through the base Gateway so closed-loop spans,
        # the tracer, and open-loop stamps all share one time domain
        super().__init__(policy, backend, retry=retry, clock=clock,
                         **gateway_kw)
        self.admission = admission or AdmissionConfig()
        # default per-request deadline (ms) stamped at submission when
        # the request doesn't carry one; 0 = no deadline
        self.deadline_ms = float(deadline_ms)
        self.route_batch = max(1, route_batch)
        # the latency SLO joins the budget targets so burn-rate
        # actuation has a latency signal to watch (threshold = the
        # default deadline when set, else 1s)
        thr = self.deadline_ms if self.deadline_ms > 0 else 1000.0
        if "latency" not in self.budget.states:
            t = latency_target(thr, objective=latency_objective)
            self.budget.states[t.name] = BudgetState(t)
        self.budget.burn_window = self.admission.burn_window
        self._lock = threading.Lock()
        self._arrivals: Deque[StreamHandle] = deque()
        self._in_flight: Dict[int, StreamHandle] = {}   # rid -> handle
        # transient-fault resubmissions waiting out their backoff:
        # (not-before gateway-clock time, handle), submission order
        self._retry_q: List[Tuple[float, StreamHandle]] = []
        # fatal serving error (backend raised non-transiently): set
        # once, rejects everything in flight, makes drain/stop return
        self._failed: Optional[BaseException] = None
        # handles popped off the queues and being dispatched by the
        # CURRENT pump iteration — they live in pump-local lists, so
        # _fail must see them here or a fatal mid-dispatch exception
        # would strand them pending forever (the silent-hang bug)
        self._processing: List[StreamHandle] = []
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._shallowest: Dict[Tuple[str, str], Action] = {}
        for a in self.space:
            if a.mode == "refuse" or a.k <= 0:
                continue
            key = (a.mode, a.retriever)
            cur = self._shallowest.get(key)
            if cur is None or a.k < cur.k:
                self._shallowest[key] = a

    # -- submission (any thread, any time) -----------------------------

    def submit_stream(self, request: Request) -> StreamHandle:
        """Enqueue one open-loop request; returns its future.  The
        arrival time and deadline are stamped HERE — queueing delay is
        part of the latency the SLO measures."""
        now = self.clock()
        if request.deadline_ms <= 0 and self.deadline_ms > 0:
            request.deadline_ms = self.deadline_ms
        request.arrival_ms = now * 1e3
        handle = StreamHandle(request=request, arrival_t=now)
        with self._lock:
            failed = self._failed
            if failed is None:
                self._arrivals.append(handle)
                # root span opens at arrival: queueing delay is part of
                # what the trace must attribute (tracer state is only
                # ever touched under the pump lock)
                self.tracer.begin_request(request.qid, now)
        if failed is not None:
            # a dead gateway must not hand out handles that never
            # complete: reject immediately with the fatal error
            handle.error = failed
            handle._complete(self._fault_outcome(
                request, -1, f"gateway failed: {failed}"), now)
        return handle

    @property
    def in_flight(self) -> int:
        """Requests somewhere between submission and completion."""
        with self._lock:
            return (len(self._arrivals) + len(self._in_flight)
                    + len(self._retry_q))

    @property
    def failed(self) -> Optional[BaseException]:
        """The fatal serving error, if the gateway has died."""
        return self._failed

    # -- admission control ---------------------------------------------

    def _shed_outcome(self, req: Request) -> ActionOutcome:
        a = self.space.refuse_action
        return ActionOutcome(
            qid=req.qid, action=(a if a is not None else -1),
            correct=False, refused=True, hallucinated=False,
            cost_tokens=0.0, hit=False,
            answerable=req.question.answerable, answer=SHED_TEXT)

    def _should_shed(self, handle: StreamHandle, now: float,
                     backlog: int) -> bool:
        adm = self.admission
        if backlog >= adm.max_backlog:
            return True
        req = handle.request
        if (adm.shed_expired and req.deadline_ms > 0
                and (now - handle.arrival_t) * 1e3 > req.deadline_ms):
            return True     # deadline burned in the queue: serving it
        #                     can only waste slots other requests need
        lat = self.budget.states.get("latency")
        if (lat is not None and len(lat.events) >= adm.min_events
                and lat.burn_rate(adm.burn_window) >= adm.shed_burn):
            return True
        return False

    def _burn(self, name: str) -> float:
        s = self.budget.states.get(name)
        if s is None or len(s.events) < self.admission.min_events:
            return 0.0
        return s.burn_rate(self.admission.burn_window)

    def _actuate_action(self, a: int) -> Tuple[int, str]:
        """Post-route actuation for one request: returns (action_idx,
        "" | "forced_refuse" | "clamped")."""
        action = self.space[a]
        if action.mode == "refuse":
            return a, ""
        adm = self.admission
        hot = max(self._burn("latency"), self._burn("cost"))
        ref = self.space.refuse_action
        if ref is not None and hot >= adm.force_refuse_burn:
            return ref, "forced_refuse"
        if action.k > 0 and self._burn("cost") >= adm.clamp_burn:
            shallow = self._shallowest.get((action.mode, action.retriever))
            if shallow is not None and shallow.k < action.k:
                return shallow.idx, "clamped"
        return a, ""

    # -- fault handling -------------------------------------------------

    def _fault_outcome(self, req: Request, a: int,
                       reason: str) -> ActionOutcome:
        """Terminal transient-failure outcome (typed ``transient`` so
        GatewayStats counts it under ``faulted``, apart from sheds and
        policy refusals)."""
        ref = self.space.refuse_action
        idx = a if a >= 0 else (ref if ref is not None else -1)
        return ActionOutcome(
            qid=req.qid, action=idx, correct=False, refused=True,
            hallucinated=False, cost_tokens=0.0, hit=False,
            answerable=req.question.answerable,
            answer=f"<transient fault: {reason}>", transient=True)

    def _deadline_at(self, h: StreamHandle) -> float:
        """Absolute gateway-clock deadline for the backend to enforce
        mid-stream (0 = none)."""
        if h.request.deadline_ms <= 0:
            return 0.0
        return h.arrival_t + h.request.deadline_ms / 1e3

    def _try_schedule_retry(self, h: StreamHandle, now: float) -> bool:
        """Queue one bounded, deadline-aware resubmission for a
        transient failure.  Never schedules a retry whose backoff alone
        would land past the request's deadline.  Lock held."""
        if self.retry is None or h.retries >= self.retry.max_retries:
            return False
        wait = self.retry.backoff(h.retries)
        dl = h.request.deadline_ms
        if dl > 0 and (now - h.arrival_t + wait) * 1e3 >= dl:
            return False
        h.retries += 1
        self.stats.retries += 1
        self._retry_q.append((now + wait, h))
        return True

    def _submit_handle(self, h: StreamHandle, a: int, now: float, *,
                       forced: bool) -> None:
        """Dispatch one routed handle into the backend stream; a
        transient fault at submit becomes a retry (or a terminal
        ``faulted`` outcome once the budget is spent).  Lock held."""
        h._action = a
        h._forced = forced
        tr = self.tracer
        try:
            rid, immediate = self.backend.stream_submit(
                h.request.question, self.space[a],
                deadline_at=self._deadline_at(h))
        except TransientFaultError as exc:
            # dispatch stamp + adoption of any retrieval note the
            # backend recorded before faulting: the admission span must
            # cover the failed attempt too
            h._dispatch_t = tr.now()
            tr.adopt(h.request.qid)
            if not self._try_schedule_retry(h, now):
                t = self.clock()
                self._account_stream(h, a, self._fault_outcome(
                    h.request, a, str(exc)), t, t, forced=forced)
            return
        # admission ends when the request is IN the backend stream —
        # retrieval ran inside stream_submit, so the retrieval note the
        # backend just recorded nests inside the admission interval.
        # The backend doesn't know our qid (request ids are per-stream),
        # hence note→adopt rather than a direct mark.
        h._dispatch_t = tr.now()
        tr.adopt(h.request.qid)
        if immediate is not None:
            t = self.clock()
            self._account_stream(h, a, immediate, t, t, forced=forced)
        else:
            self._in_flight[rid] = h

    # -- the serving loop body -----------------------------------------

    def pump(self) -> int:
        """One serving iteration: drain arrivals through admission
        control, route + dispatch the admitted batch, advance the
        engine one step, account + complete harvested requests.
        Returns the number of events handled (0 = idle).  Thread-safe;
        the background thread just calls this in a loop.

        A non-transient backend exception marks the whole gateway
        failed (every in-flight handle is rejected with the error so
        no waiter hangs) and re-raises."""
        try:
            return self._pump_once()
        except Exception as exc:
            self._fail(exc)
            raise

    def _pump_once(self) -> int:
        n_events = 0
        with self._lock:
            self._processing = []
            # 0) resubmit retries whose backoff has elapsed (already
            #    routed — they bypass admission and routing)
            now = self.clock()
            if self._retry_q:
                due = [(t, h) for t, h in self._retry_q if t <= now]
                self._retry_q = [(t, h) for t, h in self._retry_q
                                 if t > now]
                self._processing.extend(h for _, h in due)
                for _, h in due:
                    self._submit_handle(h, h._action, now,
                                        forced=h._forced)
                    n_events += 1

            batch: List[StreamHandle] = []
            while self._arrivals and len(batch) < self.route_batch:
                batch.append(self._arrivals.popleft())
            self._processing.extend(batch)

            # 1) queue-level admission: shed before spending any routing
            #    or retrieval work on the request
            admitted: List[StreamHandle] = []
            now = self.clock()
            backlog = self.backend.stream_backlog + len(self._in_flight)
            tr = self.tracer
            for h in batch:
                h._pop_t = now
                if self._should_shed(h, now, backlog + len(admitted)):
                    self.stats.shed += 1
                    # a shed request spent its whole life queued: its
                    # breakdown is pure queue_wait, stage sum == e2e
                    tr.mark(h.request.qid, "queue_wait",
                            h.arrival_t, now)
                    h.breakdown = tr.finish_request(
                        h.request.qid, "shed", t=now)
                    self.budget.record_breakdown(h.breakdown)
                    h._complete(self._shed_outcome(h.request), now,
                                shed=True)
                    n_events += 1
                else:
                    admitted.append(h)

            # 2) route the admitted batch (adaptive refusal cap included)
            if admitted:
                reqs = [h.request for h in admitted]
                decision, cap = self._route(reqs)
                if cap is not None and "refusal_cap" in decision.constraints:
                    self.stats.refusal_cap_history.append(cap)
                self.stats.decisions.append(decision)
                # 3) per-request burn actuation, then into the stream
                for h, a in zip(admitted, decision.actions):
                    a, what = self._actuate_action(int(a))
                    if what == "forced_refuse":
                        self.stats.forced_refusals += 1
                    elif what == "clamped":
                        self.stats.depth_clamped += 1
                    self._submit_handle(h, a, self.clock(),
                                        forced=(what == "forced_refuse"))
                    n_events += 1

            # 4) advance the engine and harvest; transient completions
            #    (executor fault, circuit denial) go back through the
            #    retry budget instead of straight to the caller
            for comp in self.backend.stream_poll():
                h = self._in_flight.pop(comp.rid, None)
                if h is None:
                    continue
                out = comp.outcome
                if (getattr(out, "transient", False)
                        and not getattr(out, "timed_out", False)
                        and self._try_schedule_retry(h, comp.finished_at)):
                    n_events += 1
                    continue
                self._account_stream(h, h._action, out,
                                     comp.finished_at, comp.admitted_at,
                                     forced=h._forced)
                n_events += 1
            self._sync_cache_stats()
            self._processing = []
        return n_events

    def _fail(self, exc: BaseException) -> None:
        """The serving plane died (non-transient backend exception):
        record the error and reject EVERYTHING in flight so no caller
        blocks forever on a handle that can never complete."""
        with self._lock:
            if self._failed is None:
                self._failed = exc
            victims = (list(self._arrivals)
                       + [h for _, h in self._retry_q]
                       + list(self._in_flight.values())
                       + [h for h in self._processing if not h.done()])
            self._arrivals.clear()
            self._retry_q = []
            self._in_flight.clear()
            self._processing = []
        seen: set = set()
        victims = [h for h in victims
                   if not (id(h) in seen or seen.add(id(h)))]
        now = self.clock()
        for h in victims:
            h.error = exc
            # close the victim's trace so no span is left open (the
            # well-formedness audit treats open spans as defects)
            self.tracer.finish_request(h.request.qid, "faulted", t=now)
            # completed-but-errored, NOT accounted: the gateway's stats
            # describe what it served, and it served nothing here
            h._complete(self._fault_outcome(
                h.request, h._action, f"gateway failed: {exc}"), now)

    def _account_stream(self, h: StreamHandle, a: int, out: ActionOutcome,
                        finished_t: float, first_token_t: float, *,
                        forced: bool) -> None:
        """Per-request accounting with TRUE per-request latency
        (arrival -> completion, queueing included) — unlike the
        closed-loop path's per-batch mean."""
        lat_ms = (finished_t - h.arrival_t) * 1e3
        tr = self.tracer
        if tr.enabled:
            # contiguous stage chain: arrival →(queue_wait)→ pop
            # →(admission)→ dispatch →(prefill)→ first token →(decode)→
            # engine finish →(harvest)→ here.  Stamps are clamped into
            # monotone order so a missing stamp (immediate refusal,
            # fault before dispatch) collapses its stage to zero width
            # instead of corrupting the tree — the top-level stage sum
            # equals end-to-end latency by construction.
            qid = h.request.qid
            t_acc = tr.now()
            arr = h.arrival_t
            fin = max(finished_t, arr)
            t_acc = max(t_acc, fin)
            pop = min(max(h._pop_t, arr) if h._pop_t else arr, fin)
            disp = min(max(h._dispatch_t, pop) if h._dispatch_t else pop,
                       fin)
            ft = first_token_t if first_token_t else disp
            ft = min(max(ft, disp), fin)
            tr.mark(qid, "queue_wait", arr, pop)
            tr.mark(qid, "admission", pop, disp)
            tr.mark(qid, "prefill", disp, ft)
            tr.mark(qid, "decode", ft, fin)
            # harvest: the completion sat in the engine's done list
            # until this pump iteration polled it
            tr.mark(qid, "harvest", fin, t_acc)
            if getattr(out, "timed_out", False):
                kind = "timed_out"
            elif getattr(out, "transient", False):
                kind = "faulted"
            else:
                kind = "completed"
            h.breakdown = tr.finish_request(
                qid, kind, t=t_acc, cost_tokens=out.cost_tokens)
            self.budget.record_breakdown(h.breakdown)
        self._account(h.request, a, out, lat_ms)
        h._complete(out, finished_t, forced=forced,
                    first_token_t=first_token_t)

    # -- background serving thread -------------------------------------

    def start(self, *, idle_sleep_s: float = 1e-3) -> "AsyncGateway":
        """Start the always-on host serving thread."""
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                try:
                    n = self.pump()
                except Exception:
                    # pump already marked the gateway failed and
                    # rejected every handle; a dead thread must not
                    # keep "serving" — but the death must be countable
                    with self._lock:
                        self.stats.fatal_errors += 1
                    return
                if n == 0:
                    # nothing arrived and nothing finished: yield the
                    # GIL briefly rather than spinning
                    # repro: allow[RPL001] idle GIL yield on the real serving thread; virtual-time tests drive pump() directly
                    time.sleep(idle_sleep_s)

        self._thread = threading.Thread(target=loop, name="async-gateway",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True, timeout: float = 60.0) -> None:
        """Stop the serving thread; with ``drain`` (default) serve out
        everything already submitted first."""
        if drain:
            deadline = time.monotonic() + timeout
            while (self.in_flight and self._failed is None
                   and time.monotonic() < deadline):
                if self._thread is None or not self._thread.is_alive():
                    while (self.in_flight and self._failed is None
                           and time.monotonic() < deadline):
                        try:
                            n = self.pump()
                        except Exception:
                            # handles already rejected by _fail; count
                            # the failed drain so shutdown isn't silent
                            with self._lock:
                                self.stats.fatal_errors += 1
                            break
                        if n == 0:
                            # repro: allow[RPL001] real-time drain pacing at shutdown; virtual-time paths use drain_stream()
                            time.sleep(1e-3)
                    break
                # repro: allow[RPL001] real-time drain pacing at shutdown; virtual-time paths use drain_stream()
                time.sleep(1e-3)
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def drain_stream(self) -> GatewayStats:
        """Pump (on the caller's thread) until nothing is in flight.
        Returns immediately once the gateway has failed — ``_fail``
        rejects every outstanding handle, so there is nothing left to
        drain (and nothing to hang on)."""
        while self.in_flight and self._failed is None:
            if self.pump() == 0 and self.in_flight:
                # work exists but didn't advance this tick (e.g. the
                # engine is between chunks) — keep pumping
                continue
        return self.stats

    def __enter__(self) -> "AsyncGateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
