"""Goodput under faults: open-loop traffic with seeded chaos plans.

The fault-tolerance claim is quantitative: under injected retriever
brownouts, executor fault bursts, and NaN storms, the serving plane
must keep delivering goodput — degrading to the fallback retriever,
retrying transients inside deadlines, and quarantining poisoned slots —
and every offered request must still resolve (no hangs).  This bench
measures that, deterministically: each scenario is a seeded
:class:`~repro.serving.faults.FaultPlan` driven through the REAL
continuous engine in virtual time (same seed, same rows — CI asserts
on the artifact).

Scenarios:

* ``no_faults``      — the parity baseline: all fault machinery armed
  but no plan; degraded / retries / faulted must all be ZERO.
* ``retriever_brownout`` — the ``dense`` retriever raises for a window
  of lookups: the circuit breaker trips, dense actions degrade to the
  bm25 fallback, and after the window the half-open probe re-closes
  the breaker (recovery time = last injected fault -> first healthy
  non-degraded answer).
* ``executor_fault_burst`` — decode chunks raise transiently: resident
  requests abort, the gateway retries them inside their deadlines.
* ``nan_storm`` — decode poisons slots with NaN flags: the scheduler
  quarantines them (peers keep decoding) and serves on from the
  surviving slot pool.

Writes ``benchmarks/artifacts/BENCH_chaos.json`` AND repo-root
``BENCH_chaos.json``.

    PYTHONPATH=src:. python benchmarks/chaos_bench.py [--quick]
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import save_artifact
from repro.configs import get_config
from repro.core.config import RetrievalConfig
from repro.data.synthetic_squad import SyntheticSquad
from repro.data.tokenizer import HashTokenizer
from repro.models import build_model
from repro.obs import MetricsRegistry, Tracer
from repro.retrieval.bm25 import BM25Index
from repro.retrieval.hybrid import IndexRetriever
from repro.routing import FixedPolicy
from repro.routing.engine_backend import ContinuousEngineBackend
from repro.routing.registry import Action, ActionSpace
from repro.serving.faults import ChaosInjector, FaultPlan, FaultSpec, \
    RetryPolicy
from repro.serving.streaming import AdmissionConfig, AsyncGateway
from repro.serving.traffic import LoadGenerator, PoissonProcess, \
    VirtualClock, build_trace

NUM_SLOTS = 4
MAX_PROMPT = 48
MAX_NEW = 8
SYNC_EVERY = 4
RATE = 120.0               # offered req/s of virtual time (comfortable)
DEADLINE_MS = 800.0        # roomy: faults, not overload, drive misses
QUANTUM_S = 0.01           # virtual seconds charged per gateway pump

# every non-refuse action reads through the "dense" retriever so the
# brownout scenario actually exercises the fallback rewrite (here
# "dense" is a second name over the same BM25 corpus — the fault
# seam and breaker don't care what's underneath)
CHAOS_SPACE = ActionSpace("chaos4", (
    Action(0, 0, "refuse"),
    Action(1, 1, "guarded", "dense"),
    Action(2, 3, "guarded", "dense"),
    Action(3, 5, "auto", "dense"),
))


def scenario_plans(n_requests: int):
    """name -> FaultPlan.  Windows are in site-invocation counts, so
    they scale with the trace length."""
    # short enough that the breaker's half-open probes exhaust the
    # fault window and re-close within the trace (recovery measurable)
    brown = max(4, n_requests // 6)
    return {
        "no_faults": FaultPlan(),
        "retriever_brownout": FaultPlan(specs=(
            # every dense lookup in [4, 4+brown) raises; bm25 stays up
            FaultSpec(site="retriever.dense", kind="raise",
                      start=4, count=brown),
        ), seed=0),
        "executor_fault_burst": FaultPlan(specs=(
            FaultSpec(site="executor.decode", kind="raise",
                      start=6, count=3),
        ), seed=0),
        "nan_storm": FaultPlan(specs=(
            FaultSpec(site="executor.decode", kind="nan",
                      start=5, count=2, slots=(0, 1)),
        ), seed=0),
    }


def run_scenario(model, mcfg, params, data, plan: FaultPlan,
                 n_requests: int) -> dict:
    """One seeded Poisson trace through AsyncGateway over the real
    continuous engine, with ``plan`` armed, entirely in virtual time."""
    clock = VirtualClock()
    injector = ChaosInjector(plan, clock=clock.now, sleep=clock.advance)
    index = BM25Index.build([p.text for p in data.paragraphs],
                            RetrievalConfig(vocab_hash_dim=1024))
    retrievers = {"bm25": IndexRetriever("bm25", index),
                  "dense": IndexRetriever("dense", index)}
    backend = ContinuousEngineBackend.create(
        model, params, HashTokenizer(mcfg.vocab_size), index,
        num_slots=NUM_SLOTS, max_prompt_len=MAX_PROMPT,
        max_new_tokens=MAX_NEW, sync_every=SYNC_EVERY, clock=clock.now,
        retrievers=retrievers, chaos=injector,
        # small window/cooldown so trip + recovery both land inside
        # one short trace
        breaker_kw=dict(window=8, min_calls=4, failure_threshold=0.5,
                        cooldown=4))
    gw = AsyncGateway(
        FixedPolicy(2), backend, action_space=CHAOS_SPACE,
        state_fn=lambda qs: np.zeros((len(qs), 1)),
        clock=clock.now, deadline_ms=DEADLINE_MS,
        admission=AdmissionConfig(max_backlog=4 * NUM_SLOTS),
        retry=RetryPolicy(max_retries=2, backoff_s=0.02),
        # telemetry plane on the scenario's virtual clock — each row
        # gains a trace-derived "stages" per-stage p50/p99 table
        tracer=Tracer(clock.now), metrics=MetricsRegistry(clock.now))
    trace = build_trace(data.questions, PoissonProcess(RATE, seed=0),
                        n_requests, deadline_ms=DEADLINE_MS)
    gen = LoadGenerator(gw, trace)
    rep = gen.run_virtual(clock, service_quantum_s=QUANTUM_S)

    # recovery: last injected fault -> first healthy (non-degraded,
    # answered) completion after it
    recovery_s = None
    last_fire = injector.last_fire_t()
    if last_fire is not None:
        after = [h.completed_t for h in gen.last_handles
                 if h.outcome is not None and not h.outcome.refused
                 and not getattr(h.outcome, "degraded", False)
                 and h.completed_t is not None
                 and h.completed_t >= last_fire]
        if after:
            recovery_s = round(min(after) - last_fire, 4)
    eng = backend.engine.stats
    breakers = {name: {"state": b.state, "trips": b.n_trips,
                       "denied": b.n_denied}
                for name, b in backend.breakers.items()}
    row = {
        **rep.as_dict(),
        "faults_fired": len(injector.fire_log),
        "recovery_s": recovery_s,
        "engine": {"n_quarantined": eng.n_quarantined,
                   "n_nan_trips": eng.n_nan_trips,
                   "n_watchdog_trips": eng.n_watchdog_trips,
                   "n_exec_faults": eng.n_exec_faults,
                   "n_timed_out": eng.n_timed_out},
        "breakers": breakers,
    }
    # the hard liveness claim: EVERY offered request resolved
    assert row["completed"] == row["offered"], (
        f"unresolved requests: {row['completed']}/{row['offered']}")
    return row


def main(quick: bool = False) -> dict:
    mcfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                               dtype="float32")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    n_requests = 24 if quick else 48
    data = SyntheticSquad(n_paragraphs=120, n_questions=24, seed=0)

    out = {"n_requests": n_requests, "rate": RATE,
           "deadline_ms": DEADLINE_MS, "num_slots": NUM_SLOTS,
           "action_space": CHAOS_SPACE.name, "scenarios": {}}
    for name, plan in scenario_plans(n_requests).items():
        row = run_scenario(model, mcfg, params, data, plan, n_requests)
        out["scenarios"][name] = row
        print(f"{name:22s} goodput={row['goodput']:7.2f}/s "
              f"degraded={row['degraded']:2d} retries={row['retries']:2d} "
              f"timed_out={row['timed_out']:2d} faulted={row['faulted']:2d} "
              f"quarantined={row['engine']['n_quarantined']}")

    base = out["scenarios"]["no_faults"]
    assert base["degraded"] == 0 and base["retries"] == 0 \
        and base["faulted"] == 0, base
    burst = out["scenarios"]["executor_fault_burst"]
    assert burst["goodput"] > 0, burst
    # headline per-stage latency table (healthy scenario) + the
    # telemetry plane's measured hot-path cost
    out["stage_breakdown"] = base.get("stages", {})
    from benchmarks.serving_bench import tracer_overhead_row
    out["tracer_overhead"] = tracer_overhead_row(
        repeats=5 if quick else 7)
    save_artifact("BENCH_chaos", out)
    (Path(__file__).resolve().parents[1] / "BENCH_chaos.json").write_text(
        json.dumps(out, indent=1))
    return {"burst_goodput": burst["goodput"],
            "brownout_degraded": out["scenarios"][
                "retriever_brownout"]["degraded"]}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace (CI chaos-smoke)")
    args = ap.parse_args()
    print(main(quick=args.quick))
