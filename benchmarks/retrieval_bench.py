"""Retrieval subsystem benchmark: BM25 vs dense vs hybrid vs sharded.

Throughput rows (batch of queries against the synthetic corpus):

* ``bm25_pallas`` — the blocked BM25 kernel path (full score matrix +
  host top-k, the seed scoring model);
* ``dense_pallas`` — the FUSED dense score+top-k kernel
  (``kernels/dense_topk``): only (Q, k) candidates ever leave the
  kernel, the (Q, D) matrix never materializes;
* ``bm25_host`` / ``dense_host`` — the numpy serving paths
  (``index.topk`` per query, what the simulator pipeline runs);
* ``hybrid_host`` — weighted/RRF fusion of both candidate sets;
* ``cached`` — a second pass over the same query stream through the
  bounded LRU (the serving cache satellite): hit rate + speedup.

Throughput is reported as queries/s and M-scores/s (Q·D dot-rows per
second — "tokens scored" in retrieval terms).  On this CPU container
the Pallas rows run in interpret mode: correctness smokes with relative
numbers, not TPU speedup claims (same convention as serving_bench).

Quality table: hit@k (gold answer string contained in a top-k passage,
answerable questions only) per retriever for k ∈ {2, 5, 10} — the
cost/quality frontier retriever-choice routing exploits.

A forced-8-host-device subprocess probe checks the sharded paths
(``DistributedBM25`` / ``DistributedDenseIndex``: local top-k →
all-gather → merge) stay id-identical to the single-device oracles.

Finally the paper's failure-mode convention, now with retriever choice
in the action set: a compact ``hybrid9`` cheap-profile check — does
Argmax-CE still collapse to refusal, and does the constrained
objective mitigate it?

Writes ``benchmarks/artifacts/BENCH_retrieval.json`` AND repo-root
``BENCH_retrieval.json``.

    PYTHONPATH=src:. python benchmarks/retrieval_bench.py [--quick]
        [--no-probe]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.common import save_artifact
from repro.core.config import RetrievalConfig, RouterConfig, TestbedConfig
from repro.data.synthetic_squad import SyntheticSquad
from repro.retrieval import (BM25Index, DenseIndex, HybridRetriever,
                             IndexRetriever, resolve_retrievers)

RCFG = RetrievalConfig(vocab_hash_dim=1024, dense_embed_dim=256)
KS = (2, 5, 10)
REPEATS = 3


def _best_wall(fn, repeats=REPEATS):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _throughput(n_queries, n_docs, wall):
    return {"wall_s": round(wall, 4),
            "queries_per_s": round(n_queries / wall, 1),
            "mscores_per_s": round(n_queries * n_docs / wall / 1e6, 3)}


def main(n_docs: int = 512, n_queries: int = 32, probe: bool = True) -> dict:
    import jax.numpy as jnp
    from jax import lax

    from repro.kernels import bm25_scores, dense_topk

    data = SyntheticSquad(n_paragraphs=n_docs, n_questions=n_queries,
                          seed=0)
    texts = [p.text for p in data.paragraphs]
    bm25 = BM25Index.build(texts, RCFG)
    dense = DenseIndex.build(texts, RCFG)
    hybrid = HybridRetriever(
        [IndexRetriever("bm25", bm25), IndexRetriever("dense", dense)],
        texts, method=RCFG.hybrid_method)
    queries = [q.text for q in data.questions]
    D = len(texts)

    out = {"n_docs": D, "n_queries": n_queries,
           "vocab_hash_dim": RCFG.vocab_hash_dim,
           "dense_embed_dim": RCFG.dense_embed_dim, "k": max(KS)}

    # ---------------- kernel paths (batched) ----------------
    qv = jnp.asarray(np.stack([bm25.query_vector(q) for q in queries]))
    tf = jnp.asarray(bm25.tf)
    dl = jnp.asarray(bm25.doc_len)
    idf = jnp.asarray(bm25.idf)

    def bm25_kernel():
        s = bm25_scores(qv, tf, dl, idf)         # full (Q, D) matrix...
        return lax.top_k(s, max(KS))[1].block_until_ready()

    qe = jnp.asarray(np.stack([dense.encode(q) for q in queries]))
    emb = jnp.asarray(dense.emb)

    def dense_kernel():
        return dense_topk(qe, emb, k=max(KS))[1].block_until_ready()

    bm25_kernel(), dense_kernel()                # compile warmup
    out["bm25_pallas"] = _throughput(n_queries, D, _best_wall(bm25_kernel))
    out["dense_pallas"] = _throughput(n_queries, D, _best_wall(dense_kernel))

    # ---------------- host serving paths (per query) ----------------
    for name, r in (("bm25_host", IndexRetriever("bm25", bm25)),
                    ("dense_host", IndexRetriever("dense", dense)),
                    ("hybrid_host", hybrid)):
        wall = _best_wall(lambda r=r: [r.topk(q, max(KS)) for q in queries])
        out[name] = _throughput(n_queries, D, wall)

    # ---------------- cache satellite ----------------
    suite, cache = resolve_retrievers(
        {"bm25": IndexRetriever("bm25", bm25), "hybrid": hybrid},
        bm25, cache_size=4 * n_queries)
    cold = time.perf_counter()
    for q in queries:
        suite["hybrid"].passages(q, 5)
    cold = time.perf_counter() - cold
    warm = time.perf_counter()
    for q in queries:
        suite["hybrid"].passages(q, 5)
    warm = time.perf_counter() - warm
    out["cached"] = {
        "hits": cache.hits, "lookups": cache.lookups,
        "hit_rate": round(cache.hits / max(cache.lookups, 1), 3),
        "warm_speedup": round(cold / max(warm, 1e-9), 1)}

    # ---------------- hit@k quality table ----------------
    answerable = [q for q in data.questions if q.answerable and q.gold_answer]
    quality = {}
    for name, r in (("bm25", IndexRetriever("bm25", bm25)),
                    ("dense", IndexRetriever("dense", dense)),
                    ("hybrid", hybrid)):
        row = {}
        for k in KS:
            hits = sum(any(q.gold_answer in p for p in r.passages(q.text, k))
                       for q in answerable)
            row[f"hit@{k}"] = round(hits / max(len(answerable), 1), 3)
        quality[name] = row
    out["hit_at_k"] = quality

    print(f"{'retriever':>14s} {'q/s':>9s} {'Mscores/s':>10s}")
    for name in ("bm25_pallas", "dense_pallas", "bm25_host", "dense_host",
                 "hybrid_host"):
        r = out[name]
        print(f"{name:>14s} {r['queries_per_s']:9.1f} "
              f"{r['mscores_per_s']:10.3f}")
    print("hit@k:", json.dumps(quality))
    print("cache:", json.dumps(out["cached"]))

    # ---------------- sharded probe (forced 8 host devices) ----------------
    if probe:
        print("# forced-8-device sharded retrieval probe ...")
        out["sharded_probe"] = _sharded_probe()
        print("probe:", json.dumps(out["sharded_probe"]))

    # ---------------- hybrid9 refusal-collapse check ----------------
    print("# hybrid9 cheap-profile refusal-collapse check ...")
    out["hybrid9_refusal_collapse"] = _refusal_collapse_check()
    print("collapse:", json.dumps(out["hybrid9_refusal_collapse"]))

    save_artifact("BENCH_retrieval", out)
    (Path(__file__).resolve().parents[1] / "BENCH_retrieval.json"
     ).write_text(json.dumps(out, indent=1))
    return {"dense_pallas_qps": out["dense_pallas"]["queries_per_s"],
            "hybrid_hit@5": quality["hybrid"]["hit@5"],
            "hybrid9_collapsed":
                out["hybrid9_refusal_collapse"]["collapsed"]}


_PROBE_SCRIPT = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from jax.sharding import Mesh

from repro.core.config import RetrievalConfig
from repro.data.synthetic_squad import SyntheticSquad
from repro.retrieval import (BM25Index, DenseIndex, DistributedBM25,
                             DistributedDenseIndex)

cfg = RetrievalConfig(vocab_hash_dim=1024, dense_embed_dim=256)
data = SyntheticSquad(n_paragraphs=256, n_questions=16, seed=3)
texts = [p.text for p in data.paragraphs]
bm25 = BM25Index.build(texts, cfg)
dense = DenseIndex.build(texts, cfg)
mesh = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
qv = np.stack([bm25.query_vector(q.text) for q in data.questions])
qe = np.stack([dense.encode(q.text) for q in data.questions])

report = {"devices": len(jax.devices()), "n_docs": len(texts)}
dist_b = DistributedBM25(mesh, bm25.tf, bm25.doc_len, bm25.idf)
dist_d = DistributedDenseIndex(mesh, dense.emb)
for name, dist, q, oracle in (("bm25", dist_b, qv, bm25),
                              ("dense", dist_d, qe, dense)):
    i, s = dist.topk(q, k=10)                       # compile warmup
    t0 = time.perf_counter()
    i, s = dist.topk(q, k=10)
    wall = time.perf_counter() - t0
    # bm25 sums saturate differently across shard reduction orders, so
    # exact ties at the k boundary may reorder: require >=9/10 overlap
    # per query (the test_distributed_retrieval tolerance); dense gets
    # the strict id-identical check below
    ok = all(len(set(i[j].tolist()) &
                 set(oracle.topk(data.questions[j].text, 10)[0].tolist()))
             >= 9 for j in range(len(data.questions)))
    report[name] = {"wall_s": round(wall, 4), "id_parity": bool(ok),
                    "queries_per_s": round(len(q) / wall, 1)}
# dense merge must be id-IDENTICAL (ordered), not just set-equal
exact = all(dist_d.topk(qe, k=10)[0][j].tolist() ==
            dense.topk(data.questions[j].text, 10)[0].tolist()
            for j in range(len(data.questions)))
report["dense"]["id_identical"] = bool(exact)
print("PROBE_JSON:" + json.dumps(report))
"""


def _sharded_probe() -> dict:
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ, PYTHONPATH=f"{root / 'src'}:{root}")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _PROBE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    for line in res.stdout.splitlines():
        if line.startswith("PROBE_JSON:"):
            return json.loads(line[len("PROBE_JSON:"):])
    return {"error": (res.stderr or res.stdout)[-800:]}


def _refusal_collapse_check(n_train: int = 300, n_eval: int = 100,
                            n_paragraphs: int = 300) -> dict:
    """Compact hybrid9 failure-mode check (paper §6.2 convention):
    cheap-profile Argmax-CE refusal share vs the constrained
    objective's, with retriever choice in the action set."""
    import dataclasses

    from repro.core.actions import SLO_PROFILES
    from repro.core.metrics import evaluate_actions
    from repro.core.offline_log import build_testbed
    from repro.routing import ConstrainedPolicy, MLPPolicy, get_action_space

    space = get_action_space("hybrid9")
    cfg = TestbedConfig(n_train=n_train, n_eval=n_eval,
                        n_paragraphs=n_paragraphs,
                        router=RouterConfig(n_actions=space.n_actions,
                                            n_epochs=15))
    _, _, _, train_log, eval_log = build_testbed(cfg, space)
    profile = SLO_PROFILES["cheap"]
    rewards = train_log.rewards(profile)
    # the Lagrangian caps expected refusal PROBABILITY; with 9 actions
    # the other logits split ~0.6 of the mass 8 ways, so the paper's
    # 0.45 cap never flips the argmax — the cap must push p(refuse)
    # toward ~1/9 before routing changes.  0.2 binds (collapse is
    # HARDER to mitigate as the action set grows — a failure-mode
    # scaling observation the bench records).
    rates = {}
    for name, pol in (
            ("argmax_ce", MLPPolicy.train(train_log, rewards, cfg.router,
                                          objective="argmax_ce")),
            ("constrained", ConstrainedPolicy.train(train_log, rewards,
                                                    cfg.router,
                                                    refusal_cap=0.2))):
        rep = evaluate_actions(eval_log, pol.actions(eval_log.states),
                               profile, name)
        rates[name] = round(rep.refusal_rate, 3)
    return {"slo": "cheap", "n_eval": n_eval, **rates,
            "collapsed": rates["argmax_ce"] > 0.5,
            "mitigated": rates["constrained"] < rates["argmax_ce"]}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (smaller corpus/stream)")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the forced-8-device sharded probe")
    args = ap.parse_args()
    kw = dict(n_docs=256, n_queries=16) if args.quick else {}
    print(main(probe=not args.no_probe, **kw))
