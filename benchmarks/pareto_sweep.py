"""Beyond paper: where does refusal collapse begin?

Sweep interpolated SLO profiles from quality_first (t=0) to cheap (t=1)
and track the learned policy's refusal rate and reward — locating the
collapse onset the paper observes only at the endpoints."""
import numpy as np

from benchmarks.common import bar, canonical_results, save_artifact
from repro.core.conditioned import interpolate
from repro.core.metrics import evaluate_actions
from repro.routing import MLPPolicy, get_slo_profile

TS = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0]


def main() -> dict:
    cfg, _, _, (train_log, eval_log) = canonical_results()
    a, b = get_slo_profile("quality_first"), get_slo_profile("cheap")
    rows = []
    for t in TS:
        p = interpolate(a, b, t)
        policy = MLPPolicy.train(train_log, train_log.rewards(p), cfg.router,
                                 objective="argmax_ce")
        acts = policy.actions(eval_log.states)
        rep = evaluate_actions(eval_log, acts, p, f"t={t}")
        rows.append({"t": t, "refusal": rep.refusal_rate, "acc": rep.acc,
                     "reward": rep.reward, "cost": rep.cost,
                     "refuse_share": float(rep.action_dist[4])})
    save_artifact("pareto_sweep", rows)
    print("  t   refusal  a4-share  acc    cost")
    for r in rows:
        print(f"{r['t']:4.1f}  {r['refusal']:6.3f}  {r['refuse_share']:6.3f} "
              f" {r['acc']:5.3f} {r['cost']:7.1f}  {bar(r['refuse_share'], 30)}")
    onset = next((r["t"] for r in rows if r["refuse_share"] > 0.5), None)
    return {"collapse_onset_t": onset,
            "endpoint_refusals": [rows[0]["refusal"], rows[-1]["refusal"]]}


if __name__ == "__main__":
    print(main())
