"""Paper Table 1: {SLO} × {baseline, best-fixed, Argmax-CE, Argmax-CE-WT}
(+ beyond-paper constrained objective) on the N=200 eval split."""
from benchmarks.common import canonical_results, save_artifact


def main() -> dict:
    cfg, res, extras, logs = canonical_results()
    save_artifact("table1_slo_grid", res.rows)
    print(res.table())
    rows = {(r["slo"], r["method"]): r for r in res.rows}
    bf_q = [r for (s, m), r in rows.items()
            if s == "quality_first" and m.startswith("best-fixed")][0]
    ce_q = rows[("quality_first", "argmax_ce")]
    ce_c = rows[("cheap", "argmax_ce")]
    bf_c = [r for (s, m), r in rows.items()
            if s == "cheap" and m.startswith("best-fixed")][0]
    return {
        "quality_ce_minus_bestfixed_reward":
            round(ce_q["reward"] - bf_q["reward"], 4),
        "cheap_ce_refusal": ce_c["refuse"],
        "cheap_collapse_reward_gap": round(ce_c["reward"] - bf_c["reward"], 4),
        "best_fixed_quality": bf_q["method"],
        "best_fixed_cheap": bf_c["method"],
    }


if __name__ == "__main__":
    print(main())
