"""§Perf before/after table, regenerated from the dry-run records."""
import json
from pathlib import Path

from benchmarks.common import save_artifact

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

PAIRS = [
    ("H1 it1 cf=1.0", "deepseek-v3-671b__train_4k__single",
     "deepseek-v3-671b__train_4k__single_cf10"),
    ("H1 it3 scatter-down", "deepseek-v3-671b__train_4k__single",
     "deepseek-v3-671b__train_4k__single_cf10_bf16_scat"),
    ("H2 pad-heads qwen prefill", "qwen1.5-32b__prefill_32k__single",
     "qwen1.5-32b__prefill_32k__single_padheads"),
    ("H2 pad-heads qwen train", "qwen1.5-32b__train_4k__single",
     "qwen1.5-32b__train_4k__single_padheads"),
    ("H2 pad-heads minicpm3 prefill", "minicpm3-4b__prefill_32k__single",
     "minicpm3-4b__prefill_32k__single_padheads"),
    ("H2 pad-heads minicpm3 train", "minicpm3-4b__train_4k__single",
     "minicpm3-4b__train_4k__single_padheads"),
    ("H2 pad-heads whisper train", "whisper-large-v3__train_4k__single",
     "whisper-large-v3__train_4k__single_padheads"),
    ("H2 pad-heads whisper prefill", "whisper-large-v3__prefill_32k__single",
     "whisper-large-v3__prefill_32k__single_padheads"),
    ("H3 mla-absorb minicpm3 decode", "minicpm3-4b__decode_32k__single",
     "minicpm3-4b__decode_32k__single_absorb"),
    ("H3 mla-absorb deepseek decode", "deepseek-v3-671b__decode_32k__single",
     "deepseek-v3-671b__decode_32k__single_absorb"),
    ("H4 window-ring gemma3 500k", "gemma3-12b__long_500k__single",
     "gemma3-12b__long_500k__single_ring"),
    ("H6 one-hot embed (REFUTED)", "command-r-35b__train_4k__single",
     "command-r-35b__train_4k__single_onehot"),
]


def _load(name):
    return json.loads((DRYRUN / f"{name}.json").read_text())


def main() -> dict:
    rows = []
    print(f"{'iteration':>32s} {'temp GiB':>18s} {'coll B/body':>22s} "
          f"{'HLO flops':>22s}")
    for label, base, var in PAIRS:
        if not (DRYRUN / f"{var}.json").exists():
            continue
        b, v = _load(base), _load(var)
        tb = b["temp_size_in_bytes"] / 2**30
        tv = v["temp_size_in_bytes"] / 2**30
        cb = sum(b["collective_bytes"].values())
        cv = sum(v["collective_bytes"].values())
        fb, fv = b["flops"], v["flops"]
        rows.append({"iteration": label,
                     "temp_gib": [round(tb, 2), round(tv, 2)],
                     "coll_bytes": [cb, cv],
                     "flops": [fb, fv]})
        print(f"{label:>32s} {tb:8.2f}→{tv:8.2f} {cb:10.3g}→{cv:10.3g} "
              f"{fb:10.3g}→{fv:10.3g}")
    save_artifact("perf_variants", rows)
    best = max(rows, key=lambda r: r["temp_gib"][0] / max(r["temp_gib"][1], 1e-9))
    return {"n_variants": len(rows),
            "biggest_temp_reduction": best["iteration"]}


if __name__ == "__main__":
    print(main())
