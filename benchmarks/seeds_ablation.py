"""Beyond paper (addresses limitation §8 'point estimates only'):
Table-1 headline metrics across 5 corpus/policy seeds, mean ± std."""
import numpy as np

from benchmarks.common import save_artifact
from repro.core.actions import SLO_PROFILES
from repro.core.config import RouterConfig, TestbedConfig
from repro.core.metrics import best_fixed_action, evaluate_actions
from repro.core.offline_log import build_testbed
from repro.core.policy import policy_actions, train_policy

N_SEEDS = 5


def main() -> dict:
    metrics = {"quality_ce_reward": [], "quality_bf_reward": [],
               "cheap_ce_refusal": [], "cheap_gap": []}
    for seed in range(N_SEEDS):
        cfg = TestbedConfig(n_train=500, n_eval=150, n_paragraphs=400,
                            seed=seed, router=RouterConfig(n_epochs=20,
                                                           seed=seed))
        _, _, _, train_log, eval_log = build_testbed(cfg)
        for slo, keys in (("quality_first", ("quality_ce_reward",
                                             "quality_bf_reward")),
                          ("cheap", ("cheap_ce_refusal", "cheap_gap"))):
            p = SLO_PROFILES[slo]
            tr = train_policy(train_log, train_log.rewards(p), cfg.router,
                              objective="argmax_ce")
            acts = policy_actions(tr.params, eval_log.states, cfg.router)
            rep = evaluate_actions(eval_log, acts, p, "ce")
            _, bf = best_fixed_action(eval_log, p)
            if slo == "quality_first":
                metrics["quality_ce_reward"].append(rep.reward)
                metrics["quality_bf_reward"].append(bf.reward)
            else:
                metrics["cheap_ce_refusal"].append(rep.refusal_rate)
                metrics["cheap_gap"].append(rep.reward - bf.reward)

    out = {k: {"mean": float(np.mean(v)), "std": float(np.std(v)),
               "values": [round(float(x), 4) for x in v]}
           for k, v in metrics.items()}
    save_artifact("seeds_ablation", out)
    for k, v in out.items():
        print(f"{k:22s} {v['mean']:+.4f} ± {v['std']:.4f}  {v['values']}")
    return {k: round(v["mean"], 4) for k, v in out.items()}


if __name__ == "__main__":
    print(main())
