"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle.

On CPU the numbers measure the reference path and interpret overhead —
the structural artifact (block shapes, VMEM footprint per tile) is the
TPU-relevant output.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_artifact
from repro.kernels import ref
from repro.kernels.ops import bm25_scores


def _time(fn, *args, iters=5):
    # one warmup invocation; jax.block_until_ready handles pytrees, so
    # no isinstance probe (which used to re-invoke the closure and skew
    # every reported number)
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def main() -> dict:
    out = {}
    # BM25 scoring at the paper testbed scale
    Q, D, V = 8, 640, 4096
    key = jax.random.PRNGKey(0)
    qtf = (jax.random.uniform(key, (Q, V)) < 0.003).astype(jnp.float32)
    tf = jnp.round(jax.random.uniform(key, (D, V)) * 3)
    dl = tf.sum(1)
    idf = jax.random.uniform(key, (V,)) + 0.1

    t_pallas = _time(lambda: bm25_scores(qtf, tf, dl, idf))
    k1, b = 1.2, 0.75
    norm = (k1 * (1 - b + b * dl / (dl.mean() + 1e-6)))[:, None]
    ref_fn = jax.jit(lambda: ref.bm25_ref(qtf * idf[None], tf, norm))
    t_ref = _time(ref_fn)
    out["bm25"] = {"us_pallas_interp": round(t_pallas, 1),
                   "us_jnp_ref": round(t_ref, 1),
                   "shape": f"Q{Q}xD{D}xV{V}",
                   "vmem_tile_bytes": (8 * 512 + 128 * 512 + 8 * 128) * 4}

    # flash attention tile accounting (structural)
    for (bq, bkv, d) in [(128, 128, 128), (256, 512, 128)]:
        vmem = (bq * d + 2 * bkv * d + bq * d + bq * 2) * 4
        out[f"flash_tile_{bq}x{bkv}"] = {
            "vmem_bytes_per_tile": vmem,
            "fits_16MB_vmem": vmem < 16 * 2**20}

    # flash decode: kernel (interpret) vs dense oracle at slot-cache shape
    from repro.kernels import flash_decode
    S, L, H, Hkv, D = 8, 512, 4, 4, 64
    q = jax.random.normal(key, (S, H, D))
    kc = jax.random.normal(key, (S, L, Hkv, D))
    vc = jax.random.normal(key, (S, L, Hkv, D))
    lens = (jnp.arange(S) * 61 % L + 1).astype(jnp.int32)
    t_fd = _time(lambda: flash_decode(q, kc, vc, lens))
    lens_f = jnp.repeat(lens, H)
    fd_ref = jax.jit(lambda: ref.flash_decode_ref(
        q.reshape(S * H, D),
        kc.transpose(0, 2, 1, 3).reshape(S * H, L, D),
        vc.transpose(0, 2, 1, 3).reshape(S * H, L, D), lens_f))
    t_fd_ref = _time(fd_ref)
    out["flash_decode"] = {
        "us_pallas_interp": round(t_fd, 1),
        "us_jnp_ref": round(t_fd_ref, 1),
        "shape": f"S{S}xL{L}xH{H}xD{D}",
        "vmem_tile_bytes": (D + 2 * 128 * D + D + 2) * 4}

    # paged flash decode: the same KV content laid out as a page pool +
    # block table, at several page sizes, vs the dense kernel above
    from repro.kernels import paged_flash_decode
    dense_out = flash_decode(q, kc, vc, lens)
    for ps in (16, 32, 64):
        MB = L // ps
        NPg = S * MB
        kp = kc.reshape(NPg, ps, Hkv, D)
        vp = vc.reshape(NPg, ps, Hkv, D)
        table = jnp.arange(NPg, dtype=jnp.int32).reshape(S, MB)
        t_paged = _time(lambda: paged_flash_decode(q, kp, vp, table, lens))
        paged_out = paged_flash_decode(q, kp, vp, table, lens)
        out[f"paged_flash_decode_ps{ps}"] = {
            "us_pallas_interp": round(t_paged, 1),
            "us_dense_pallas_interp": round(t_fd, 1),
            "page_size": ps, "num_pages": NPg,
            "shape": f"S{S}xL{L}xH{H}xD{D}",
            "matches_dense": bool(jnp.allclose(dense_out, paged_out,
                                               rtol=1e-5, atol=1e-5)),
            # per-tile VMEM: one query row + one K page + one V page +
            # accumulator + (m, l) running stats
            "vmem_tile_bytes": (D + 2 * ps * D + D + 2) * 4}

    save_artifact("kernels_bench", out)
    for k, v in out.items():
        print(k, v)
    return {"bm25_us": out["bm25"]["us_pallas_interp"]}


if __name__ == "__main__":
    print(main())
