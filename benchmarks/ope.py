"""Beyond-paper (§8 future work): counterfactual estimators (IPS / SNIPS /
Doubly-Robust) evaluated against the exactly-known policy value."""
import numpy as np

from benchmarks.common import canonical_results, save_artifact
from repro.core.actions import SLO_PROFILES
from repro.core.ope import estimator_suite
from repro.core.policy import policy_actions, train_policy


def main() -> dict:
    cfg, _, _, (train_log, eval_log) = canonical_results()
    profile = SLO_PROFILES["quality_first"]
    tr = train_policy(train_log, train_log.rewards(profile), cfg.router,
                      objective="argmax_ce")
    target = policy_actions(tr.params, eval_log.states, cfg.router)
    rewards = eval_log.rewards(profile)
    out = {}
    for kind in ("uniform", "eps_anchor"):
        out[kind] = estimator_suite(rewards, eval_log.states, target,
                                    kind=kind, seeds=30)
    save_artifact("ope", out)
    print(f"{'logging':>11s} {'estimator':>10s} {'value':>8s} {'bias':>8s} {'rmse':>8s}")
    for kind, suite in out.items():
        for est, stats in suite.items():
            print(f"{kind:>11s} {est:>10s} {stats['value']:+8.4f} "
                  f"{stats['bias']:+8.4f} {stats['rmse']:8.4f}")
    return {
        "snips_rmse_uniform": round(out["uniform"]["snips"]["rmse"], 4),
        "ips_rmse_uniform": round(out["uniform"]["ips"]["rmse"], 4),
        "dr_rmse_uniform": round(out["uniform"]["dr"]["rmse"], 4),
    }


if __name__ == "__main__":
    print(main())
