"""Paper Figure 2: average token cost vs accuracy per method."""
from benchmarks.common import canonical_results, save_artifact


def main() -> dict:
    _, res, _, _ = canonical_results()
    pts = [{"slo": r["slo"], "method": r["method"], "cost": r["cost"],
            "acc": r["acc"]} for r in res.rows]
    save_artifact("fig2_cost_quality", pts)
    print(f"{'slo':>14s} {'method':>16s} {'cost':>8s} {'acc':>6s}")
    for p in pts:
        print(f"{p['slo']:>14s} {p['method']:>16s} {p['cost']:8.1f} "
              f"{p['acc']:6.3f}")
    # derived: pareto check — learned quality policy should not be
    # dominated (higher cost AND lower acc) by the best fixed action
    rows = {(r["slo"], r["method"]): r for r in res.rows}
    ce = rows[("quality_first", "argmax_ce")]
    bf = [r for (s, m), r in rows.items()
          if s == "quality_first" and m.startswith("best-fixed")][0]
    dominated = ce["cost"] > bf["cost"] and ce["acc"] < bf["acc"]
    return {"quality_ce_cost": ce["cost"], "quality_ce_acc": ce["acc"],
            "dominated_by_best_fixed": dominated}


if __name__ == "__main__":
    print(main())
