"""Paper Figure 1: learned action distributions per SLO × objective."""
from benchmarks.common import bar, canonical_results, save_artifact

ACTION_LABELS = ["a0 k=2 guarded", "a1 k=5 guarded", "a2 k=10 guarded",
                 "a3 k=5 auto", "a4 refuse"]


def main() -> dict:
    _, res, extras, _ = canonical_results()
    dists = extras["action_dists"]
    save_artifact("fig1_action_dist", dists)
    for key, dist in dists.items():
        print(f"\n{key}")
        for lbl, p in zip(ACTION_LABELS, dist):
            print(f"  {lbl:16s} {p:5.3f} {bar(p)}")
    collapse = dists.get("cheap/argmax_ce", [0] * 5)[4]
    return {"cheap_ce_refuse_share": collapse,
            "quality_ce_a0_share": dists["quality_first/argmax_ce"][0]}


if __name__ == "__main__":
    print(main())
