"""Serving throughput: padded per-bucket Engine vs continuous engine.

Mixed-action synthetic workload (the paper testbed's questions, BM25
retrieval at each routed depth) with heterogeneous per-request
generation lengths — most answers are short, a tail is long, exactly
the EOS behaviour a real model produces — served two ways:

* **padded**: requests bucketed by action, each bucket one serial
  prefill+decode `Engine.generate` call (the pre-continuous Gateway
  execution model).  A bucket decodes until its LAST request finishes,
  so every short request burns wasted decode steps waiting for the
  bucket's longest, and a fresh KV cache is allocated per call.
* **continuous**: a bounded slot pool (`num_slots` << workload) in one
  `ContinuousEngine`; a request frees its slot the moment it finishes
  and the next queued request is admitted mid-stream, across action
  buckets, so the decode batch only ever does useful work.

Both paths produce the same useful tokens (each request's own length,
trimmed at its own EOS); tokens/s counts useful tokens only, so the
padded path's run-to-bucket-max waste shows up as time, not tokens.
Decode tokens/s is isolated by differencing a prefill-only run
(length 1) from the full run.  The prefill-only run admits in full
`prefill_batch` groups while the full run also admits smaller
mid-stream groups, so some extra prefill dispatch time is charged to
the continuous engine's decode — the isolation is conservative for the
continuous side.  Per-request latency is completion time since
workload start (padded requests inherit their bucket's serial position
and its longest member — head-of-line blocking the continuous engine
does not have).

A third engine variant, **continuous_sharded**, runs the same workload
through the slot-sharded ``ShardedExecutor`` on a 1-device mesh (the
mesh axis shows executor overhead, not parallel speedup, on this host)
— its decode tokens/s lands next to the single-device executor's in the
artifact.  Two forced-8-host-device probes (subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) check the
sharded path's token parity on the mixed-action workload and report
throughput: ``--mesh dp=8`` (slot data parallel) and the
**continuous_sharded_mp** engine row (``--mesh-mp dp=4,mp=2``: slots on
``data`` × params tensor-parallel on ``model``, with the model-axis
sharding of the params asserted on-device).  Host devices share the
same CPU, so the probes are correctness smokes, not speedup claims.

A fourth variant, **paged**, serves the same workload through the
paged KV executor (block-table pages + copy-on-write prefix sharing)
with a page pool deliberately sized BELOW the dense cache's byte
budget at equal ``max_len`` — the row records greedy token parity
against the dense oracle, prefix-hit rate, prefill-tokens-avoided,
decode tokens/s, the slots-per-GiB arithmetic, and a fixed-rate
open-loop latency row.  ``--quick`` runs just the paged-vs-dense
parity + prefix-hit smoke and merges the row into BENCH_serving.json
(the CI bench-smoke entry point).

Writes ``benchmarks/artifacts/BENCH_serving.json`` AND repo-root
``BENCH_serving.json`` (the perf-trajectory file).

    PYTHONPATH=src:. python benchmarks/serving_bench.py \
        [--mesh dp=8] [--mesh-mp dp=4,mp=2]
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time
from collections import defaultdict
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import save_artifact
from repro.configs import get_config
from repro.core.config import RetrievalConfig
from repro.data.synthetic_squad import SyntheticSquad
from repro.data.tokenizer import EOS, HashTokenizer
from repro.generation.prompts import build_prompt
from repro.models import build_model
from repro.retrieval.bm25 import BM25Index
from repro.routing.registry import get_action_space
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine
from repro.serving.slo_budget import LatencyReservoir

N_REQUESTS = 32
GATEWAY_BATCH = 16     # Gateway.step micro-batch (the old serving unit)
NUM_SLOTS = 4          # continuous slot pool (<< micro-batch: constant
                       # admission pressure keeps every row useful)
MAX_PROMPT = 48
MAX_NEW = 64
MAX_LEN = MAX_PROMPT + MAX_NEW
# per-request generation lengths: 3 short answers per long one — the
# heterogeneous-termination pattern continuous batching exists for
LENGTHS = (2, 4, 4, 64)
SYNC_EVERY = 4
REPEATS = 5            # best-of-N walls (the container CPU is noisy)
# paged engine: pool deliberately SMALLER than the dense cache at equal
# max_len (48*8 = 384 KV positions vs dense 4*112 = 448) — the bench
# demonstrates the same slot concurrency under a tighter memory budget
PAGE_SIZE = 8
PAGED_POOL_PAGES = 48


def build_workload():
    """(prompt_tokens, action_idx, gen_len) per request, mixed across
    the paper5 non-refuse actions (deep-k, shallow-k, auto)."""
    data = SyntheticSquad(n_paragraphs=120, n_questions=N_REQUESTS, seed=0)
    index = BM25Index.build([p.text for p in data.paragraphs],
                            RetrievalConfig(vocab_hash_dim=1024))
    space = get_action_space()
    gen_actions = [a for a in space if a.mode != "refuse"]
    tok = HashTokenizer(512)
    workload = []
    for i, q in enumerate(data.questions):
        action = gen_actions[i % len(gen_actions)]
        idx, _ = index.topk(q.text, action.k) if action.k else ([], None)
        passages = [index.texts[j] for j in idx]
        prompt = build_prompt(action.mode, q.text, passages)
        workload.append((tok.encode(prompt, bos=True, max_len=MAX_PROMPT),
                         action.idx, LENGTHS[(i // len(gen_actions))
                                             % len(LENGTHS)]))
    return workload


def _micro_batches(workload):
    for i in range(0, len(workload), GATEWAY_BATCH):
        yield workload[i:i + GATEWAY_BATCH]


def run_padded(engine, workload, prefill_only=False):
    """The old Gateway execution model: per micro-batch, requests are
    bucketed by routed action and every bucket is a serial
    prefill+decode `Engine.generate` call.  A bucket decodes to its
    LONGEST member's length; only each request's own `gen_len` tokens
    count as useful."""
    t0 = time.perf_counter()
    useful = 0
    lat = []
    for mb in _micro_batches(workload):
        buckets = defaultdict(list)
        for prompt, a, n in mb:
            buckets[a].append((prompt, 1 if prefill_only else n))
        for a in sorted(buckets):
            prompts = [p for p, _ in buckets[a]]
            lens = [n for _, n in buckets[a]]
            res = engine.generate(prompts, max_new_tokens=max(lens))
            for row, n in zip(res.tokens, lens):
                # credit only tokens up to the request's own budget AND
                # its own EOS — the bucket keeps decoding for its
                # longest member, but those are not useful tokens
                eos = np.nonzero(row == EOS)[0]
                own = eos[0] + 1 if eos.size else res.n_steps
                useful += int(min(n, own))
            done_at = (time.perf_counter() - t0) * 1e3
            lat += [done_at] * len(prompts)  # bucket completes together
    return useful, time.perf_counter() - t0, lat


def run_continuous(engine, workload, prefill_only=False):
    """The continuous Gateway model: each micro-batch's action buckets
    all feed the bounded slot pool of ONE engine; finished slots admit
    queued requests mid-stream.  (finished_at is the engine's
    perf_counter timestamp, so t0 shares that clock.)"""
    t0 = time.perf_counter()
    useful = 0
    lat = []
    for mb in _micro_batches(workload):
        rids = []
        for prompt, _, n in mb:
            rid = engine.reserve_rid()
            engine.submit(rid, prompt, 1 if prefill_only else n)
            rids.append(rid)
        done = engine.run()
        useful += sum(done[r].n_steps for r in rids)
        lat += [(done[r].finished_at - t0) * 1e3 for r in rids]
    return useful, time.perf_counter() - t0, lat


def _token_run(engine, workload):
    """One pass through a continuous engine, returning the trimmed
    greedy tokens per request (for dense-vs-paged parity)."""
    from repro.data.tokenizer import trim_at_eos as trim
    toks = []
    for mb in _micro_batches(workload):
        rids = []
        for prompt, _, n in mb:
            rid = engine.reserve_rid()
            engine.submit(rid, prompt, n)
            rids.append(rid)
        done = engine.run()
        toks += [trim(done[r].tokens) for r in rids]
    return toks


def _paged_extras(paged_eng, dense_eng, workload, mcfg) -> dict:
    """The paged engine row's correctness + memory fields: greedy token
    parity against the dense oracle (one fresh paired pass), cumulative
    prefix-sharing stats, and the slots-per-HBM arithmetic at equal
    ``max_len``.  Byte counts use the same ``kv_quant.cache_bytes``
    accounting the executors report, plus the paged path's block-table
    and position metadata."""
    from repro.serving.kv_quant import cache_bytes
    ex = paged_eng.executor
    parity = _token_run(dense_eng, workload) == _token_run(paged_eng,
                                                           workload)
    st = paged_eng.stats
    quant = bool(mcfg.kv_quant_int8)
    dense_b = mcfg.n_layers * cache_bytes(
        ex.num_slots, ex.max_len, mcfg.n_kv_heads, mcfg.head_dim, quant)
    pool_b = mcfg.n_layers * cache_bytes(
        ex.num_pages, ex.page_size, mcfg.n_kv_heads, mcfg.head_dim, quant)
    # block table (int32 per slot x block) + per-slot position register
    meta_b = ex.num_slots * ex.max_blocks * 4 + ex.num_slots * 4
    paged_b = pool_b + meta_b
    row = {
        "token_parity": bool(parity),
        "page_size": ex.page_size,
        "num_pages": ex.num_pages,
        "max_concurrent": st.max_concurrent,
        "prefix_hit_rate": round(st.prefill_tokens_avoided
                                 / max(st.prompt_tokens_total, 1), 4),
        "prefill_tokens_avoided": int(st.prefill_tokens_avoided),
        "prompt_tokens_total": int(st.prompt_tokens_total),
        "n_deferred_admissions": st.n_deferred_admissions,
        "n_pages_evicted": st.n_pages_evicted,
        "n_cow_forks": st.n_cow_forks,
        "kv_bytes_dense": dense_b,
        "kv_bytes_paged": paged_b,
        "slots_per_gib_dense": round(ex.num_slots * 2**30 / dense_b, 1),
        "slots_per_gib_paged": round(ex.num_slots * 2**30 / paged_b, 1),
    }
    assert parity, "paged engine diverged from dense greedy decode"
    return row


# --- open-loop serving: offered-load sweep, goodput under SLO ---------------

# offered rates (req/s of *virtual* time) swept against the smoke
# model: low -> comfortable, high -> over-offered so shedding engages
OPEN_LOOP_RATES = (25.0, 100.0, 400.0, 1600.0)
OPEN_LOOP_N = 96             # requests per rate (seeded Poisson trace)
OPEN_LOOP_DEADLINE_MS = 250.0
OPEN_LOOP_QUANTUM_S = 0.01   # virtual seconds charged per gateway pump


def run_open_loop(model, mcfg, params, rates=OPEN_LOOP_RATES,
                  engine_kw=None) -> dict:
    """Seeded Poisson traces through AsyncGateway over the continuous
    engine in VIRTUAL time: per offered rate, one goodput-under-SLO +
    p50/p99-latency row.  Deterministic — same seed, same rows — so the
    CI smoke job can assert on the artifact.  ``engine_kw`` flows into
    the backend's ContinuousEngine (e.g. ``paged=True``)."""
    import numpy as _np
    from repro.core.config import RetrievalConfig as _RC
    from repro.obs import MetricsRegistry, Tracer
    from repro.routing import FixedPolicy
    from repro.routing.engine_backend import ContinuousEngineBackend
    from repro.serving.streaming import AdmissionConfig, AsyncGateway
    from repro.serving.traffic import sweep_offered_load

    data = SyntheticSquad(n_paragraphs=120, n_questions=24, seed=0)
    index = BM25Index.build([p.text for p in data.paragraphs],
                            _RC(vocab_hash_dim=1024))

    def make_gateway(clock):
        backend = ContinuousEngineBackend.create(
            model, params, HashTokenizer(mcfg.vocab_size), index,
            num_slots=NUM_SLOTS, max_prompt_len=MAX_PROMPT,
            max_new_tokens=8, sync_every=SYNC_EVERY, clock=clock.now,
            **(engine_kw or {}))
        # telemetry plane on the same virtual clock: each row's
        # "stages" key is the trace-derived per-stage p50/p99 table
        return AsyncGateway(
            FixedPolicy(1), backend,
            state_fn=lambda qs: _np.zeros((len(qs), 1)),
            clock=clock.now, deadline_ms=OPEN_LOOP_DEADLINE_MS,
            admission=AdmissionConfig(max_backlog=3 * NUM_SLOTS),
            tracer=Tracer(clock.now), metrics=MetricsRegistry(clock.now))

    rows = sweep_offered_load(
        make_gateway, data.questions, list(rates),
        n_requests=OPEN_LOOP_N, deadline_ms=OPEN_LOOP_DEADLINE_MS,
        seed=0, service_quantum_s=OPEN_LOOP_QUANTUM_S)
    for r in rows:
        print(f"open-loop rate={r['rate']:7.1f}/s  "
              f"goodput={r['goodput']:7.2f}/s  shed={r['shed']:3d}  "
              f"p50={r['latency_p50_ms']}ms p99={r['latency_p99_ms']}ms")
    return {
        "deadline_ms": OPEN_LOOP_DEADLINE_MS, "n_per_rate": OPEN_LOOP_N,
        "num_slots": NUM_SLOTS, "arrival": "poisson(seed=0)",
        "service_quantum_s": OPEN_LOOP_QUANTUM_S,
        "rows": rows,
        # trace-derived per-stage latency at the comfortable operating
        # point (stage -> {n, p50_ms, p99_ms} of virtual time)
        "stage_breakdown": rows[min(1, len(rows) - 1)].get("stages", {}),
        # headline: shedding engages under over-offered load
        "shed_at_max_rate": rows[-1]["shed"],
        "shed_at_min_rate": rows[0]["shed"],
    }


def tracer_overhead_row(repeats: int = 7, n_requests: int = 400) -> dict:
    """Hot-path cost of the telemetry plane: the same seeded open-loop
    replay through the host-only simulator backend, once with a live
    Tracer + MetricsRegistry attached and once with the no-op defaults,
    best-of-N REAL wall each.  Virtual time pins the schedule (same
    pumps, same admissions, token-identical outcomes), so the wall
    difference is pure instrumentation cost — asserted within 5%."""
    from repro.core.config import RetrievalConfig as _RC
    from repro.generation.simulator import SimulatedGenerator
    from repro.obs import MetricsRegistry, Tracer
    from repro.routing import FixedPolicy
    from repro.routing.backends import SimulatorBackend
    from repro.serving.pipeline import RAGPipeline
    from repro.serving.streaming import AdmissionConfig, AsyncGateway
    from repro.serving.traffic import (LoadGenerator, PoissonProcess,
                                       VirtualClock, build_trace)

    data = SyntheticSquad(n_paragraphs=120, n_questions=24, seed=0)
    index = BM25Index.build([p.text for p in data.paragraphs],
                            _RC(vocab_hash_dim=1024))
    tok = HashTokenizer(512)

    def one_run(traced: bool) -> float:
        clock = VirtualClock()
        pipe = RAGPipeline(index, SimulatedGenerator(tok))
        backend = SimulatorBackend(pipe, stream_slots=NUM_SLOTS,
                                   service_polls=2, clock=clock.now)
        kw = ({"tracer": Tracer(clock.now),
               "metrics": MetricsRegistry(clock.now)} if traced else {})
        gw = AsyncGateway(
            FixedPolicy(1), backend,
            state_fn=lambda qs: np.zeros((len(qs), 1)),
            clock=clock.now, deadline_ms=OPEN_LOOP_DEADLINE_MS,
            admission=AdmissionConfig(max_backlog=3 * NUM_SLOTS), **kw)
        trace = build_trace(data.questions, PoissonProcess(200.0, seed=0),
                            n_requests, deadline_ms=OPEN_LOOP_DEADLINE_MS)
        t0 = time.perf_counter()
        LoadGenerator(gw, trace).run_virtual(
            clock, service_quantum_s=OPEN_LOOP_QUANTUM_S)
        return time.perf_counter() - t0

    one_run(False)
    one_run(True)                                   # warmup both paths
    # interleave so both paths sample the same noise windows (shared-
    # container CPU), best-of-N each
    base, traced = 9e9, 9e9
    for _ in range(repeats):
        base = min(base, one_run(False))
        traced = min(traced, one_run(True))
    pct = round((traced - base) / base * 100.0, 2)
    row = {"base_wall_s": round(base, 4),
           "traced_wall_s": round(traced, 4),
           "tracer_overhead_pct": pct,
           "repeats": repeats, "n_requests": n_requests}
    print(f"tracer overhead: {pct}% "
          f"(base {base:.4f}s vs traced {traced:.4f}s, best of {repeats})")
    assert pct <= 5.0, f"tracer hot-path overhead {pct}% exceeds 5%"
    return row


def _one_device_mesh():
    """A 1-device ("data","model") mesh regardless of host flags."""
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


def _sharded_probe(mesh_spec: str) -> dict:
    """Re-exec this benchmark in a subprocess with dp*mp forced host
    devices: token parity (single-device vs sharded executor) on the
    mixed-action workload, plus the sharded decode throughput (and,
    with mp>1, an on-device check that params shard on the model
    axis)."""
    parts = dict(kv.split("=") for kv in mesh_spec.split(","))
    ndev = int(parts.get("dp", 1)) * int(parts.get("mp", 1))
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
               PYTHONPATH=f"{root / 'src'}:{root}")
    res = subprocess.run(
        [sys.executable, __file__, "--probe", mesh_spec],
        env=env, capture_output=True, text=True, timeout=1200)
    for line in res.stdout.splitlines():
        if line.startswith("PROBE_JSON:"):
            return json.loads(line[len("PROBE_JSON:"):])
    return {"mesh": mesh_spec, "error": (res.stderr or res.stdout)[-800:]}


def probe_main(mesh_spec: str) -> None:
    """Subprocess body (XLA_FLAGS already set before jax imported)."""
    from repro.data.tokenizer import trim_at_eos as trim
    from repro.launch.mesh import make_serving_mesh
    from repro.sharding import mesh_axis_sizes, model_axis_fallbacks

    mcfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                               dtype="float32")
    mesh = make_serving_mesh(mesh_spec, model_cfg=mcfg)
    ndev = len(jax.devices())
    mp = mesh_axis_sizes(mesh)["model"]
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = build_workload()[:2 * ndev]
    slots = ndev

    outs = {}
    for name, mesh_arg in (("single", None), ("sharded", mesh)):
        eng = ContinuousEngine(model, params, num_slots=slots,
                               max_len=MAX_LEN, max_new_cap=MAX_NEW,
                               sync_every=SYNC_EVERY, prefill_batch=slots,
                               mesh=mesh_arg)
        tokens = []
        walls = []
        for trial in range(2):            # trial 0 = compile warmup
            rids = []
            t0 = time.perf_counter()
            for prompt, _, n in workload:
                rid = eng.reserve_rid()
                eng.submit(rid, prompt, n)
                rids.append(rid)
            done = eng.run()
            walls.append(time.perf_counter() - t0)
            tokens = [trim(done[r].tokens) for r in rids]
        if mesh_arg is not None and mp > 1:
            # params must be PARTITIONED on the model axis, not
            # replicated per device (the mp>1 silent-replication bug):
            # on-device shard-shape check on one tensor, resolver audit
            # over the whole schema
            wq = eng.executor.params["blocks"]["p0"]["attn"]["wq"]
            shapes = {s.data.shape for s in wq.addressable_shards}
            want_heads = mcfg.n_heads // mp
            assert all(sh[-2] == want_heads for sh in shapes), (
                mesh_spec, shapes)
            _, fallbacks = model_axis_fallbacks(model.schema, mesh)
            assert not fallbacks, fallbacks
        outs[name] = {"tokens": tokens, "wall_s": walls[-1],
                      "useful": sum(len(t) for t in tokens),
                      "allocations": eng.stats.cache_allocations}
    parity = outs["single"]["tokens"] == outs["sharded"]["tokens"]
    # measured, not assumed: true only when mp>1 AND the asserts above
    # confirmed every model-capable leaf actually partitioned
    report = {
        "mesh": mesh_spec, "devices": ndev, "n_requests": len(workload),
        "num_slots": slots, "token_parity": bool(parity),
        "params_model_sharded": mp > 1,
        "cache_allocations": outs["sharded"]["allocations"],
        "sharded_tokens_per_s": round(
            outs["sharded"]["useful"] / outs["sharded"]["wall_s"], 1),
        "single_tokens_per_s": round(
            outs["single"]["useful"] / outs["single"]["wall_s"], 1),
    }
    assert parity, "sharded executor diverged from single-device greedy"
    print("PROBE_JSON:" + json.dumps(report))


def main(mesh_probe: str = "dp=8", mp_probe: str = "dp=4,mp=2") -> dict:
    mcfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                               dtype="float32")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = build_workload()

    out = {"n_requests": N_REQUESTS, "num_slots": NUM_SLOTS,
           "gen_lengths": list(LENGTHS), "max_prompt_len": MAX_PROMPT,
           "model": mcfg.name, "n_buckets": len({a for _, a, _ in workload}),
           "useful_tokens": sum(n for _, _, n in workload)}
    # ONE engine instance per execution model, reused across all trials
    # — jit caches are per instance, so fresh engines would put seconds
    # of retrace/compile inside every timed window
    engines = {
        "padded": Engine(model, params, max_len=MAX_LEN),
        "continuous": ContinuousEngine(
            model, params, num_slots=NUM_SLOTS, max_len=MAX_LEN,
            max_new_cap=MAX_NEW, sync_every=SYNC_EVERY,
            prefill_batch=NUM_SLOTS),
        "continuous_sharded": ContinuousEngine(
            model, params, num_slots=NUM_SLOTS, max_len=MAX_LEN,
            max_new_cap=MAX_NEW, sync_every=SYNC_EVERY,
            prefill_batch=NUM_SLOTS, mesh=_one_device_mesh()),
        "paged": ContinuousEngine(
            model, params, num_slots=NUM_SLOTS, max_len=MAX_LEN,
            max_new_cap=MAX_NEW, sync_every=SYNC_EVERY,
            prefill_batch=NUM_SLOTS, paged=True, page_size=PAGE_SIZE,
            num_pages=PAGED_POOL_PAGES),
    }
    runners = (("padded", run_padded), ("continuous", run_continuous),
               ("continuous_sharded", run_continuous),
               ("paged", run_continuous))
    best = {}
    for name, runner in runners:
        runner(engines[name], workload)                # warmup (compile)
        runner(engines[name], workload, prefill_only=True)
        best[name] = {"decode_t": 9e9, "decode_tok": 0, "full": (0, 9e9, [])}
    # interleave trials so both engines sample the same noise windows
    # (shared-container CPU); the prefill-only and full runs of a trial
    # are paired back-to-back so their difference correlates the noise
    for _ in range(REPEATS):
        for name, runner in runners:
            tok_pre, t_pre, _ = runner(engines[name], workload,
                                       prefill_only=True)
            full = runner(engines[name], workload)
            # a trial whose full wall lands under its prefill-only wall
            # is noise (possible when prefill is nearly free, e.g. the
            # paged engine cache-hot) — skip it rather than divide by a
            # clamped epsilon
            d_t = full[1] - t_pre
            if 0 < d_t < best[name]["decode_t"]:
                best[name]["decode_t"] = d_t
                best[name]["decode_tok"] = full[0] - tok_pre
            if full[1] < best[name]["full"][1]:
                best[name]["full"] = full
    for name, _runner in runners:
        tok_full, t_full, lat = best[name]["full"]
        decode_tok = best[name]["decode_tok"]
        decode_t = best[name]["decode_t"]
        if decode_t >= 9e9 or decode_tok <= 0:
            # no trial isolated cleanly: report the end-to-end rate
            # (prefill charged to decode — a conservative lower bound)
            decode_tok, decode_t = tok_full, t_full
        # the one shared home for serving percentiles (p50/p95/p99) —
        # no more ad-hoc np.percentile math per bench
        res = LatencyReservoir()
        res.extend(lat)
        pct = res.percentiles()
        out[name] = {
            "tokens": tok_full,
            "wall_s": round(t_full, 4),
            "tokens_per_s": round(tok_full / t_full, 1),
            "decode_tokens_per_s": round(decode_tok / decode_t, 1),
            "latency_ms_mean": pct["mean_ms"],
            "latency_ms_p50": pct["p50_ms"],
            "latency_ms_p95": pct["p95_ms"],
            "latency_ms_p99": pct["p99_ms"],
            "latency_ms_max": pct["max_ms"],
        }
        print(name, out[name])

    # paged row: token parity vs the dense oracle + prefix-sharing and
    # memory-budget fields (the timing loops above left the paged
    # engine's page pool cache-hot, so the hit rate reflects the
    # repeated-passage workload, not a cold start)
    out["paged"].update(_paged_extras(engines["paged"],
                                      engines["continuous"],
                                      workload, mcfg))
    print("paged extras:", {k: out["paged"][k] for k in
                            ("token_parity", "prefix_hit_rate",
                             "prefill_tokens_avoided",
                             "slots_per_gib_dense",
                             "slots_per_gib_paged")})
    out["decode_speedup"] = round(
        out["continuous"]["decode_tokens_per_s"]
        / out["padded"]["decode_tokens_per_s"], 2)
    out["e2e_speedup"] = round(
        out["continuous"]["tokens_per_s"]
        / out["padded"]["tokens_per_s"], 2)
    out["latency_mean_speedup"] = round(
        out["padded"]["latency_ms_mean"]
        / out["continuous"]["latency_ms_mean"], 2)
    # sharded-on-1-device-mesh vs single-device executor: the mesh
    # machinery (NamedSharding layouts, out_shardings jits) must not
    # regress decode throughput
    out["sharded_1dev_decode_ratio"] = round(
        out["continuous_sharded"]["decode_tokens_per_s"]
        / out["continuous"]["decode_tokens_per_s"], 2)
    print(f"decode speedup: {out['decode_speedup']}x; "
          f"end-to-end: {out['e2e_speedup']}x; "
          f"mean latency: {out['latency_mean_speedup']}x lower; "
          f"sharded/single decode on 1-dev mesh: "
          f"{out['sharded_1dev_decode_ratio']}x")
    if mesh_probe:
        print(f"# forced-device sharded probe ({mesh_probe}) ...")
        out["sharded_probe"] = _sharded_probe(mesh_probe)
        print("probe:", out["sharded_probe"])
    if mp_probe:
        # the dp×mp tensor-parallel engine row: greedy parity + params
        # verifiably partitioned on the model axis (forced 8 devices)
        print(f"# forced-device tensor-parallel probe ({mp_probe}) ...")
        out["continuous_sharded_mp"] = _sharded_probe(mp_probe)
        print("probe:", out["continuous_sharded_mp"])
    print("# open-loop offered-load sweep ...")
    out["open_loop"] = run_open_loop(model, mcfg, params)
    # the paged engine's open-loop latency at one fixed mid-sweep rate
    # (same seeded trace as the dense sweep's second operating point)
    print("# open-loop fixed-rate paged row ...")
    paged_ol = run_open_loop(
        model, mcfg, params, rates=(OPEN_LOOP_RATES[1],),
        engine_kw={"paged": True, "page_size": PAGE_SIZE})
    out["paged"]["open_loop"] = paged_ol["rows"][0]
    print("# tracer hot-path overhead ...")
    out["tracer_overhead"] = tracer_overhead_row()
    save_artifact("BENCH_serving", out)
    # the repo-root copy is the perf-trajectory entry point
    (Path(__file__).resolve().parents[1] / "BENCH_serving.json").write_text(
        json.dumps(out, indent=1))
    return {"decode_speedup": out["decode_speedup"],
            "sharded_1dev_decode_ratio": out["sharded_1dev_decode_ratio"]}


def quick_main() -> dict:
    """CI paged smoke: dense-vs-paged greedy parity plus prefix-sharing
    stats on the mixed-action workload, no timing repeats or probes.
    Two passes through the same paged engine so the second is
    cache-hot; merges the ``paged`` row into BENCH_serving.json,
    preserving whatever a full run already wrote (the
    ``open_loop_main`` merge pattern)."""
    mcfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                               dtype="float32")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = build_workload()[:GATEWAY_BATCH]
    dense = ContinuousEngine(
        model, params, num_slots=NUM_SLOTS, max_len=MAX_LEN,
        max_new_cap=MAX_NEW, sync_every=SYNC_EVERY,
        prefill_batch=NUM_SLOTS)
    paged = ContinuousEngine(
        model, params, num_slots=NUM_SLOTS, max_len=MAX_LEN,
        max_new_cap=MAX_NEW, sync_every=SYNC_EVERY,
        prefill_batch=NUM_SLOTS, paged=True, page_size=PAGE_SIZE,
        num_pages=PAGED_POOL_PAGES)
    _paged_extras(paged, dense, workload, mcfg)        # pass 1: cold
    row = _paged_extras(paged, dense, workload, mcfg)  # pass 2: hot
    print("paged-quick:", row)
    assert row["prefix_hit_rate"] > 0, row
    root = Path(__file__).resolve().parents[1]
    out = {}
    target = root / "BENCH_serving.json"
    if target.exists():
        out = json.loads(target.read_text())
    merged = out.get("paged", {})
    merged.update(row)
    out["paged"] = merged
    save_artifact("BENCH_serving", out)
    target.write_text(json.dumps(out, indent=1))
    return row


def open_loop_main() -> dict:
    """Just the open-loop sweep (the CI traffic-harness smoke): merge
    the ``open_loop`` key into BENCH_serving.json, preserving whatever
    engine rows a full run already wrote."""
    mcfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                               dtype="float32")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    open_loop = run_open_loop(model, mcfg, params)
    overhead = tracer_overhead_row(repeats=3)
    root = Path(__file__).resolve().parents[1]
    out = {}
    target = root / "BENCH_serving.json"
    if target.exists():
        out = json.loads(target.read_text())
    out["open_loop"] = open_loop
    out["tracer_overhead"] = overhead
    save_artifact("BENCH_serving", out)
    target.write_text(json.dumps(out, indent=1))
    return open_loop


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="dp=8", metavar="dp=N",
                    help="forced-host-device count for the sharded probe "
                         "(empty string skips the probe)")
    ap.add_argument("--mesh-mp", default="dp=4,mp=2", metavar="dp=N,mp=M",
                    help="dp×mp tensor-parallel probe — writes the "
                         "continuous_sharded_mp engine row (empty string "
                         "skips it)")
    ap.add_argument("--open-loop-only", action="store_true",
                    help="run only the open-loop offered-load sweep and "
                         "merge it into BENCH_serving.json (CI smoke)")
    ap.add_argument("--quick", action="store_true",
                    help="paged-vs-dense parity + prefix-hit smoke only; "
                         "merges the paged row into BENCH_serving.json "
                         "(CI bench-smoke)")
    ap.add_argument("--probe", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.probe:
        probe_main(args.probe)
    elif args.open_loop_only:
        open_loop_main()
    elif args.quick:
        quick_main()
    else:
        print(main(mesh_probe=args.mesh, mp_probe=args.mesh_mp))
