"""Paper Figure 3: average reward, best-fixed vs learned, per condition."""
from benchmarks.common import bar, canonical_results, save_artifact


def main() -> dict:
    _, res, _, _ = canonical_results()
    rows = {(r["slo"], r["method"]): r for r in res.rows}
    out = {}
    for slo in ("quality_first", "cheap"):
        for (s, m), r in rows.items():
            if s != slo:
                continue
            out[f"{slo}/{m}"] = r["reward"]
    save_artifact("fig3_reward", out)
    lo = min(out.values())
    for k, v in out.items():
        print(f"{k:40s} {v:+8.4f} {bar(v - lo, 40)}")
    return {"max_reward": max(out.values()),
            "argmax": max(out, key=out.get)}


if __name__ == "__main__":
    print(main())
