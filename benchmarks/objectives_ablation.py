"""The paper's namesake: objective ablation.

All four policy objectives (Argmax-CE, Argmax-CE-WT, reward-softmax
soft targets, constrained CE) under both SLO profiles on the canonical
testbed — the full grid behind the paper's "objective choice strongly
shapes learned behavior" conclusion.

Beyond the paper, the same grid runs over the ``hybrid9`` action space
(retriever ∈ {bm25, dense, hybrid} × depth × guarded/auto + refuse):
the paper's failure-mode convention — report the cheap-profile refusal
collapse and whether the constrained objective mitigates it — now with
retriever choice in the action set (the Lagrangian watches hybrid9's
refuse index 8 via the log's ``refuse_action``).
"""
from benchmarks.common import (canonical_hybrid9_logs, canonical_results,
                               save_artifact)
from repro.core.metrics import best_fixed_action, evaluate_actions
from repro.routing import MLPPolicy
# live registry view, iterated in registration order so artifact rows
# keep the seed ordering (quality_first before cheap)
from repro.routing.registry import SLO_PROFILES, SPACE_DEFAULT_PROFILES

OBJECTIVES = ("argmax_ce", "argmax_ce_wt", "soft_reward", "constrained")


def _grid(space_name, router_cfg, train_log, eval_log, profiles):
    """One space's {profile × objective} grid -> artifact rows."""
    rows = []
    for slo, profile in profiles:
        rewards = train_log.rewards(profile)
        _, bf = best_fixed_action(eval_log, profile)
        rows.append({"space": space_name, "slo": slo, **bf.row()})
        for obj in OBJECTIVES:
            policy = MLPPolicy.train(train_log, rewards, router_cfg,
                                     objective=obj, refusal_cap=0.45)
            rep = evaluate_actions(eval_log, policy.actions(eval_log.states),
                                   profile, obj)
            rows.append({"space": space_name, "slo": slo, **rep.row()})
    return rows


def main(spaces=("paper5", "hybrid9")) -> dict:
    rows = []
    if "paper5" in spaces:
        cfg, _, _, (train_log, eval_log) = canonical_results()
        rows += _grid("paper5", cfg.router, train_log, eval_log,
                      list(SLO_PROFILES.items()))
    if "hybrid9" in spaces:
        hcfg, hspace, (h_train, h_eval) = canonical_hybrid9_logs()
        profiles = [(name, SLO_PROFILES[name])
                    for name in SPACE_DEFAULT_PROFILES["hybrid9"]]
        rows += _grid("hybrid9", hcfg.router, h_train, h_eval, profiles)
    save_artifact("objectives_ablation", rows)
    print(f"{'space':>8s} {'slo':>14s} {'objective':>16s} {'acc':>6s} "
          f"{'cost':>8s} {'reward':>8s} {'refuse':>7s}")
    for r in rows:
        print(f"{r['space']:>8s} {r['slo']:>14s} {r['method']:>16s} "
              f"{r['acc']:6.3f} {r['cost']:8.1f} {r['reward']:+8.4f} "
              f"{r['refuse']:7.3f}")
    by = {(r["space"], r["slo"], r["method"]): r for r in rows}
    out = {}
    if "paper5" in spaces:
        out.update({
            "cheap_soft_reward_refusal":
                by[("paper5", "cheap", "soft_reward")]["refuse"],
            "cheap_constrained_refusal":
                by[("paper5", "cheap", "constrained")]["refuse"],
            "quality_best_objective": max(
                (r for r in rows
                 if r["space"] == "paper5" and r["slo"] == "quality_first"),
                key=lambda r: r["reward"])["method"],
        })
    if "hybrid9" in spaces:
        # the paper's failure-mode check, now with retriever choice in
        # the action set: does cheap still collapse to refusal, and
        # does the constrained objective pull it back?
        out.update({
            "hybrid9_cheap_argmax_ce_refusal":
                by[("hybrid9", "cheap", "argmax_ce")]["refuse"],
            "hybrid9_cheap_constrained_refusal":
                by[("hybrid9", "cheap", "constrained")]["refuse"],
            "hybrid9_quality_best_objective": max(
                (r for r in rows
                 if r["space"] == "hybrid9" and r["slo"] == "quality_first"),
                key=lambda r: r["reward"])["method"],
        })
    return out


if __name__ == "__main__":
    print(main())
