"""The paper's namesake: objective ablation.

All four policy objectives (Argmax-CE, Argmax-CE-WT, reward-softmax
soft targets, constrained CE) under both SLO profiles on the canonical
testbed — the full grid behind the paper's "objective choice strongly
shapes learned behavior" conclusion.
"""
from benchmarks.common import canonical_results, save_artifact
from repro.core.metrics import best_fixed_action, evaluate_actions
from repro.routing import MLPPolicy
# live registry view, iterated in registration order so artifact rows
# keep the seed ordering (quality_first before cheap)
from repro.routing.registry import SLO_PROFILES

OBJECTIVES = ("argmax_ce", "argmax_ce_wt", "soft_reward", "constrained")


def main() -> dict:
    cfg, _, _, (train_log, eval_log) = canonical_results()
    rows = []
    for slo, profile in SLO_PROFILES.items():
        rewards = train_log.rewards(profile)
        _, bf = best_fixed_action(eval_log, profile)
        rows.append({"slo": slo, **bf.row()})
        for obj in OBJECTIVES:
            policy = MLPPolicy.train(train_log, rewards, cfg.router,
                                     objective=obj, refusal_cap=0.45)
            rep = evaluate_actions(eval_log, policy.actions(eval_log.states),
                                   profile, obj)
            rows.append({"slo": slo, **rep.row()})
    save_artifact("objectives_ablation", rows)
    print(f"{'slo':>14s} {'objective':>16s} {'acc':>6s} {'cost':>8s} "
          f"{'reward':>8s} {'refuse':>7s}")
    for r in rows:
        print(f"{r['slo']:>14s} {r['method']:>16s} {r['acc']:6.3f} "
              f"{r['cost']:8.1f} {r['reward']:+8.4f} {r['refuse']:7.3f}")
    by = {(r["slo"], r["method"]): r for r in rows}
    return {
        "cheap_soft_reward_refusal": by[("cheap", "soft_reward")]["refuse"],
        "cheap_constrained_refusal": by[("cheap", "constrained")]["refuse"],
        "quality_best_objective": max(
            (r for r in rows if r["slo"] == "quality_first"),
            key=lambda r: r["reward"])["method"],
    }


if __name__ == "__main__":
    print(main())
