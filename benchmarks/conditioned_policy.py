"""Beyond paper: one SLO-conditioned policy vs per-profile policies,
including generalization to an UNSEEN interpolated profile."""
import numpy as np

from benchmarks.common import canonical_results, save_artifact
from repro.core.conditioned import interpolate
from repro.core.metrics import best_fixed_action, evaluate_actions
from repro.routing import (ConditionedPolicy, MLPPolicy, get_slo_profile,
                           list_slo_profiles)


def main() -> dict:
    cfg, _, _, (train_log, eval_log) = canonical_results()
    profiles = [get_slo_profile("quality_first"), get_slo_profile("cheap")]
    cond = ConditionedPolicy.train(train_log, profiles, cfg.router)

    rows = []
    for p in profiles + [interpolate(profiles[0], profiles[1], 0.5)]:
        acts_c = cond.route(eval_log.states, p).actions
        rep_c = evaluate_actions(eval_log, acts_c, p, f"conditioned@{p.name}")
        rows.append(rep_c.row())
        # per-profile specialist for comparison (seen profiles only)
        if p.name in list_slo_profiles():
            spec = MLPPolicy.train(train_log, train_log.rewards(p),
                                   cfg.router, objective="argmax_ce")
            rows.append(evaluate_actions(eval_log,
                                         spec.actions(eval_log.states), p,
                                         f"specialist@{p.name}").row())
        _, bf = best_fixed_action(eval_log, p)
        rows.append({**bf.row(), "method": f"best-fixed@{p.name}"})

    save_artifact("conditioned_policy", rows)
    for r in rows:
        print(f"{r['method']:38s} reward={r['reward']:+8.4f} "
              f"acc={r['acc']:.3f} cost={r['cost']:7.1f} "
              f"refuse={r['refuse']:.2f}")
    cond = {r["method"]: r for r in rows}
    gap_q = (cond["conditioned@quality_first"]["reward"]
             - cond["specialist@quality_first"]["reward"])
    return {"conditioned_vs_specialist_quality_gap": round(gap_q, 4),
            "unseen_mix_reward":
                cond[[k for k in cond if k.startswith("conditioned@mix")][0]]
                ["reward"]}


if __name__ == "__main__":
    print(main())
