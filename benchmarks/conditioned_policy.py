"""Beyond paper: one SLO-conditioned policy vs per-profile policies,
including generalization to an UNSEEN interpolated profile."""
import numpy as np

from benchmarks.common import canonical_results, save_artifact
from repro.core.actions import SLO_PROFILES
from repro.core.conditioned import (conditioned_actions, interpolate,
                                    train_conditioned)
from repro.core.metrics import best_fixed_action, evaluate_actions
from repro.core.policy import policy_actions, train_policy


def main() -> dict:
    cfg, _, _, (train_log, eval_log) = canonical_results()
    profiles = [SLO_PROFILES["quality_first"], SLO_PROFILES["cheap"]]
    result, ccfg = train_conditioned(train_log, profiles, cfg.router)

    rows = []
    for p in profiles + [interpolate(profiles[0], profiles[1], 0.5)]:
        acts_c = conditioned_actions(result, ccfg, eval_log, p)
        rep_c = evaluate_actions(eval_log, acts_c, p, f"conditioned@{p.name}")
        rows.append(rep_c.row())
        # per-profile specialist for comparison (seen profiles only)
        if p.name in SLO_PROFILES:
            tr = train_policy(train_log, train_log.rewards(p), cfg.router,
                              objective="argmax_ce")
            acts_s = policy_actions(tr.params, eval_log.states, cfg.router)
            rows.append(evaluate_actions(eval_log, acts_s, p,
                                         f"specialist@{p.name}").row())
        _, bf = best_fixed_action(eval_log, p)
        rows.append({**bf.row(), "method": f"best-fixed@{p.name}"})

    save_artifact("conditioned_policy", rows)
    for r in rows:
        print(f"{r['method']:38s} reward={r['reward']:+8.4f} "
              f"acc={r['acc']:.3f} cost={r['cost']:7.1f} "
              f"refuse={r['refuse']:.2f}")
    cond = {r["method"]: r for r in rows}
    gap_q = (cond["conditioned@quality_first"]["reward"]
             - cond["specialist@quality_first"]["reward"])
    return {"conditioned_vs_specialist_quality_gap": round(gap_q, 4),
            "unseen_mix_reward":
                cond[[k for k in cond if k.startswith("conditioned@mix")][0]]
                ["reward"]}


if __name__ == "__main__":
    print(main())
