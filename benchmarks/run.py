"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark) after
each benchmark's own report.  Artifacts land in benchmarks/artifacts/.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table1 ope # a subset
"""
from __future__ import annotations

import json
import sys
import time

from benchmarks import (chaos_bench, conditioned_policy, fig1_action_dist,
                        fig2_cost_quality, fig3_reward, kernels_bench,
                        mitigation, objectives_ablation, ope, pareto_sweep,
                        perf_variants, retrieval_bench, roofline,
                        seeds_ablation, serving_bench, table1_slo_grid)

BENCHMARKS = {
    "table1": table1_slo_grid.main,     # paper Table 1
    "fig1": fig1_action_dist.main,      # paper Figure 1
    "fig2": fig2_cost_quality.main,     # paper Figure 2
    "fig3": fig3_reward.main,           # paper Figure 3
    "mitigation": mitigation.main,      # paper §7.1 mitigation
    "objectives": objectives_ablation.main,  # paper's objective ablation
    "ope": ope.main,                    # beyond paper (§8 future work)
    "conditioned": conditioned_policy.main,  # beyond paper
    "pareto": pareto_sweep.main,        # beyond paper: collapse onset
    "seeds": seeds_ablation.main,       # beyond paper: §8 uncertainty
    "kernels": kernels_bench.main,      # kernel micro-bench
    "serving": serving_bench.main,      # padded vs continuous vs sharded
                                        # engines (writes BENCH_serving.json
                                        # at repo root + artifacts/)
    "retrieval": retrieval_bench.main,  # bm25 vs dense vs hybrid vs sharded
                                        # + hit@k + hybrid9 collapse check
                                        # (writes BENCH_retrieval.json)
    "chaos": chaos_bench.main,          # goodput under injected faults
                                        # (writes BENCH_chaos.json)
    "roofline": roofline.main,          # §Roofline table
    "perf": perf_variants.main,         # §Perf before/after from records
}


def main() -> None:
    names = [a for a in sys.argv[1:] if a in BENCHMARKS] or list(BENCHMARKS)
    rows = []
    for name in names:
        print(f"\n===== {name} =====")
        t0 = time.time()
        derived = BENCHMARKS[name]()
        us = (time.time() - t0) * 1e6
        rows.append((name, us, derived))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{json.dumps(derived)}")


if __name__ == "__main__":
    main()
