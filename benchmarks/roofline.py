"""§Roofline: three-term roofline per (arch × shape) from the dry-run."""
from benchmarks.common import save_artifact
from repro.analysis.roofline import full_table


def main() -> dict:
    rows = full_table("single")
    out = [r.as_dict() for r in rows]
    save_artifact("roofline", out)
    multi = full_table("multi")
    if multi:
        save_artifact("roofline_multi", [r.as_dict() for r in multi])
    hdr = (f"{'arch':>22s} {'shape':>12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>10s} {'dominant':>10s} {'useful':>7s}")
    print(hdr)
    for r in rows:
        print(f"{r.arch:>22s} {r.shape:>12s} {r.compute_s:10.4f} "
              f"{r.memory_s:10.4f} {r.collective_s:10.4f} {r.dominant:>10s} "
              f"{r.useful_ratio:7.3f}")
    doms = {}
    for r in rows:
        doms[r.dominant] = doms.get(r.dominant, 0) + 1
    return {"n_pairs": len(rows), "dominant_histogram": doms}


if __name__ == "__main__":
    print(main())
