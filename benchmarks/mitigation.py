"""Paper §7.1 mitigation: Lagrangian refusal cap vs vanilla Argmax-CE
under the cheap SLO — the practical fix for refusal collapse."""
from benchmarks.common import canonical_results, save_artifact


def main() -> dict:
    _, res, extras, _ = canonical_results()
    rows = {(r["slo"], r["method"]): r for r in res.rows}
    ce = rows[("cheap", "argmax_ce")]
    con = rows.get(("cheap", "constrained"))
    assert con is not None, "constrained objective missing from experiment"
    out = {
        "cheap_argmax_ce": {k: ce[k] for k in
                            ("acc", "cost", "reward", "refuse")},
        "cheap_constrained": {k: con[k] for k in
                              ("acc", "cost", "reward", "refuse")},
        "lagrange_final": extras["train_hist"]
        .get("cheap/constrained", {}).get("lambda"),
    }
    save_artifact("mitigation", out)
    print(f"{'method':>22s} {'acc':>6s} {'cost':>8s} {'reward':>8s} {'refuse':>7s}")
    for name, r in (("argmax_ce (collapsed)", ce), ("constrained", con)):
        print(f"{name:>22s} {r['acc']:6.3f} {r['cost']:8.1f} "
              f"{r['reward']:+8.4f} {r['refuse']:7.3f}")
    return {"refusal_reduction": round(ce["refuse"] - con["refuse"], 3),
            "acc_recovered": round(con["acc"] - ce["acc"], 3)}


if __name__ == "__main__":
    print(main())
