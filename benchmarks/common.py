"""Shared testbed/policy cache so each table reuses one sweep."""
from __future__ import annotations

import dataclasses
import functools
import json
import time
from pathlib import Path

from repro.core.config import TestbedConfig
from repro.core.experiment import run_experiment

ART_DIR = Path(__file__).resolve().parent / "artifacts"


@functools.lru_cache(maxsize=1)
def canonical_results():
    """One full experiment on the canonical testbed (N=200 eval)."""
    cfg = TestbedConfig()
    res, extras, logs = run_experiment(
        cfg, include_mitigation=True, refusal_cap=0.45, verbose=False)
    return cfg, res, extras, logs


@functools.lru_cache(maxsize=1)
def canonical_hybrid9_logs():
    """hybrid9 offline logs (9-action full sweep with the dense/hybrid
    retrievers) on the canonical testbed sizes — the retriever-choice
    counterpart of :func:`canonical_results`."""
    from repro.core.offline_log import build_testbed
    from repro.routing import get_action_space

    space = get_action_space("hybrid9")
    cfg = TestbedConfig()
    cfg = dataclasses.replace(cfg, router=dataclasses.replace(
        cfg.router, n_actions=space.n_actions))
    data, index, pipe, train_log, eval_log = build_testbed(cfg, space)
    return cfg, space, (train_log, eval_log)


def save_artifact(name: str, obj) -> Path:
    ART_DIR.mkdir(parents=True, exist_ok=True)
    p = ART_DIR / f"{name}.json"
    p.write_text(json.dumps(obj, indent=1))
    return p


def timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def bar(x: float, scale: float = 50) -> str:
    n = max(0, int(x * scale))
    return "#" * n
