"""Dev scratch: forward/train/decode one step for every SMOKE config."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.transformer import loss_fn


def batch_for(cfg, B=2, S=32):
    key = jax.random.PRNGKey(1)
    S_txt = S - (cfg.n_modality_tokens if cfg.modality == "vision" else 0)
    inputs = {"tokens": jax.random.randint(key, (B, S_txt), 0, cfg.vocab_size)}
    if cfg.modality == "vision":
        inputs["image_emb"] = jax.random.normal(
            key, (B, cfg.n_modality_tokens, cfg.modality_embed_dim), jnp.bfloat16)
    if cfg.modality == "audio":
        inputs["audio_emb"] = jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    labels = jax.random.randint(key, (B, S_txt), 0, cfg.vocab_size)
    return inputs, labels


def run_lint_gate():
    """The same zero-findings gate CI runs, first — a lint violation
    fails the smoke before any model compiles."""
    from pathlib import Path

    from repro.analysis.cli import main as lint_main
    src = Path(__file__).resolve().parents[1] / "src"
    rc = lint_main([str(src), "--fail-on-findings"])
    if rc != 0:
        sys.exit("reprolint found unsuppressed findings (see above)")
    print("OK reprolint: src/ is clean")


def main():
    run_lint_gate()
    only = sys.argv[1:] or ARCH_IDS
    for arch in only:
        cfg = get_config(arch, "smoke")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        inputs, labels = batch_for(cfg)
        # train forward
        logits, extras = model.train_logits(params, inputs)
        loss = loss_fn(logits, labels, extras=extras)
        assert np.isfinite(float(loss)), (arch, float(loss))
        # decode path: prefill 8 tokens then 2 decode steps
        B = 2
        cache = model.init_cache(B, 64)
        pre_in = dict(inputs)
        pre_in["tokens"] = inputs["tokens"][:, :8]
        lg, cache = model.prefill(params, pre_in, cache)
        assert np.all(np.isfinite(np.asarray(lg, np.float32))), arch
        for i in range(2):
            tok = jnp.argmax(lg[:, -1], axis=-1)[:, None]
            lg, cache = model.decode(params, {"tokens": tok}, cache)
        print(f"OK {arch:24s} loss={float(loss):.3f} "
              f"params={model.n_params():,}")


if __name__ == "__main__":
    main()
