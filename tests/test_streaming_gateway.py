"""Open-loop streaming: arrival processes, the virtual-time load
harness, AsyncGateway admission control, and the background serving
thread.  Most tests run the deterministic SimulatorBackend service
model; the continuous-engine end-to-end is marked slow+loadtest."""
import numpy as np
import pytest

from repro.core.config import RouterConfig, TestbedConfig
from repro.core.offline_log import build_testbed
from repro.routing import FixedPolicy, Request, SimulatorBackend
from repro.serving.streaming import (AdmissionConfig, AsyncGateway,
                                     StreamHandle)
from repro.serving.traffic import (Arrival, LoadGenerator, OnOffProcess,
                                   PoissonProcess, VirtualClock, build_trace,
                                   sweep_offered_load)

ZERO_STATE = lambda qs: np.zeros((len(qs), 1))


@pytest.fixture(scope="module")
def testbed():
    cfg = TestbedConfig(n_train=40, n_eval=16, n_paragraphs=60,
                        router=RouterConfig(n_epochs=1))
    return cfg, build_testbed(cfg)


def _gateway(pipe, clock, *, action=2, deadline_ms=200.0, admission=None,
             **kw):
    be = SimulatorBackend(pipe, stream_slots=4, service_polls=2,
                          clock=clock.now)
    return AsyncGateway(FixedPolicy(action), be, state_fn=ZERO_STATE,
                        clock=clock.now, deadline_ms=deadline_ms,
                        admission=admission or AdmissionConfig(), **kw)


# --- arrival processes ------------------------------------------------------


def test_poisson_seeded_and_mean_rate():
    gaps = PoissonProcess(100.0, seed=7).inter_arrivals()
    a = [next(gaps) for _ in range(5000)]
    gaps2 = PoissonProcess(100.0, seed=7).inter_arrivals()
    b = [next(gaps2) for _ in range(5000)]
    assert a == b                                     # seeded
    assert np.mean(a) == pytest.approx(1 / 100.0, rel=0.1)
    assert PoissonProcess(50.0, seed=1).inter_arrivals() is not None
    with pytest.raises(ValueError):
        PoissonProcess(0.0)


def test_onoff_bursty_but_same_mean():
    p = OnOffProcess(200.0, on_s=0.5, off_s=0.5, seed=3)
    assert p.mean_rate == pytest.approx(100.0)
    gaps = [next(iter_g) for iter_g in [p.inter_arrivals()] for _ in range(8000)]
    # mean offered rate near the analytic mean...
    assert 1 / np.mean(gaps) == pytest.approx(100.0, rel=0.25)
    # ...but far burstier than Poisson at the same mean (CV >> 1)
    cv = np.std(gaps) / np.mean(gaps)
    assert cv > 1.3


def test_build_trace_monotone_and_cycling(testbed):
    _, (data, *_rest) = testbed
    qs = data.questions[:3]
    trace = build_trace(qs, PoissonProcess(10.0, seed=0), 7,
                        deadline_ms=123.0, slo="cheap")
    assert len(trace) == 7
    ts = [a.t for a in trace]
    assert ts == sorted(ts) and ts[0] > 0
    assert [a.request.qid for a in trace] == list(range(7))
    assert trace[3].request.question is qs[0]         # cycles
    assert all(a.request.deadline_ms == 123.0 for a in trace)
    assert all(a.request.slo == "cheap" for a in trace)


def test_virtual_clock():
    c = VirtualClock()
    assert c.now() == 0.0
    c.advance(1.5)
    c.advance_to(1.0)                                 # no-op backwards
    assert c.now() == 1.5
    with pytest.raises(ValueError):
        c.advance(-1.0)


# --- AsyncGateway: open-loop serving ----------------------------------------


def test_open_loop_serves_trace_and_stamps_latency(testbed):
    _, (data, index, pipe, *_rest) = testbed
    clock = VirtualClock()
    gw = _gateway(pipe, clock)
    trace = build_trace(data.questions[:8], PoissonProcess(40.0, seed=0),
                        40, deadline_ms=500.0)
    rep = LoadGenerator(gw, trace).run_virtual(clock,
                                               service_quantum_s=0.01)
    assert rep.offered == 40 and rep.completed == 40
    assert rep.shed == 0
    assert rep.answered + rep.refused == 40
    # queueing + 2 service polls of 10ms quantum => real latencies
    p = rep.latency.percentiles()
    assert p["n"] > 0 and p["p50_ms"] >= 0.0
    assert gw.stats.served == 40                      # all accounted
    assert gw.stats.latency_percentiles()["n"] == 40
    assert gw.in_flight == 0


def test_open_loop_deterministic_same_seed(testbed):
    """The acceptance criterion: same seed => same completions, sheds,
    and latencies, bit for bit."""
    _, (data, index, pipe, *_rest) = testbed

    def run():
        clock = VirtualClock()
        gw = _gateway(pipe, clock, admission=AdmissionConfig(max_backlog=6))
        trace = build_trace(data.questions[:8],
                            PoissonProcess(300.0, seed=11), 60,
                            deadline_ms=100.0)
        rep = LoadGenerator(gw, trace).run_virtual(clock)
        return rep.as_dict(), gw.stats.shed, gw.stats.forced_refusals

    assert run() == run()


def test_backlog_shedding_engages_under_overload(testbed):
    """Over-offered load with a tiny backlog cap: admission sheds at
    the queue, typed apart from policy refusals, and the system still
    completes everything it admitted."""
    _, (data, index, pipe, *_rest) = testbed
    clock = VirtualClock()
    gw = _gateway(pipe, clock,
                  admission=AdmissionConfig(max_backlog=4))
    # 500 req/s into a ~4-slot service: queue must overflow
    trace = build_trace(data.questions[:8], PoissonProcess(500.0, seed=0),
                        80, deadline_ms=1000.0)
    rep = LoadGenerator(gw, trace).run_virtual(clock)
    assert rep.shed > 0
    assert gw.stats.shed == rep.shed
    assert rep.completed == rep.offered               # sheds complete too
    # shed handles carry the typed marker, not a policy refusal count
    assert rep.shed + rep.answered + rep.refused == rep.offered


def test_expired_deadline_shed_at_queue(testbed):
    _, (data, index, pipe, *_rest) = testbed
    clock = VirtualClock()
    gw = _gateway(pipe, clock, deadline_ms=5.0,
                  admission=AdmissionConfig(max_backlog=1000))
    h = gw.submit_stream(Request(qid=0, question=data.questions[0]))
    clock.advance(1.0)       # 1000ms in the queue >> 5ms deadline
    gw.pump()
    assert h.done() and h.shed
    assert gw.stats.shed == 1
    assert not h.deadline_met


def test_latency_burn_shed_and_forced_refusals(testbed):
    """Burn-rate actuation: sustained deadline violations push the
    latency budget's short-window burn over the thresholds, and the
    gateway starts refusing/shedding instead of queueing deeper."""
    _, (data, index, pipe, *_rest) = testbed
    clock = VirtualClock()
    adm = AdmissionConfig(max_backlog=10_000, min_events=8,
                          shed_burn=3.0, force_refuse_burn=2.0,
                          burn_window=16, shed_expired=False)
    # 10ms deadline, 2 polls x 10ms quantum service => every completion
    # violates; the latency budget must burn hot
    gw = _gateway(pipe, clock, deadline_ms=10.0, admission=adm)
    trace = build_trace(data.questions[:8], PoissonProcess(200.0, seed=0),
                        60, deadline_ms=10.0)
    rep = LoadGenerator(gw, trace).run_virtual(clock,
                                               service_quantum_s=0.01)
    assert gw.budget.burn_rate("latency") > 1.0
    assert gw.stats.forced_refusals > 0 or gw.stats.shed > 0
    assert rep.forced_refusals == gw.stats.forced_refusals


def test_depth_clamp_on_cost_burn(testbed):
    """Cost-budget burn clamps routed retrieval depth to the shallowest
    same-mode action instead of refusing: requests still get answered,
    the depth actuation is counted."""
    _, (data, index, pipe, *_rest) = testbed
    clock = VirtualClock()
    # cost target with a tiny threshold: every request violates it
    from repro.serving.slo_budget import SLOTarget
    targets = [SLOTarget("cost", "cost_tokens", 1.0, objective=0.95)]
    adm = AdmissionConfig(max_backlog=10_000, min_events=4,
                          clamp_burn=1.0, force_refuse_burn=1e9,
                          shed_burn=1e9)
    gw = _gateway(pipe, clock, deadline_ms=0.0, admission=adm,
                  budget_targets=targets, action=2)   # k=10 guarded
    trace = build_trace(data.questions[:8], PoissonProcess(40.0, seed=0),
                        30)
    LoadGenerator(gw, trace).run_virtual(clock)
    assert gw.stats.depth_clamped > 0
    assert gw.stats.forced_refusals == 0
    # clamped requests were served with the shallowest guarded action
    space = gw.space
    shallow = min((a for a in space if a.mode == "guarded" and a.k > 0),
                  key=lambda a: a.k)
    assert gw.stats.action_counts[shallow.idx] > 0


def test_closed_loop_paths_untouched(testbed):
    """AsyncGateway still serves the classic closed-loop way (serve/
    drain), identically to the base Gateway — the streaming layer is
    additive."""
    from repro.routing import Gateway
    _, (data, index, pipe, *_rest) = testbed
    reqs = [Request(qid=q.qid, question=q) for q in data.questions[:12]]
    base = Gateway(FixedPolicy(1), SimulatorBackend(pipe),
                   state_fn=ZERO_STATE).serve(list(reqs))
    clock = VirtualClock()
    stream = _gateway(pipe, clock, action=1).serve(list(reqs))
    assert base.served == stream.served == 12
    assert dict(base.action_counts) == dict(stream.action_counts)
    assert base.total_reward == pytest.approx(stream.total_reward)


def test_async_gateway_rejects_nonstreaming_backend(testbed):
    _, (data, index, pipe, *_rest) = testbed

    class NoStream:
        def execute_batch(self, qs, a):
            return []

    with pytest.raises(TypeError):
        AsyncGateway(FixedPolicy(0), NoStream(), state_fn=ZERO_STATE)


def test_stream_handle_result_timeout(testbed):
    _, (data, index, pipe, *_rest) = testbed
    clock = VirtualClock()
    gw = _gateway(pipe, clock)
    h = gw.submit_stream(Request(qid=0, question=data.questions[0]))
    with pytest.raises(TimeoutError):
        h.result(timeout=0.01)
    gw.drain_stream()
    assert h.done() and h.result() is not None


def test_background_thread_smoke(testbed):
    """Realtime mode: the daemon serving thread completes futures while
    the client thread just submits and waits."""
    _, (data, index, pipe, *_rest) = testbed
    be = SimulatorBackend(pipe, stream_slots=4, service_polls=2)
    gw = AsyncGateway(FixedPolicy(2), be, state_fn=ZERO_STATE,
                      deadline_ms=10_000.0)
    with gw:
        handles = [gw.submit_stream(Request(qid=i, question=q))
                   for i, q in enumerate(data.questions[:10])]
        outs = [h.result(timeout=30.0) for h in handles]
    assert len(outs) == 10 and all(o is not None for o in outs)
    assert gw.stats.served + gw.stats.shed == 10


# --- offered-load sweep -----------------------------------------------------


def test_sweep_offered_load_rows(testbed):
    _, (data, index, pipe, *_rest) = testbed

    def make(clock):
        return _gateway(pipe, clock,
                        admission=AdmissionConfig(max_backlog=6))

    rows = sweep_offered_load(make, data.questions[:8], [20.0, 800.0],
                              n_requests=60, deadline_ms=200.0, seed=0)
    assert [r["rate"] for r in rows] == [20.0, 800.0]
    for r in rows:
        assert r["offered"] == 60
        assert {"goodput", "shed", "latency_p50_ms",
                "latency_p99_ms"} <= set(r)
    # over-offered load sheds; comfortable load doesn't
    assert rows[1]["shed"] > rows[0]["shed"]


# --- continuous engine end-to-end (slow) ------------------------------------


@pytest.mark.slow
@pytest.mark.loadtest
def test_open_loop_continuous_engine_end_to_end(testbed):
    """The real thing: a seeded Poisson trace through AsyncGateway over
    the continuous engine in virtual time — deterministic completions,
    every request accounted, engine stream serves across pumps."""
    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.data.tokenizer import HashTokenizer
    from repro.models import build_model
    from repro.routing import ContinuousEngineBackend

    _, (data, index, pipe, *_rest) = testbed
    mcfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                               dtype="float32")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))

    def run():
        clock = VirtualClock()
        backend = ContinuousEngineBackend.create(
            model, params, HashTokenizer(mcfg.vocab_size), index,
            num_slots=4, max_prompt_len=96, max_new_tokens=4,
            clock=clock.now)
        gw = AsyncGateway(FixedPolicy(0), backend, state_fn=ZERO_STATE,
                          clock=clock.now, deadline_ms=5000.0,
                          admission=AdmissionConfig(max_backlog=12))
        trace = build_trace(data.questions[:6], PoissonProcess(100.0, seed=2),
                            16, deadline_ms=5000.0)
        rep = LoadGenerator(gw, trace).run_virtual(clock,
                                                   service_quantum_s=0.005)
        return rep, gw

    rep, gw = run()
    assert rep.completed == rep.offered == 16
    assert gw.stats.served + gw.stats.shed == 16
    assert gw.engine_stats.n_completed > 0
    rep2, _ = run()
    assert rep.as_dict() == rep2.as_dict()
