"""Unified telemetry plane: metrics registry, span tracer, latency
attribution, and the instrumented serving stack.

Unit layers run against hand-fed instruments; the integration layer
replays a seeded open-loop trace through AsyncGateway + the simulator
backend in virtual time and asserts the PR's acceptance criteria:
every terminal request carries a per-stage breakdown whose top-level
stage sum equals end-to-end latency, the span trees are well-formed,
the Chrome trace and Prometheus exposition parse, and the healthy path
is bit-identical with tracing disabled.
"""
from __future__ import annotations

import json
import math

import numpy as np
import pytest

from repro.core.config import RouterConfig, TestbedConfig
from repro.core.offline_log import build_testbed
from repro.obs import (KINDS, NULL_TRACER, TOP_LEVEL, Histogram,
                       MetricsRegistry, NullTracer, RequestBreakdown,
                       StageAttribution, Tracer)
from repro.routing import FixedPolicy, SimulatorBackend
from repro.serving.slo_budget import LatencyReservoir
from repro.serving.streaming import AdmissionConfig, AsyncGateway
from repro.serving.traffic import (LoadGenerator, PoissonProcess,
                                   VirtualClock, build_trace)

ZERO_STATE = lambda qs: np.zeros((len(qs), 1))


@pytest.fixture(scope="module")
def testbed():
    cfg = TestbedConfig(n_train=40, n_eval=16, n_paragraphs=60,
                        router=RouterConfig(n_epochs=1))
    return cfg, build_testbed(cfg)


# --- MetricsRegistry --------------------------------------------------------


def test_registry_exposition_and_snapshot():
    clock = VirtualClock()
    clock.advance(3.5)
    reg = MetricsRegistry(clock.now)
    c = reg.counter("served_total", "requests served")
    g = reg.gauge("queue_depth", "pending")
    h = reg.histogram("latency_ms", "per-request", bounds=(1.0, 10.0))
    c.inc(); c.inc(2.0)
    g.set(4)
    h.observe(0.5); h.observe(5.0); h.observe(99.0)
    text = reg.exposition()
    lines = text.splitlines()
    assert "# HELP repro_served_total requests served" in lines
    assert "# TYPE repro_served_total counter" in lines
    assert "repro_served_total 3" in lines
    assert "repro_queue_depth 4" in lines
    # cumulative buckets + implicit +Inf
    assert 'repro_latency_ms_bucket{le="1"} 1' in lines
    assert 'repro_latency_ms_bucket{le="10"} 2' in lines
    assert 'repro_latency_ms_bucket{le="+Inf"} 3' in lines
    assert "repro_latency_ms_count 3" in lines
    # every non-comment line is `name[{labels}] value`
    for ln in lines:
        if not ln.startswith("#"):
            name, val = ln.rsplit(" ", 1)
            assert name.startswith("repro_") and float(val) >= 0
    snap = json.loads(reg.snapshot_json())
    assert snap["clock_s"] == 3.5                    # injected clock
    assert snap["metrics"]["served_total"]["value"] == 3.0
    assert snap["metrics"]["latency_ms"]["count"] == 3


def test_registry_rejects_duplicates_bad_names_and_clockless():
    reg = MetricsRegistry(lambda: 0.0)
    reg.counter("served_total")
    with pytest.raises(ValueError, match="registered twice"):
        reg.gauge("served_total")
    with pytest.raises(ValueError, match="lowercase_snake"):
        reg.counter("ServedTotal")
    with pytest.raises(TypeError, match="clock"):
        MetricsRegistry()  # type: ignore[call-arg]
    with pytest.raises(TypeError, match="clock"):
        Tracer()  # type: ignore[call-arg]


def test_registry_collector_runs_at_scrape_only():
    reg = MetricsRegistry(lambda: 0.0)
    g = reg.gauge("resident")
    state = {"v": 0, "scrapes": 0}

    def scrape():
        state["scrapes"] += 1
        g.set(state["v"])

    reg.register_collector(scrape)
    state["v"] = 7
    assert state["scrapes"] == 0                     # hot path untouched
    assert "repro_resident 7" in reg.exposition()
    assert state["scrapes"] == 1


def test_histogram_merge_associative_and_commutative():
    bounds = (1.0, 5.0, 25.0)

    def build(vals):
        h = Histogram("m", bounds=bounds)
        for v in vals:
            h.observe(v)
        return h

    a = build([0.5, 3.0])
    b = build([30.0, 4.0, 0.1])
    c = build([7.0])

    def key(h):
        return (h.counts, h.inf_count, h.total, h.count)

    assert key(a.merge(b).merge(c)) == key(a.merge(b.merge(c)))
    assert key(a.merge(b)) == key(b.merge(a))
    # merge returns a NEW histogram; inputs unchanged
    assert a.count == 2 and b.count == 3
    merged = a.merge(b).merge(c)
    assert merged.count == 6 and merged.inf_count == 1
    with pytest.raises(ValueError, match="different bounds"):
        a.merge(Histogram("m", bounds=(1.0, 2.0)))


def test_histogram_quantile_and_empty():
    h = Histogram("m", bounds=(10.0, 20.0))
    assert math.isnan(h.quantile(0.5))
    for v in (1.0, 2.0, 3.0, 15.0):
        h.observe(v)
    assert 0.0 < h.quantile(0.5) <= 10.0
    assert 10.0 < h.quantile(0.99) <= 20.0


# --- LatencyReservoir percentile edges --------------------------------------


def test_latency_reservoir_empty_is_nan():
    r = LatencyReservoir()
    assert math.isnan(r.percentile(50))
    p = r.percentiles()
    assert p["n"] == 0 and math.isnan(p["p99_ms"])


def test_latency_reservoir_single_sample():
    r = LatencyReservoir()
    r.record(42.0)
    for q in (0, 50, 99, 100):
        assert r.percentile(q) == 42.0
    assert r.percentiles()["n"] == 1


def test_latency_reservoir_exact_capacity_boundary():
    r = LatencyReservoir(capacity=8, seed=0)
    r.extend(float(i) for i in range(8))
    # below/at capacity the reservoir is exact — no sampling yet
    assert len(r) == 8 and r.count == 8
    assert r.percentile(0) == 0.0 and r.percentile(100) == 7.0
    r.record(100.0)                     # crosses the boundary
    assert len(r) == 8 and r.count == 9
    # deterministic for a given seed + insert sequence
    r2 = LatencyReservoir(capacity=8, seed=0)
    r2.extend(float(i) for i in range(8))
    r2.record(100.0)
    assert r.percentiles() == r2.percentiles()


# --- Tracer unit ------------------------------------------------------------


def _finish_simple(tr, qid=1, t0=0.0):
    tr.begin_request(qid, t0)
    tr.mark(qid, "queue_wait", t0, t0 + 0.001)
    tr.mark(qid, "admission", t0 + 0.001, t0 + 0.003)
    tr.mark(qid, "retrieval", t0 + 0.0015, t0 + 0.0025)
    tr.mark(qid, "prefill", t0 + 0.003, t0 + 0.004)
    tr.mark(qid, "decode", t0 + 0.004, t0 + 0.009)
    tr.mark(qid, "harvest", t0 + 0.009, t0 + 0.010)
    return tr.finish_request(qid, "completed", t=t0 + 0.010,
                             cost_tokens=17.0)


def test_tracer_breakdown_sums_and_dominant_stage():
    tr = Tracer(lambda: 0.0)
    bd = _finish_simple(tr)
    assert bd.kind == "completed" and bd.cost_tokens == 17.0
    assert bd.e2e_ms == pytest.approx(10.0)
    # top-level chain is contiguous: stage sum == e2e exactly
    assert bd.stage_sum_ms == pytest.approx(bd.e2e_ms)
    # retrieval (1ms) nests inside admission (2ms): no double count,
    # decode (5ms) dominates
    assert bd.dominant_stage == "decode"
    assert tr.n_finished == 1 and tr.n_open == 0
    d = bd.as_dict()
    assert d["dominant_stage"] == "decode"
    assert set(d["stages"]) <= set(TOP_LEVEL) | {"retrieval"}


def test_tracer_rejects_unknown_kind_and_ignores_unknown_qid():
    tr = Tracer(lambda: 0.0)
    tr.begin_request(1, 0.0)
    with pytest.raises(ValueError, match="unknown terminal kind"):
        tr.finish_request(1, "exploded")
    tr.mark(99, "decode", 0.0, 1.0)          # unknown qid: no-op
    assert tr.finish_request(99, "completed") is None
    tr.begin_request(2, 0.0)
    assert tr.finish_request(2, "completed", t=0.5) is not None


def test_tracer_note_adopt_and_discard():
    tr = Tracer(lambda: 0.0)
    tr.begin_request(5, 0.0)
    tr.note("retrieval", 0.001, 0.002, retriever="bm25", k=3)
    tr.adopt(5)
    bd = tr.finish_request(5, "completed", t=0.01)
    assert bd.stages["retrieval"] == pytest.approx(1.0)
    tree = tr.sampled_trees[0]
    retr = [s for s in tree.spans if s.name == "retrieval"][0]
    assert retr.attrs == {"retriever": "bm25", "k": 3}
    # discarded notes never attach
    tr.begin_request(6, 0.0)
    tr.note("retrieval", 0.0, 0.001)
    tr.discard_pending()
    tr.adopt(6)
    assert "retrieval" not in tr.finish_request(6, "completed", t=0.01).stages


def test_tracer_problems_catch_malformed_trees():
    tr = Tracer(lambda: 0.0)
    _finish_simple(tr)
    assert tr.problems() == []
    # open request
    tr.begin_request(2, 0.0)
    assert any("never finished" in p for p in tr.problems())
    tr.finish_request(2, "faulted", t=0.001)
    assert tr.problems() == []
    # span escaping the root interval
    tr.begin_request(3, 1.0)
    tr.mark(3, "decode", 0.5, 2.0)
    tr.finish_request(3, "completed", t=1.5)
    assert any("escapes root" in p for p in tr.problems())


def test_tracer_chrome_trace_export():
    tr = Tracer(lambda: 0.0)
    _finish_simple(tr)
    tr.engine_span("decode_chunk", 0.004, 0.008, steps=4)
    data = json.loads(tr.chrome_trace_json(indent=1))
    events = data["traceEvents"]
    assert data["displayTimeUnit"] == "ms"
    # the artifact carries its own well-formedness audit
    assert data["otherData"] == {"n_finished": 1, "n_open": 0,
                                 "problems": []}
    x = [e for e in events if e["ph"] == "X"]
    meta = [e for e in events if e["ph"] == "M"]
    assert len(meta) == 2                   # engine + requests tracks
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in x)
    root = [e for e in x if e["name"] == "request[completed]"]
    assert len(root) == 1 and root[0]["pid"] == 1
    # children stay inside the root interval (µs domain)
    for e in x:
        if e["pid"] == 1 and e is not root[0]:
            assert e["ts"] >= root[0]["ts"] - 1e-6
            assert (e["ts"] + e["dur"]
                    <= root[0]["ts"] + root[0]["dur"] + 1e-6)
    eng = [e for e in x if e["pid"] == 0]
    assert len(eng) == 1 and eng[0]["args"]["steps"] == 4


def test_tracer_sampling_bounds_memory():
    tr = Tracer(lambda: 0.0, max_trees=16, seed=3)
    for i in range(200):
        tr.begin_request(i, float(i))
        tr.finish_request(i, "completed", t=float(i) + 0.001)
    assert len(tr.sampled_trees) == 16
    assert tr.n_finished == 200
    assert len(tr.breakdowns) == 200        # every request still counted


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.now() == 0.0
    NULL_TRACER.begin_request(1, 0.0)
    NULL_TRACER.mark(1, "decode", 0.0, 1.0)
    NULL_TRACER.note("retrieval", 0.0, 1.0)
    NULL_TRACER.adopt(1)
    NULL_TRACER.engine_span("prefill_dispatch", 0.0, 1.0)
    assert NULL_TRACER.finish_request(1, "completed") is None
    assert NULL_TRACER.stage_percentiles() == {}
    assert NULL_TRACER.problems() == []
    assert isinstance(NULL_TRACER, NullTracer)


# --- StageAttribution / budget integration ----------------------------------


def test_stage_attribution_windowed_report():
    att = StageAttribution(window=4)
    for i in range(6):
        att.record(RequestBreakdown(
            qid=i, kind="completed", e2e_ms=10.0,
            stages={"queue_wait": 1.0, "admission": 2.0,
                    "retrieval": 1.5, "decode": 7.0}))
    assert len(att) == 4                    # window bounds the deque
    rep = att.report()
    assert rep["n"] == 4 and rep["dominant_stage"] == "decode"
    # admission share is net of nested retrieval
    assert rep["stage_ms"]["admission"] == pytest.approx(2.0)
    assert rep["stage_share"]["retrieval"] > 0
    shares = sum(rep["stage_share"].values())
    assert shares == pytest.approx(1.0, abs=1e-6)


# --- open-loop integration: the acceptance criteria -------------------------


def _run_traced(data, pipe, *, rate=500.0, n=80, deadline_ms=1000.0,
                backlog=4, traced=True):
    """500 req/s into a ~4-slot service with a tiny backlog cap: the
    queue must overflow, so the run exercises shed AND completed
    terminal kinds (mirrors test_backlog_shedding_engages_under_overload)."""
    clock = VirtualClock()
    backend = SimulatorBackend(pipe, stream_slots=4, service_polls=2,
                               clock=clock.now)
    kw = ({"tracer": Tracer(clock.now),
           "metrics": MetricsRegistry(clock.now)} if traced else {})
    gw = AsyncGateway(FixedPolicy(2), backend, state_fn=ZERO_STATE,
                      clock=clock.now, deadline_ms=deadline_ms,
                      admission=AdmissionConfig(max_backlog=backlog), **kw)
    trace = build_trace(data.questions[:8], PoissonProcess(rate, seed=0),
                        n, deadline_ms=deadline_ms)
    gen = LoadGenerator(gw, trace)
    rep = gen.run_virtual(clock, service_quantum_s=0.01)
    return gw, gen, rep


@pytest.fixture(scope="module")
def traced_run(testbed):
    _, (data, index, pipe, *_rest) = testbed
    return _run_traced(data, pipe)


def test_every_terminal_request_carries_breakdown(traced_run):
    gw, gen, rep = traced_run
    assert rep.offered == 80 and rep.completed == 80
    assert rep.shed > 0                     # overload engaged shedding
    for h in gen.last_handles:
        assert h.done()
        bd = h.breakdown
        assert bd is not None, f"qid {h.request.qid} missing breakdown"
        assert bd.kind in KINDS
        if h.shed:
            assert bd.kind == "shed"
        # top-level stage sum equals end-to-end latency by construction
        assert bd.stage_sum_ms == pytest.approx(bd.e2e_ms, abs=1e-6), \
            (bd.qid, bd.kind, bd.stages, bd.e2e_ms)
    kinds = {h.breakdown.kind for h in gen.last_handles}
    assert "completed" in kinds and "shed" in kinds


def test_traced_run_trees_well_formed_and_export_parses(traced_run):
    gw, gen, rep = traced_run
    tr = gw.tracer
    assert tr.n_open == 0
    assert tr.problems() == []
    data = json.loads(tr.chrome_trace_json())
    assert len([e for e in data["traceEvents"] if e["ph"] == "X"]) > 0
    pct = tr.stage_percentiles()
    assert set(pct) <= set(TOP_LEVEL) | {"retrieval", "e2e"}
    assert pct["e2e"]["n"] == 80            # every terminal kind counted
    # LoadReport picked the stages table up
    assert rep.stages == pct
    assert "stages" in rep.as_dict()


def test_traced_run_metrics_and_attribution(traced_run):
    gw, gen, rep = traced_run
    text = gw.metrics.exposition()
    served = gw.stats.served
    assert f"repro_gateway_served_total {served}" in text.splitlines()
    assert "repro_gateway_request_latency_ms_bucket" in text
    assert f"repro_gateway_shed_total {gw.stats.shed}" in text.splitlines()
    report = gw.budget.report_dict()
    att = report.get("latency_attribution")
    assert att and att["n"] > 0
    assert att["dominant_stage"] in set(TOP_LEVEL) | {"retrieval"}


def test_healthy_path_parity_with_tracing_disabled(testbed):
    """Acceptance criterion: the traced run and the NULL_TRACER run are
    token-identical — same outcomes, same latencies, same report."""
    _, (data, index, pipe, *_rest) = testbed
    gw_t, gen_t, rep_t = _run_traced(data, pipe, traced=True)
    gw_n, gen_n, rep_n = _run_traced(data, pipe, traced=False)
    assert gw_n.tracer is NULL_TRACER
    d_t, d_n = rep_t.as_dict(), rep_n.as_dict()
    d_t.pop("stages", None)                  # the only traced-run extra
    assert d_t == d_n
    for ht, hn in zip(gen_t.last_handles, gen_n.last_handles):
        assert ht.request.qid == hn.request.qid
        assert ht.shed == hn.shed
        if ht.outcome is not None:
            assert ht.outcome.answer == hn.outcome.answer
            assert ht.outcome.cost_tokens == hn.outcome.cost_tokens
            assert ht.outcome.to_row() == hn.outcome.to_row()
    assert gw_t.stats.served == gw_n.stats.served
    assert gw_t.stats.avg_reward == gw_n.stats.avg_reward


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
