"""Fixture tests for every reprolint rule: each rule's true positives
fire, its negatives stay quiet, and suppressions parse.

The fixture files live under ``tests/fixtures/lint/`` and are parsed,
never imported.  DEFAULT_CONFIG path-scopes several rules to repo
subtrees the fixtures are outside of, so these tests build an
everywhere-enabled config; the DEFAULT_CONFIG contract on the real tree
is covered by the meta-test in ``test_lint_meta.py``.
"""
from __future__ import annotations

from pathlib import Path

import pytest

from repro.analysis import LintConfig, run_lint
from repro.analysis.base import all_rules

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "lint"


def everywhere_config() -> LintConfig:
    cfg = LintConfig()
    for rid in all_rules():
        cfg.rule(rid)          # default RuleConfig: enabled, no scoping
    return cfg


def lint(name: str):
    return run_lint([str(FIXTURES / name)], config=everywhere_config())


def by_rule(result, rule):
    return [f for f in result.findings if f.rule == rule]


def src_line(name: str, lineno: int) -> str:
    return (FIXTURES / name).read_text().splitlines()[lineno - 1]


def test_registry_has_all_seven_rules():
    assert sorted(all_rules()) == [
        "RPL001", "RPL002", "RPL003", "RPL004", "RPL005", "RPL006",
        "RPL007"]


def test_clean_fixture_has_no_findings():
    res = lint("clean.py")
    assert res.findings == [] and res.suppressed == []


# -- RPL001 clock-discipline ------------------------------------------------


def test_rpl001_flags_wall_clock_calls():
    res = lint("rpl001_clock.py")
    hits = by_rule(res, "RPL001")
    assert len(hits) == 3
    srcs = [src_line("rpl001_clock.py", f.line) for f in hits]
    assert any("time.time()" in s for s in srcs)
    assert any("time.sleep(0.1)" in s for s in srcs)
    assert any("datetime.now()" in s for s in srcs)
    # perf_counter and the un-called seam reference stay quiet
    assert not any("perf_counter" in s or "sleep or" in s for s in srcs)


def test_rpl001_suppressions_inline_and_preceding():
    res = lint("rpl001_clock.py")
    sup = [f for f in res.suppressed if f.rule == "RPL001"]
    assert len(sup) == 2
    assert {f.suppress_reason for f in sup} == {
        "fixture: preceding-line suppression",
        "fixture: inline suppression"}


# -- RPL002 determinism -----------------------------------------------------


def test_rpl002_flags_global_rng_not_seeded_instances():
    res = lint("rpl002_rng.py")
    hits = by_rule(res, "RPL002")
    srcs = [src_line("rpl002_rng.py", f.line) for f in hits]
    assert len(hits) == 4
    assert any("random.random()" in s for s in srcs)
    assert any("np.random.rand" in s for s in srcs)
    assert any("np.random.seed" in s for s in srcs)
    assert any("default_rng()" in s for s in srcs)
    assert not any("default_rng(seed)" in s for s in srcs)


# -- RPL003 jit-donation ----------------------------------------------------


def test_rpl003_use_after_donate_and_out_shardings():
    res = lint("rpl003_donate.py")
    hits = by_rule(res, "RPL003")
    assert len(hits) == 3
    donated = [f for f in hits if "was donated" in f.message]
    mesh = [f for f in hits if "out_shardings" in f.message]
    assert len(donated) == 2 and len(mesh) == 1
    donated_srcs = [src_line("rpl003_donate.py", f.line) for f in donated]
    assert any("params.mean()" in s for s in donated_srcs)
    assert any("cache.pos" in s for s in donated_srcs)
    # the cross-method finding names the donated argument
    assert any("`cache`" in f.message for f in donated)
    assert "jax.jit(decode_fn" in src_line("rpl003_donate.py",
                                           mesh[0].line)


def test_rpl003_rebind_and_store_clear_taint():
    res = lint("rpl003_donate.py")
    srcs = [src_line("rpl003_donate.py", f.line)
            for f in by_rule(res, "RPL003")]
    # neither good_rebind's return nor GoodExecutor's read is flagged
    assert not any(s.strip() == "return params" for s in srcs)
    assert sum("cache.pos" in s for s in srcs) == 1


# -- RPL004 pallas-vmem-budget ----------------------------------------------


def test_rpl004_budget_unbound_and_masked_tail():
    res = lint("rpl004_vmem.py")
    hits = by_rule(res, "RPL004")
    assert len(hits) == 3
    over = [f for f in hits if "exceeds" in f.message]
    unbound = [f for f in hits if "mystery_dim" in f.message]
    tail = [f for f in hits if "non-divisible" in f.message]
    assert len(over) == 1 and len(unbound) == 1 and len(tail) == 1
    # budget + tail findings both anchor on the same bad pallas_call
    assert over[0].line == tail[0].line
    assert tail[0].message.startswith("kernel `_unmasked_kernel`")


def test_rpl004_transitive_iota_and_assert_satisfy_tail_check():
    res = lint("rpl004_vmem.py")
    tail = [f for f in by_rule(res, "RPL004")
            if "non-divisible" in f.message]
    # ok_transitive_mask (helper-call iota) and ok_divisibility_assert
    # produced no tail findings — only the unmasked one did
    assert len(tail) == 1


# -- RPL005 thread-shared-state ---------------------------------------------


def test_rpl005_flags_unlocked_shared_writes_only():
    res = lint("rpl005_threads.py")
    hits = by_rule(res, "RPL005")
    assert len(hits) == 2
    assert all("self.count" in f.message for f in hits)
    srcs = [src_line("rpl005_threads.py", f.line) for f in hits]
    assert all("self.count += 1" in s for s in srcs)
    # the single-writer `done` flag and GoodWorker's locked writes pass
    assert not any("done" in f.message for f in hits)


# -- RPL006 exception-hygiene -----------------------------------------------


def test_rpl006_flags_swallowing_handlers_only():
    res = lint("rpl006_except.py")
    hits = by_rule(res, "RPL006")
    assert len(hits) == 2
    srcs = [src_line("rpl006_except.py", f.line) for f in hits]
    assert any("except Exception:" in s for s in srcs)
    assert any(s.strip().startswith("except:") for s in srcs)


# -- RPL007 metric-hygiene --------------------------------------------------


def test_rpl007_flags_bad_names_duplicates_and_clockless():
    res = lint("rpl007_metrics.py")
    hits = by_rule(res, "RPL007")
    assert len(hits) == 5
    srcs = [src_line("rpl007_metrics.py", f.line) for f in hits]
    assert any("GatewayServed" in s for s in srcs)
    assert any("queue-depth" in s for s in srcs)
    dup = [f for f in hits if "registered twice" in f.message]
    assert len(dup) == 1 and "served_total" in dup[0].message
    clockless = [f for f in hits if "clock" in f.message]
    assert len(clockless) == 2
    assert {("Tracer()" in src_line("rpl007_metrics.py", f.line)
             or "MetricsRegistry()" in src_line("rpl007_metrics.py",
                                                f.line))
            for f in clockless} == {True}


def test_rpl007_negatives_stay_quiet():
    res = lint("rpl007_metrics.py")
    srcs = [src_line("rpl007_metrics.py", f.line)
            for f in by_rule(res, "RPL007")]
    # f-string names, clocked constructions, NullTracer(), and the
    # same-name-different-registry pair all pass
    assert not any("breaker_" in s for s in srcs)
    assert not any("NullTracer" in s for s in srcs)
    assert not any("reg_a" in s or "reg_b" in s for s in srcs)
    assert not any("Tracer(clock" in s for s in srcs)


# -- suppression machinery --------------------------------------------------


def test_bare_allow_is_reported_and_does_not_suppress():
    res = lint("suppressions.py")
    errs = by_rule(res, "RPLERR")
    assert len(errs) == 1 and "no reason" in errs[0].message
    # the RPL001 finding on that same line is still active
    assert any(f.rule == "RPL001" and f.line == errs[0].line
               for f in res.findings)


def test_multi_rule_allow_suppresses_both_ids():
    res = lint("suppressions.py")
    sup_rules = {f.rule for f in res.suppressed}
    assert {"RPL001", "RPL002"} <= sup_rules
    assert all(f.suppress_reason == "fixture: one comment, two rules"
               for f in res.suppressed)


def test_wrong_rule_id_does_not_suppress():
    res = lint("suppressions.py")
    line = next(i + 1 for i, s in enumerate(
        (FIXTURES / "suppressions.py").read_text().splitlines())
        if "wrong id" in s)
    assert any(f.rule == "RPL001" and f.line == line
               for f in res.findings)


def test_path_scoping_include_exclude():
    cfg = everywhere_config()
    cfg.rule("RPL001").include = ("no/such/fragment",)
    res = run_lint([str(FIXTURES / "rpl001_clock.py")], config=cfg)
    assert by_rule(res, "RPL001") == []
    cfg2 = everywhere_config()
    cfg2.rule("RPL001").exclude = ("fixtures/lint",)
    res2 = run_lint([str(FIXTURES / "rpl001_clock.py")], config=cfg2)
    assert by_rule(res2, "RPL001") == []


def test_syntax_error_reports_rplerr(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    res = run_lint([str(bad)], config=everywhere_config())
    assert [f.rule for f in res.findings] == ["RPLERR"]
    assert "syntax error" in res.findings[0].message


def test_config_overlay_disables_and_retargets():
    cfg = everywhere_config().overlay({"rules": {
        "RPL001": {"enabled": False},
        "RPL004": {"options": {"budget_bytes": 1}},
    }})
    assert not cfg.rule("RPL001").enabled
    assert cfg.rule("RPL004").options["budget_bytes"] == 1
    res = run_lint([str(FIXTURES / "rpl001_clock.py")], config=cfg)
    assert by_rule(res, "RPL001") == []


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
