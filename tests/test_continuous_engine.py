"""Continuous-batching engine: greedy parity with the padded engine,
slot reuse under admission pressure, one-allocation lifetime invariant,
and cross-bucket in-flight serving through the Gateway."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import trim_at_eos as _trim
from repro.models import build_model
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, n, plen, seed=0):
    rng = np.random.default_rng(seed)
    return [list(rng.integers(4, cfg.vocab_size, size=plen)) for _ in range(n)]


@pytest.mark.parametrize("prefill_batch", [1, 3])
def test_greedy_parity_with_padded_engine(qwen, prefill_batch):
    """Token-identical greedy outputs vs the padded-bucket Engine for
    the same (equal-length) prompts — per request, trimmed at its EOS,
    with both single-row and batched prefill admission."""
    cfg, model, params = qwen
    prompts = _prompts(cfg, 5, 10)
    old = Engine(model, params, max_len=64)
    res = old.generate(prompts, max_new_tokens=12)
    # fewer slots than requests: the 4th/5th prompts are admitted
    # mid-stream into freed slots, outputs must not change
    ce = ContinuousEngine(model, params, num_slots=3, max_len=64,
                          max_new_cap=16, sync_every=4,
                          prefill_batch=prefill_batch)
    outs = ce.generate_many(prompts, max_new_tokens=12)
    for i in range(len(prompts)):
        assert _trim(res.tokens[i]) == _trim(outs[i].tokens), i
    assert ce.stats.max_concurrent == 3
    assert ce.stats.n_admitted == 5


def test_one_cache_allocation_per_lifetime(qwen):
    """The slot cache (and the prefill scratch) are allocated at
    construction and never again — serving more requests, across
    multiple run() waves, must not call init_cache."""
    cfg, model, params = qwen
    calls = []
    orig = model.init_cache

    class Counting:
        def __getattr__(self, name):
            return getattr(model, name)

        def init_cache(self, batch, max_len):
            calls.append((batch, max_len))
            return orig(batch, max_len)

    ce = ContinuousEngine(Counting(), params, num_slots=2, max_len=48,
                          max_new_cap=8, sync_every=2)
    n_construction = len(calls)
    assert n_construction == 2  # slot cache + single-row prefill scratch
    for wave in range(2):
        ce.generate_many(_prompts(cfg, 3, 8, seed=wave), max_new_tokens=6)
    assert len(calls) == n_construction
    assert ce.stats.cache_allocations == 2
    assert ce.stats.n_completed == 6


def test_immediate_finish_and_limit_one(qwen):
    """max_new_tokens=1 requests finish at prefill and free their slot
    without entering the decode loop."""
    cfg, model, params = qwen
    ce = ContinuousEngine(model, params, num_slots=2, max_len=32,
                          max_new_cap=8)
    outs = ce.generate_many(_prompts(cfg, 3, 6), max_new_tokens=1)
    assert [o.n_steps for o in outs] == [1, 1, 1]
    assert ce.stats.n_decode_chunks == 0
    assert ce.stats.n_completed == 3


def test_submit_rejects_overflow(qwen):
    cfg, model, params = qwen
    ce = ContinuousEngine(model, params, num_slots=1, max_len=16,
                          max_new_cap=8)
    with pytest.raises(ValueError):
        ce.submit(0, list(range(4, 16)), max_new_tokens=8)
    with pytest.raises(ValueError):
        ce.submit(1, [], max_new_tokens=2)


def test_overlength_rejected_per_request_keeps_stream_alive(qwen):
    """Regression: one over-length prompt in a mixed stream must not
    kill the run — with strict=False it surfaces as a failed
    CompletedGeneration while every other request's output is
    token-identical to an all-valid stream."""
    cfg, model, params = qwen
    good = _prompts(cfg, 3, 8)
    ref = ContinuousEngine(model, params, num_slots=2, max_len=32,
                           max_new_cap=8)
    want = [list(o.tokens) for o in ref.generate_many(good,
                                                      max_new_tokens=6)]

    ce = ContinuousEngine(model, params, num_slots=2, max_len=32,
                          max_new_cap=8)
    long_prompt = _prompts(cfg, 1, 30, seed=9)[0]   # 30 + 6 > 32
    rids = [ce.reserve_rid() for _ in range(4)]
    ce.submit(rids[0], good[0], 6)
    assert ce.submit(rids[1], long_prompt, 6, strict=False) is False
    ce.submit(rids[2], good[1], 6)
    ce.submit(rids[3], good[2], 6)
    done = ce.run()
    assert done[rids[1]].failed and "max_len" in done[rids[1]].failed
    assert done[rids[1]].n_steps == 0
    got = [list(done[r].tokens) for r in (rids[0], rids[2], rids[3])]
    assert got == want
    assert ce.stats.n_rejected == 1 and ce.stats.n_completed == 3


def test_interleaved_waves_keep_results_separate(qwen):
    """run() returns only the requests completed since the last call."""
    cfg, model, params = qwen
    ce = ContinuousEngine(model, params, num_slots=2, max_len=48,
                          max_new_cap=8)
    a = ce.generate_many(_prompts(cfg, 2, 8, seed=1), max_new_tokens=4)
    b = ce.generate_many(_prompts(cfg, 2, 8, seed=2), max_new_tokens=4)
    assert {o.rid for o in a}.isdisjoint({o.rid for o in b})


# --- Gateway integration ----------------------------------------------------


class _RoundRobinPolicy:
    """Deterministic mixed-action router (cycles the whole space)."""

    def route(self, states, slo, context):
        from repro.routing.policy import RoutingDecision
        acts = np.arange(states.shape[0]) % 5
        return RoutingDecision(actions=acts.astype(np.int64))


@pytest.fixture(scope="module")
def small_testbed():
    from repro.core.config import RouterConfig, TestbedConfig
    from repro.core.offline_log import build_testbed
    cfg = TestbedConfig(n_train=40, n_eval=16, n_paragraphs=60,
                        router=RouterConfig(n_epochs=1))
    return cfg, build_testbed(cfg)


def test_gateway_mixed_stream_shares_inflight_batch(qwen, small_testbed):
    """A mixed quality_first/cheap stream routed across all 5 actions
    serves through ONE shared in-flight batch: more requests concurrent
    than any single action bucket, and zero cache reallocation."""
    from repro.data.tokenizer import HashTokenizer
    from repro.routing import ContinuousEngineBackend, Gateway, Request

    mcfg, model, params = qwen
    tcfg, (data, index, pipe, train_log, eval_log) = small_testbed
    engine = ContinuousEngine(model, params, num_slots=8, max_len=160,
                              max_new_cap=8, sync_every=4)
    backend = ContinuousEngineBackend(
        engine, HashTokenizer(mcfg.vocab_size), index,
        max_prompt_len=128, max_new_tokens=4)
    gw = Gateway(_RoundRobinPolicy(), backend, router_cfg=tcfg.router,
                 index=index, max_batch=10, adaptive_refusal=False)
    reqs = [Request(qid=q.qid, question=q,
                    slo=("cheap" if i % 2 else "quality_first"))
            for i, q in enumerate(data.questions[:10])]
    stats = gw.serve(reqs)

    assert stats.served == 10
    # every action bucket was routed (2 requests each incl. refuse)
    assert dict(stats.action_counts) == {a: 2 for a in range(5)}
    # 8 generating requests (refusals short-circuit) with at most 2 per
    # bucket — the 8 concurrent slots prove cross-bucket interleaving
    assert engine.stats.max_concurrent == 8
    assert engine.stats.n_admitted == 8
    # one engine lifetime, one slot-cache allocation (+ prefill scratch)
    assert engine.stats.cache_allocations == 2
    # refusals never reached the engine
    assert stats.action_counts[4] == 2 and engine.stats.n_completed == 8


def test_gateway_survives_overlength_requests(qwen, small_testbed):
    """Regression for the Gateway-killing failure: a backend whose
    prompts can't fit the engine's max_len serves the whole batch as
    per-request rejected (refused) outcomes — the stream stays alive,
    every request is accounted, nothing raises."""
    from repro.data.tokenizer import HashTokenizer
    from repro.routing import ContinuousEngineBackend, Gateway, Request

    mcfg, model, params = qwen
    tcfg, (data, index, *_rest) = small_testbed
    # max_len 100 < max_prompt_len 128 + max_new 4: every generating
    # request overflows; refusal-routed ones never reach the engine
    engine = ContinuousEngine(model, params, num_slots=4, max_len=100,
                              max_new_cap=4)
    backend = ContinuousEngineBackend(
        engine, HashTokenizer(mcfg.vocab_size), index,
        max_prompt_len=128, max_new_tokens=4)
    gw = Gateway(_RoundRobinPolicy(), backend, router_cfg=tcfg.router,
                 index=index, max_batch=10, adaptive_refusal=False)
    reqs = [Request(qid=q.qid, question=q, slo="quality_first")
            for q in data.questions[:10]]
    stats = gw.serve(reqs)
    assert stats.served == 10                 # nothing killed the batch
    assert engine.stats.n_rejected == 8       # all generating requests
    assert engine.stats.n_admitted == 0
    # capacity rejections counted apart from the 2 policy refusals
    assert stats.rejected == 8
    outcomes_refused = stats.action_counts    # all 5 actions accounted
    assert sum(outcomes_refused.values()) == 10
    assert all(np.isfinite(v) for v in (stats.avg_reward,))


def test_continuous_backend_outcomes_match_bucketed_accounting(qwen,
                                                               small_testbed):
    """execute_mixed produces the same outcome structure (refusal cost,
    hallucination flags, per-request token accounting) as the padded
    backend's bucketed path."""
    from repro.data.tokenizer import HashTokenizer
    from repro.routing import ContinuousEngineBackend, EngineBackend
    from repro.routing.registry import get_action_space

    mcfg, model, params = qwen
    tcfg, (data, index, *_rest) = small_testbed
    space = get_action_space()
    tok = HashTokenizer(mcfg.vocab_size)
    qs = data.questions[:4]

    cont = ContinuousEngineBackend(
        ContinuousEngine(model, params, num_slots=4, max_len=160,
                         max_new_cap=4),
        tok, index, max_prompt_len=128, max_new_tokens=4)
    padded = EngineBackend(Engine(model, params, max_len=160), tok, index,
                           max_prompt_len=128, max_new_tokens=4)

    for action in (space[1], space[4]):          # guarded k=5, refuse
        a = cont.execute_batch(qs, action)
        b = padded.execute_batch(qs, action)
        for oa, ob in zip(a, b):
            assert oa.qid == ob.qid and oa.action == ob.action
            assert oa.refused == ob.refused
            assert oa.hallucinated == ob.hallucinated
            assert oa.cost_tokens == ob.cost_tokens


@pytest.mark.slow
def test_gateway_trained_policy_end_to_end(small_testbed):
    """End-to-end: trained MLP policy + continuous backend over a
    40-request mixed-SLO stream (multiple micro-batches, slot reuse
    across Gateway.step calls)."""
    from repro.core.actions import SLO_PROFILES
    from repro.core.policy import train_policy
    from repro.data.tokenizer import HashTokenizer
    from repro.routing import ContinuousEngineBackend, Gateway, MLPPolicy, \
        Request

    cfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tcfg, (data, index, pipe, train_log, eval_log) = small_testbed
    tr = train_policy(train_log,
                      train_log.rewards(SLO_PROFILES["quality_first"]),
                      tcfg.router, objective="argmax_ce")
    engine = ContinuousEngine(model, params, num_slots=6, max_len=256,
                              max_new_cap=8, sync_every=4)
    backend = ContinuousEngineBackend(
        engine, HashTokenizer(cfg.vocab_size), index,
        max_prompt_len=192, max_new_tokens=6)
    gw = Gateway(MLPPolicy(tr.params, tcfg.router), backend,
                 router_cfg=tcfg.router, index=index, max_batch=16,
                 adaptive_refusal=True, base_refusal_share=0.5)
    reqs = [Request(qid=q.qid, question=q,
                    slo=("cheap" if i % 2 else "quality_first"))
            for i, q in enumerate(data.questions[:40])]
    stats = gw.serve(reqs)
    assert stats.served == 40
    assert engine.stats.cache_allocations == 2
    assert np.isfinite(stats.avg_reward)
    assert engine.stats.n_completed == engine.stats.n_admitted


# --- int8 KV cache (cfg.kv_quant_int8) --------------------------------------


def test_int8_kv_cache_greedy_parity(qwen):
    """Wiring test for the int8 KV path: with `kv_quant_int8=True` the
    executor's slot caches hold int8 payloads + f16 scales (about half
    the bytes), and greedy decode through the continuous engine stays
    token-identical to the bf16/f32 cache at smoke-model scale —
    mid-stream admission, mixed prompt lengths and all."""
    cfg, model, params = qwen
    prompts = _prompts(cfg, 5, 8, seed=3) + _prompts(cfg, 2, 14, seed=4)

    def run(c):
        m = build_model(c)
        ce = ContinuousEngine(m, params, num_slots=3, max_len=48,
                              max_new_cap=8, sync_every=2)
        outs = ce.generate_many(prompts, max_new_tokens=6)
        return [_trim(o.tokens) for o in sorted(outs, key=lambda o: o.rid)], ce

    base, _ = run(cfg)
    qcfg = dataclasses.replace(cfg, kv_quant_int8=True)
    quant, ce = run(qcfg)
    assert base == quant

    # the slot cache really is quantized: int8 keys + f16 scales
    leaves = jax.tree_util.tree_leaves_with_path(ce.executor._cache)
    dtypes = {jax.tree_util.keystr(p): l.dtype for p, l in leaves}
    assert any(str(d) == "int8" for d in dtypes.values())
    assert any(str(d) == "float16" for d in dtypes.values())
    assert not any("'k'" in k and str(d) in ("float32", "bfloat16")
                   for k, d in dtypes.items() if k.endswith("'k'"))


def test_int8_kv_cache_schema_halves_bytes(qwen):
    """The quantized schema's cache footprint is ~half the dense one."""
    cfg, model, params = qwen
    from repro.models.transformer import init_cache_schema

    def nbytes(schema):
        import numpy as _np
        sizes = {"int8": 1, "float16": 2, "bfloat16": 2, "float32": 4,
                 "int32": 4, "bool": 1}
        return sum(int(_np.prod(s.shape)) * sizes[s.dtype]
                   for s in jax.tree_util.tree_leaves(
                       schema, is_leaf=lambda x: hasattr(x, "shape")))

    dense = nbytes(init_cache_schema(cfg, 8, 256))
    quant = nbytes(init_cache_schema(
        dataclasses.replace(cfg, kv_quant_int8=True), 8, 256))
    # f32 smoke dtype: int8+f16 scales ~ 0.27x; vs bf16 it would be ~0.53x
    assert quant < 0.6 * dense
