"""Beyond-paper modules: SLO-conditioned policy + profile interpolation."""
import numpy as np
import pytest

from repro.core.actions import SLO_PROFILES
from repro.core.conditioned import (conditioned_actions, interpolate,
                                    profile_vector, train_conditioned)
from repro.core.config import RouterConfig, TestbedConfig
from repro.core.metrics import evaluate_actions
from repro.core.offline_log import build_testbed


@pytest.fixture(scope="module")
def testbed():
    cfg = TestbedConfig(n_train=250, n_eval=80, n_paragraphs=250,
                        router=RouterConfig(n_epochs=10))
    return cfg, build_testbed(cfg)


def test_interpolation_endpoints():
    a, b = SLO_PROFILES["quality_first"], SLO_PROFILES["cheap"]
    np.testing.assert_allclose(profile_vector(interpolate(a, b, 0.0)),
                               profile_vector(a))
    np.testing.assert_allclose(profile_vector(interpolate(a, b, 1.0)),
                               profile_vector(b))
    mid = profile_vector(interpolate(a, b, 0.5))
    np.testing.assert_allclose(
        mid, 0.5 * (profile_vector(a) + profile_vector(b)))


def test_conditioned_policy_adapts_to_profile(testbed):
    """One policy must behave differently under different SLO inputs."""
    cfg, (_, _, _, train_log, eval_log) = testbed
    profiles = [SLO_PROFILES["quality_first"], SLO_PROFILES["cheap"]]
    result, ccfg = train_conditioned(train_log, profiles, cfg.router,
                                     n_interp=1)
    acts_q = conditioned_actions(result, ccfg, eval_log, profiles[0])
    acts_c = conditioned_actions(result, ccfg, eval_log, profiles[1])
    # the cheap conditioning must refuse more than the quality one
    assert (acts_c == 4).mean() > (acts_q == 4).mean()
    rep_q = evaluate_actions(eval_log, acts_q, profiles[0], "q")
    rep_c = evaluate_actions(eval_log, acts_c, profiles[1], "c")
    assert rep_q.cost > rep_c.cost  # quality profile spends more
