"""MoE: ragged_dot path vs expert-parallel shard_map path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import get_config
from repro.models.moe import moe_apply_ragged, moe_schema
from repro.models.schema import init_from_schema


def _setup():
    cfg = get_config("dbrx-132b", "smoke")
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    from repro.models.transformer import _retag_dtype
    schema = _retag_dtype(moe_schema(cfg), "float32")
    p = init_from_schema(jax.random.PRNGKey(0), schema)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32) * 0.5
    return cfg, p, x


def test_ragged_routes_topk_mass():
    cfg, p, x = _setup()
    y, aux = moe_apply_ragged(p, x, cfg)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(aux) > 0


def test_ep_matches_ragged_on_single_shard():
    """With a 1x1 mesh and no capacity drops the EP path must equal the
    ragged path exactly (same math, different dispatch)."""
    cfg, p, x = _setup()
    y_ref, aux_ref = moe_apply_ragged(p, x, cfg)

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    from repro.launch.moe_parallel import make_ep_moe_fn
    moe_fn = make_ep_moe_fn(mesh, capacity_factor=8.0)  # no drops
    with mesh:
        y_ep, aux_ep = jax.jit(lambda p, x: moe_fn(p, x, cfg))(p, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                               rtol=2e-4, atol=2e-4)
    assert float(aux_ref) == pytest.approx(float(aux_ep), rel=1e-4)


def test_ep_capacity_drops_are_bounded():
    """Tiny capacity must still return finite output (dropped tokens pass
    through the residual unchanged = zero delta)."""
    cfg, p, x = _setup()
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    from repro.launch.moe_parallel import make_ep_moe_fn
    moe_fn = make_ep_moe_fn(mesh, capacity_factor=0.25)
    with mesh:
        y, _ = jax.jit(lambda p, x: moe_fn(p, x, cfg))(p, x)
    assert np.all(np.isfinite(np.asarray(y)))
    # dropped tokens contribute less mass than the no-drop path
    y_full, _ = moe_apply_ragged(p, x, cfg)
    assert float(jnp.abs(y).sum()) <= float(jnp.abs(y_full).sum()) * 1.5


def test_router_load_balance_loss_uniform_is_low():
    """Aux loss is minimized (≈ coef) for a uniform router."""
    from repro.core.config import MoEConfig
    from repro.models.moe import router_probs
    e = MoEConfig(n_experts=8, top_k=2, d_ff_expert=64)
    T, d = 512, 32
    p = {"router": jnp.zeros((d, 8))}
    x = jax.random.normal(jax.random.PRNGKey(0), (T, d))
    _, _, aux = router_probs(p, x, e)
    # perfectly uniform probs: E * sum(pe*fe) = E * E*(1/E^2) = 1
    assert float(aux) == pytest.approx(e.load_balance_coef, rel=0.3)
