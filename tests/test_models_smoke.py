"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED same-family variant
(≤2 layers, d_model ≤ 512, ≤4 experts) and runs one forward + one train
step + a prefill/decode roundtrip on CPU, asserting output shapes and
finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.schema import init_from_schema
from repro.models.transformer import loss_fn
from repro.training.optimizer import OptConfig, adamw_init_schema
from repro.training.steps import make_train_step

B, S = 2, 32


def _inputs(cfg, key):
    s_txt = S - (cfg.n_modality_tokens if cfg.modality == "vision" else 0)
    out = {"tokens": jax.random.randint(key, (B, s_txt), 0, cfg.vocab_size)}
    if cfg.modality == "vision":
        out["image_emb"] = 0.02 * jax.random.normal(
            key, (B, cfg.n_modality_tokens, cfg.modality_embed_dim),
            jnp.bfloat16)
    if cfg.modality == "audio":
        out["audio_emb"] = 0.02 * jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return out, jax.random.randint(key, (B, s_txt), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_config(arch, "smoke")
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inputs, labels = _inputs(cfg, jax.random.PRNGKey(1))
    logits, extras = model.train_logits(params, inputs)
    s_total = S if cfg.modality != "vision" else S
    assert logits.shape == (B, s_total, cfg.padded_vocab), logits.shape
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    loss = loss_fn(logits, labels, extras=extras)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, "smoke")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_state = init_from_schema(key, adamw_init_schema(model.schema))
    step = jax.jit(make_train_step(model, OptConfig(lr=1e-3, warmup_steps=1,
                                                    total_steps=10)))
    inputs, labels = _inputs(cfg, jax.random.PRNGKey(2))
    batch = dict(inputs, labels=labels)
    p1, o1, m1 = step(params, opt_state, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert int(o2["step"]) == 2
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l.astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: a.astype(jnp.float32)
                               - b.astype(jnp.float32), p1, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, "smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inputs, _ = _inputs(cfg, jax.random.PRNGKey(3))
    cache = model.init_cache(B, 64)
    pre = dict(inputs)
    pre["tokens"] = inputs["tokens"][:, :8]
    logits, cache = model.prefill(params, pre, cache)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.padded_vocab
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    logits2, cache = model.decode(params, {"tokens": tok}, cache)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert int(cache["pos"][0]) == 8 + (cfg.n_modality_tokens
                                        if cfg.modality == "vision" else 0) + 1
