"""Host-side paged KV-cache bookkeeping as a pure unit: allocator
alloc/free/refcount invariants, prefix-cache copy-on-write forks,
pool-exhaustion back-pressure, and prefix-hash determinism.

No JAX anywhere — :mod:`repro.serving.paged` is numpy/stdlib only, so
these tests cover the allocator exactly as the engine drives it."""
import random
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import pytest

from repro.serving.paged import PagePool, hash_prefix_pages

PS = 4  # page size for most tests


def toks(n, seed=0):
    rng = random.Random(seed)
    return [rng.randrange(4, 500) for _ in range(n)]


# -- hashing ------------------------------------------------------------

def test_prefix_hash_determinism_across_instances():
    t = toks(20)
    a = hash_prefix_pages(t, PS)
    b = hash_prefix_pages(list(t), PS)
    assert a == b and len(a) == 5


def test_prefix_hash_is_cumulative():
    """A page hash covers the whole prefix, not just its own tokens:
    same page-1 tokens after a different page 0 must not collide."""
    t1 = toks(8, seed=1)
    t2 = toks(4, seed=2) + t1[4:]
    h1 = hash_prefix_pages(t1, PS)
    h2 = hash_prefix_pages(t2, PS)
    assert t1[4:] == t2[4:]
    assert h1[1] != h2[1]


def test_prefix_hash_full_pages_only():
    assert hash_prefix_pages(toks(7), PS) == hash_prefix_pages(toks(7)[:4],
                                                               PS)


# -- allocator invariants ----------------------------------------------

def test_alloc_free_refcount_invariants():
    pool = PagePool(8, PS)
    plan = pool.plan(toks(8), limit=3)  # 8+3+1=12 -> 3 blocks
    assert plan is not None and len(plan.pages) == 3
    assert len(set(plan.pages)) == 3
    assert all(pool.refcount(p) == 1 for p in plan.pages)
    assert pool.pages_in_use == 3 and pool.n_free() == 5
    pool.release(plan)
    assert pool.pages_in_use == 0 and pool.n_free() == 8


def test_double_release_is_an_error():
    pool = PagePool(4, PS)
    plan = pool.plan(toks(4), limit=0)
    pool.release(plan)
    with pytest.raises(AssertionError):
        pool.release(plan)


def test_registered_pages_survive_release():
    """Commit registers full prompt pages in the prefix cache (one cache
    ref), so releasing the slot keeps them resident for future hits."""
    pool = PagePool(8, PS)
    plan = pool.plan(toks(8), limit=0)
    pool.commit(plan)
    assert [pool.refcount(p) for p in plan.pages[:2]] == [2, 2]
    pool.release(plan)
    assert [pool.refcount(p) for p in plan.pages[:2]] == [1, 1]
    assert pool.cached_pages() == 2
    # and a later identical prompt hits them
    assert pool.preview_hit_tokens(toks(8)) == 7  # capped at plen-1


# -- prefix sharing / copy-on-write ------------------------------------

def test_cow_fork_shares_full_pages_and_forks_partial():
    pool = PagePool(16, PS)
    t = toks(12)
    first = pool.plan(t, limit=3)
    pool.commit(first)
    # identical prompt: hits all 3 full pages, p0 capped at 11 -> 2
    # full shared pages + a CoW fork of page 2
    second = pool.plan(t, limit=3)
    assert second.p0 == 11 and second.shared == 2 and second.cow
    assert second.pages[:2] == first.pages[:2]          # borrowed
    assert second.pages[2] != first.pages[2]            # forked
    assert pool.refcount(first.pages[0]) == 3            # cache+2 slots
    assert second.gather_src == first.pages[:3]          # incl. CoW src
    assert second.write_mask == [False, False, True, False]
    pool.release(second)
    assert pool.refcount(first.pages[0]) == 2


def test_divergent_suffix_shares_only_common_prefix():
    pool = PagePool(16, PS)
    t = toks(12)
    first = pool.plan(t, limit=0)
    pool.commit(first)
    other = t[:8] + toks(4, seed=9)
    second = pool.plan(other, limit=0)
    assert second.p0 == 8 and second.shared == 2 and not second.cow
    assert second.pages[:2] == first.pages[:2]
    assert second.pages[2] != first.pages[2]


def test_same_group_no_share_before_commit():
    """Two identical prompts planned before either commits must not
    share (the second's gather would read pages the first's prefill has
    not yet written)."""
    pool = PagePool(16, PS)
    t = toks(8)
    a = pool.plan(t, limit=0)
    b = pool.plan(t, limit=0)
    assert b.shared == 0 and not set(a.pages) & set(b.pages)


# -- exhaustion / back-pressure ----------------------------------------

def test_pool_exhaustion_defers_not_crashes():
    pool = PagePool(4, PS)
    a = pool.plan(toks(8), limit=3)   # needs 3 pages
    assert a is not None
    b = pool.plan(toks(8, seed=5), limit=3)
    assert b is None                   # only 1 page left -> defer
    assert pool.pages_in_use == 3      # failed plan took nothing
    pool.release(a)
    assert pool.plan(toks(8, seed=5), limit=3) is not None


def test_eviction_frees_only_unreferenced_cache_pages():
    pool = PagePool(4, PS)
    held = pool.plan(toks(8), limit=0)   # 2 prompt pages + 1 slack
    pool.commit(held)                    # both registered, still held
    # needs 3 pages; only 1 free and every cached page is slot-held
    assert pool.plan(toks(8, seed=5), limit=3) is None
    pool.release(held)                   # cache refs remain
    nxt = pool.plan(toks(8, seed=5), limit=3)
    assert nxt is not None and pool.n_evicted >= 1


def test_lru_eviction_order():
    pool = PagePool(8, PS)
    for seed in (1, 2):                        # register a then b
        p = pool.plan(toks(8, seed=seed), limit=0)
        pool.commit(p)
        pool.release(p)
    # a re-planned: borrowing its first page bumps it in the LRU
    c = pool.plan(toks(8, seed=1), limit=0)
    assert c.shared == 1
    pool.commit(c)
    pool.release(c)
    # 4 cached + 4 free; 7 blocks forces exactly 3 evictions, oldest
    # first: a's page 1 and both of b's go, a's bumped page 0 survives
    big = pool.plan(toks(24, seed=3), limit=0)
    assert big is not None and pool.n_evicted == 3
    assert pool.preview_hit_tokens(toks(8, seed=2)) == 0
    assert pool.preview_hit_tokens(toks(8, seed=1)) == 4


# -- partitions ---------------------------------------------------------

def test_partitioned_pools_are_isolated():
    pool = PagePool(8, PS, partitions=2)
    a = pool.plan(toks(8), limit=0, partition=0)
    pool.commit(a)
    assert all(p < 4 for p in a.pages)
    # same prompt on the other partition: no cross-partition hits
    b = pool.plan(toks(8), limit=0, partition=1)
    assert b.shared == 0 and all(p >= 4 for p in b.pages)
    assert pool.preview_hit_tokens(toks(8), partition=0) == 7
    assert pool.preview_hit_tokens(toks(8), partition=1) == 0


def test_partition_size_must_divide():
    with pytest.raises(ValueError):
        PagePool(9, PS, partitions=2)


def test_sharing_disabled():
    pool = PagePool(8, PS, prefix_sharing=False)
    a = pool.plan(toks(8), limit=0)
    pool.commit(a)
    assert pool.preview_hit_tokens(toks(8)) == 0
    b = pool.plan(toks(8), limit=0)
    assert b.shared == 0 and not b.cow
