"""Unified Router API: registry round-trips, policy adapters, Gateway
parity with the legacy Scheduler, and budget back-pressure."""
import numpy as np
import pytest

from repro.core import actions as legacy
from repro.core.config import RouterConfig, TestbedConfig
from repro.core.metrics import fixed_action_report
from repro.core.offline_log import build_testbed
from repro.core.policy import policy_actions, train_policy
from repro.routing import (ActionSpace, ConditionedPolicy, FixedPolicy,
                           Gateway, MLPPolicy, Request, SimulatorBackend,
                           get_action_space, get_slo_profile,
                           list_action_spaces, register_action_space,
                           register_slo_profile, slo_profile_from_config)
from repro.routing.registry import SLO_PROFILES as REGISTRY_PROFILES
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def testbed():
    cfg = TestbedConfig(n_train=200, n_eval=80, n_paragraphs=200,
                        router=RouterConfig(n_epochs=10))
    return cfg, build_testbed(cfg)


@pytest.fixture(scope="module")
def cheap_policy(testbed):
    cfg, (_, _, _, train_log, _) = testbed
    return MLPPolicy.train(
        train_log, train_log.rewards(get_slo_profile("cheap")),
        cfg.router, objective="argmax_ce")


# --- registry ---------------------------------------------------------------


def test_paper_space_matches_legacy_constants():
    space = get_action_space()
    assert space.name == "paper5"
    assert space.actions == legacy.ACTIONS
    assert space.n_actions == legacy.N_ACTIONS == len(space)
    assert space.refuse_action == legacy.REFUSE_ACTION
    assert [a.k for a in space] == [2, 5, 10, 5, 0]


def test_action_space_config_roundtrip():
    space = get_action_space("paper5")
    again = ActionSpace.from_config(space.to_config())
    assert again == space

    custom = ActionSpace.from_config({
        "name": "deep7",
        "actions": [{"k": k, "mode": "guarded"} for k in (1, 2, 4, 8, 16, 32)]
                   + [{"k": 0, "mode": "refuse"}]})
    register_action_space(custom)
    try:
        assert get_action_space("deep7") is custom
        assert custom.refuse_action == 6
        assert "deep7" in list_action_spaces()
        with pytest.raises(ValueError):
            register_action_space(custom)          # duplicate name
    finally:
        from repro.routing.registry import _ACTION_SPACES
        _ACTION_SPACES.pop("deep7", None)


def test_action_space_validation():
    with pytest.raises(ValueError):               # refuse must have k=0
        ActionSpace("bad", (legacy.Action(0, 3, "refuse"),))
    with pytest.raises(ValueError):               # idx must match position
        ActionSpace("bad", (legacy.Action(1, 3, "guarded"),))
    with pytest.raises(ValueError):               # unknown mode
        ActionSpace("bad", (legacy.Action(0, 3, "creative"),))
    with pytest.raises(KeyError):
        get_action_space("nope")


def test_slo_profile_registry_roundtrip_and_legacy_view():
    p = slo_profile_from_config(dict(
        name="latency_paranoid", w_acc=0.5, w_cost=1.5, w_hall=0.2,
        w_ref=0.2, w_ref_wrong=0.4))
    register_slo_profile(p)
    try:
        assert get_slo_profile("latency_paranoid") is p
        # the legacy dict is a live view of the registry
        assert legacy.SLO_PROFILES["latency_paranoid"] is p
        with pytest.raises(ValueError):
            register_slo_profile(p)
    finally:
        REGISTRY_PROFILES.pop("latency_paranoid", None)
    assert "latency_paranoid" not in legacy.SLO_PROFILES
    # profiles pass through resolution unchanged
    assert get_slo_profile(p) is p
    with pytest.raises(KeyError):
        get_slo_profile("latency_paranoid")


# --- policy adapters --------------------------------------------------------


def test_mlp_policy_matches_policy_actions(testbed, cheap_policy):
    cfg, (_, _, _, _, eval_log) = testbed
    d = cheap_policy.route(eval_log.states)
    ref = policy_actions(cheap_policy.params, eval_log.states, cfg.router)
    np.testing.assert_array_equal(d.actions, ref)
    assert d.logits.shape == (eval_log.n, legacy.N_ACTIONS)
    assert d.confidences.shape == (eval_log.n,)
    assert ((0 < d.confidences) & (d.confidences <= 1)).all()


def test_fixed_policy_decision():
    pol = FixedPolicy(2)
    d = pol.route(np.zeros((7, 4), np.float32))
    assert (d.actions == 2).all() and d.n == 7
    assert d.policy == "fixed(a2)"


def test_conditioned_policy_route(testbed):
    cfg, (_, _, _, train_log, eval_log) = testbed
    profiles = [get_slo_profile("quality_first"), get_slo_profile("cheap")]
    pol = ConditionedPolicy.train(train_log, profiles, cfg.router, n_interp=0)
    a_q = pol.route(eval_log.states, "quality_first").actions
    a_c = pol.route(eval_log.states, "cheap").actions
    # per-request SLO list must agree with the uniform call
    mixed = pol.route(eval_log.states, ["cheap"] * eval_log.n).actions
    np.testing.assert_array_equal(mixed, a_c)
    # conditioning must matter (cheap refuses more)
    assert (a_c == legacy.REFUSE_ACTION).mean() >= \
        (a_q == legacy.REFUSE_ACTION).mean()
    with pytest.raises(ValueError):
        pol.route(eval_log.states)                # SLO is required


# --- Gateway ----------------------------------------------------------------


def _requests(data, n, slo):
    return [Request(qid=q.qid, question=q, slo=slo)
            for q in data.questions[-n:]]


def test_gateway_parity_with_legacy_scheduler(testbed, cheap_policy):
    """The Scheduler path and a directly-constructed Gateway must agree
    bit-for-bit: same actions, rewards, and cap history for same seeds."""
    cfg, (data, index, pipe, train_log, _) = testbed
    reqs = _requests(data, 80, "cheap")

    sched = Scheduler(pipe, cheap_policy.params, cfg.router, max_batch=16,
                      adaptive_refusal=True, base_refusal_share=0.5)
    sched.submit(list(reqs))
    s_stats = sched.drain()

    gw = Gateway(cheap_policy, SimulatorBackend(pipe), router_cfg=cfg.router,
                 index=index, max_batch=16, adaptive_refusal=True,
                 base_refusal_share=0.5)
    g_stats = gw.serve(list(reqs))

    assert dict(g_stats.action_counts) == dict(s_stats.action_counts)
    assert g_stats.served == s_stats.served == 80
    assert g_stats.avg_reward == pytest.approx(s_stats.avg_reward, abs=1e-12)
    assert g_stats.refusal_cap_history == s_stats.refusal_cap_history


def test_gateway_fixed_policy_matches_offline_report(testbed):
    """FixedPolicy(a1) through the Gateway reproduces the logged fixed
    baseline: deterministic simulator + same reward equation."""
    cfg, (data, index, pipe, train_log, eval_log) = testbed
    gw = Gateway(FixedPolicy(1), SimulatorBackend(pipe),
                 router_cfg=cfg.router, index=index, adaptive_refusal=False)
    stats = gw.serve(_requests(data, 80, "quality_first"))
    assert dict(stats.action_counts) == {1: 80}
    rep = fixed_action_report(eval_log, 1, get_slo_profile("quality_first"))
    assert stats.avg_reward == pytest.approx(rep.reward, abs=1e-6)


def test_gateway_budget_backpressure(testbed, cheap_policy):
    """Refusal-cap tightening still fires through the new path."""
    cfg, (data, index, pipe, _, _) = testbed
    reqs = _requests(data, 80, "cheap")

    free = Gateway(cheap_policy, SimulatorBackend(pipe),
                   router_cfg=cfg.router, index=index, max_batch=16,
                   adaptive_refusal=False)
    capped = Gateway(cheap_policy, SimulatorBackend(pipe),
                     router_cfg=cfg.router, index=index, max_batch=16,
                     adaptive_refusal=True, base_refusal_share=0.5)
    free.serve(list(reqs))
    capped.serve(list(reqs))

    assert capped.refusal_share <= 0.55 + 1e-9
    assert capped.refusal_share <= free.refusal_share
    # budget burn tightened the per-batch cap below the base share
    assert min(capped.stats.refusal_cap_history) < 0.5
    # decisions carry the applied constraint
    d = capped.stats.decisions[-1]
    assert "refusal_cap" in d.constraints
    assert np.isfinite(capped.stats.avg_reward)


def test_gateway_mixed_slo_batch(testbed, cheap_policy):
    """Per-request SLOs in one micro-batch: rewards use each request's
    own profile."""
    cfg, (data, index, pipe, _, _) = testbed
    reqs = _requests(data, 20, "cheap")
    for r in reqs[::2]:
        r.slo = "quality_first"
    gw = Gateway(cheap_policy, SimulatorBackend(pipe), router_cfg=cfg.router,
                 index=index, max_batch=20, adaptive_refusal=False)
    stats = gw.serve(reqs)
    assert stats.served == 20
    assert np.isfinite(stats.avg_reward)
