"""End-to-end behaviour tests: the paper's headline findings must hold
on the full pipeline (corpus -> BM25 -> simulator sweep -> policy
training -> evaluation)."""
import numpy as np
import pytest

from repro.core.config import RouterConfig, TestbedConfig
from repro.core.experiment import run_experiment


@pytest.fixture(scope="module")
def results():
    # the canonical testbed (same as benchmarks/table1): N=200 eval,
    # 800 train — the configuration the calibration targets
    cfg = TestbedConfig()
    res, extras, logs = run_experiment(cfg, verbose=False)
    rows = {(r["slo"], r["method"]): r for r in res.rows}
    return rows, extras, logs


def test_best_fixed_is_a_cheap_guarded_action(results):
    rows, _, _ = results
    for slo in ("quality_first", "cheap"):
        method = [m for (s, m) in rows if s == slo and m.startswith("best-fixed")]
        assert method, rows.keys()
        # paper: best fixed action is a conservative guarded one (a0)
        assert method[0] in ("best-fixed(a0)", "best-fixed(a1)")


def test_fixed_baseline_is_strong_under_quality(results):
    """Paper abstract: 'a strong fixed baseline performs competitively'."""
    rows, _, _ = results
    bf = [r for (s, m), r in rows.items()
          if s == "quality_first" and m.startswith("best-fixed")][0]
    ce = rows[("quality_first", "argmax_ce")]
    assert abs(ce["reward"] - bf["reward"]) < 0.1


def test_cheap_slo_refusal_collapse(results):
    """Paper §6.2: cheap + Argmax-CE collapses to refusal."""
    rows, _, _ = results
    ce = rows[("cheap", "argmax_ce")]
    bf = [r for (s, m), r in rows.items()
          if s == "cheap" and m.startswith("best-fixed")][0]
    assert ce["refuse"] > 0.6
    assert ce["acc"] < 0.2
    assert ce["reward"] < bf["reward"] - 0.03


def test_wt_objective_instability(results):
    """Paper §6.3: the weighted objective shifts the action mix and does
    not beat the best fixed baseline under quality_first."""
    rows, _, _ = results
    wt = rows[("quality_first", "argmax_ce_wt")]
    ce = rows[("quality_first", "argmax_ce")]
    bf = [r for (s, m), r in rows.items()
          if s == "quality_first" and m.startswith("best-fixed")][0]
    assert wt["reward"] <= bf["reward"] + 1e-6
    # action distribution differs markedly from argmax-CE
    d = np.abs(np.array(wt["action_dist"]) - np.array(ce["action_dist"]))
    assert d.sum() > 0.2


def test_learned_policies_save_cost_under_quality(results):
    rows, _, _ = results
    ce = rows[("quality_first", "argmax_ce")]
    base = rows[("quality_first", "baseline(a1)")]
    assert ce["cost"] < base["cost"]
