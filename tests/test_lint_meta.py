"""Meta-test: the merged tree itself is lint-clean, via the exact
invocation CI runs, plus CLI behaviour (exit codes, formats, --rules).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.cli import main

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"


def test_src_tree_has_zero_unsuppressed_findings():
    """The CI gate: ``python -m repro.analysis src --fail-on-findings``
    exits 0 on the repo's own source with all seven rules active."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src",
         "--fail-on-findings", "--format", "json"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(proc.stdout)
    assert data["findings"] == []
    assert data["n_files"] > 50
    # the allow-list is auditable: every suppression carries a reason
    assert all(f["suppress_reason"] for f in data["suppressed"])


def test_cli_exit_1_on_findings_with_flag(capsys):
    rc = main([str(FIXTURES / "rpl001_clock.py"), "--fail-on-findings"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "RPL001" in out and "findings" in out


def test_cli_exit_0_without_flag(capsys):
    rc = main([str(FIXTURES / "rpl001_clock.py")])
    assert rc == 0
    assert "RPL001" in capsys.readouterr().out


def test_cli_rules_filter(capsys):
    rc = main([str(FIXTURES / "rpl001_clock.py"), "--rules", "RPL002",
               "--fail-on-findings"])
    assert rc == 0              # RPL001 disabled, nothing else fires
    with pytest.raises(SystemExit):
        main([str(FIXTURES / "rpl001_clock.py"), "--rules", "RPL999"])


def test_cli_json_schema(capsys):
    rc = main([str(FIXTURES / "rpl001_clock.py"), "--format", "json"])
    assert rc == 0
    data = json.loads(capsys.readouterr().out)
    assert set(data) == {"n_files", "counts", "findings", "suppressed"}
    assert data["counts"].get("RPL001", 0) == 3
    for f in data["findings"] + data["suppressed"]:
        assert set(f) == {"rule", "path", "line", "col", "message",
                          "severity", "suppressed", "suppress_reason"}


def test_cli_list_rules(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in ("RPL001", "RPL002", "RPL003", "RPL004", "RPL005",
                "RPL006", "RPL007"):
        assert rid in out


def test_cli_budget_override_flag(capsys):
    # a 0.001 MiB budget makes every kernel site over-budget
    rc = main([str(REPO / "src" / "repro" / "kernels"),
               "--budget-mib", "0.001", "--rules", "RPL004",
               "--fail-on-findings"])
    assert rc == 1
    assert "exceeds" in capsys.readouterr().out


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
