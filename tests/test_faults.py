"""Fault-tolerant serving plane: deterministic chaos injection,
circuit breakers, slot quarantine, deadline enforcement, and
deadline-aware retries.

Scheduler-level tests run the pure numpy FakeExecutor from
test_host_scheduler through :class:`ChaosExecutor` seams; gateway-level
tests use minimal scripted streaming backends.  Everything here is
seeded/virtual-time deterministic — that's the point of the chaos
machinery.  The real-executor NaN-detection test (JAX smoke model)
lives at the bottom.
"""
import numpy as np
import pytest

from test_host_scheduler import FakeExecutor, arith_gen, expected, _prompts

from repro.core.errors import (CircuitOpenError, FaultTimeoutError,
                               TransientFaultError)
from repro.retrieval.hybrid import (CircuitBreaker, IndexRetriever,
                                    RetrievalCache, collect_breakers,
                                    resolve_retrievers,
                                    retrieve_with_fallback)
from repro.routing import FixedPolicy, Request
from repro.serving.continuous import ContinuousEngine
from repro.serving.faults import (ChaosInjector, FaultPlan, FaultSpec,
                                  RetryPolicy)
from repro.serving.streaming import AdmissionConfig, AsyncGateway
from repro.serving.traffic import VirtualClock

pytestmark = pytest.mark.chaos

ZERO_STATE = lambda qs: np.zeros((len(qs), 1))


# --- injector ---------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(site="s", kind="explode")
    with pytest.raises(ValueError):
        FaultSpec(site="s", kind="raise", count=0)
    with pytest.raises(ValueError):
        FaultSpec(site="s", kind="raise", prob=0.0)


def test_injector_window_and_replay():
    plan = FaultPlan(specs=(
        FaultSpec(site="a", kind="raise", start=2, count=3),
        FaultSpec(site="b", kind="raise", start=0, count=-1, prob=0.5),
    ), seed=7)

    def run():
        inj = ChaosInjector(plan)
        hits_a = [inj.fire("a") is not None for _ in range(8)]
        hits_b = [inj.fire("b") is not None for _ in range(20)]
        return hits_a, hits_b, [r[:3] for r in inj.fire_log]

    ha, hb, log = run()
    # window [2, 5) exactly
    assert ha == [False, False, True, True, True, False, False, False]
    # probabilistic spec fires a thinned subset, deterministically
    assert 0 < sum(hb) < 20
    assert run() == (ha, hb, log)       # same seed => same schedule


def test_injector_unarmed_is_noop():
    inj = ChaosInjector(FaultPlan())
    assert not inj.armed
    assert inj.fire("anything") is None
    assert inj.fire_log == [] and inj.calls("anything") == 0


def test_apply_error_kinds():
    inj = ChaosInjector(FaultPlan(specs=(
        FaultSpec(site="s", kind="raise"),)), sleep=lambda s: None)
    with pytest.raises(TransientFaultError):
        inj.apply_error_kind(FaultSpec(site="s", kind="raise"), "s")
    with pytest.raises(FaultTimeoutError):
        inj.apply_error_kind(FaultSpec(site="s", kind="timeout"), "s")
    assert inj.apply_error_kind(
        FaultSpec(site="s", kind="latency", latency_s=0.1), "s") is True
    assert inj.apply_error_kind(
        FaultSpec(site="s", kind="stall"), "s") is False


# --- circuit breaker --------------------------------------------------------


def test_breaker_state_machine():
    b = CircuitBreaker(window=8, min_calls=4, failure_threshold=0.5,
                       cooldown=3, half_open_probes=1)
    for _ in range(4):
        assert b.allow()
        b.record_failure()
    assert b.state == "open" and b.n_trips == 1
    # cooldown - 1 = 2 denials; the 3rd attempted call is the probe
    assert not b.allow() and not b.allow()
    assert b.allow() and b.state == "half_open"
    b.record_success()
    assert b.state == "closed" and b.failure_rate() == 0.0
    # probe FAILURE reopens instead
    for _ in range(4):
        b.record_failure()
    assert b.state == "open"
    assert not b.allow() and not b.allow() and b.allow()
    b.record_failure()
    assert b.state == "open" and b.n_trips == 3


def test_breaker_window_evicts_old_failures():
    b = CircuitBreaker(window=4, min_calls=4, failure_threshold=0.75)
    for _ in range(3):
        b.record_failure()
    for _ in range(4):          # pushes the failures out of the window
        b.record_success()
    assert b.failure_rate() == 0.0 and b.state == "closed"


def test_breaker_wall_clock_cooldown():
    """Clock mode: open→half-open paces on elapsed time, not on denied
    calls — hammering before the cooldown never reaches a probe, and a
    single sparse call after it does."""
    t = [0.0]
    b = CircuitBreaker(window=8, min_calls=4, failure_threshold=0.5,
                       cooldown=3, half_open_probes=1,
                       clock=lambda: t[0], cooldown_s=5.0)
    for _ in range(4):
        assert b.allow()
        b.record_failure()
    assert b.state == "open"
    # many attempts inside the cooldown window: all denied (the
    # call-count path would already have probed after 3)
    t[0] = 4.9
    for _ in range(10):
        assert not b.allow()
    assert b.state == "open" and b.n_denied == 10
    # first call at/past the deadline is the probe, however sparse
    t[0] = 5.0
    assert b.allow() and b.state == "half_open"
    b.record_success()
    assert b.state == "closed"
    # reopen; a probe failure re-arms the clock from the new trip
    for _ in range(4):
        b.record_failure()
    t[0] = 11.0
    assert b.allow()            # 11.0 - 5.0 > 5s: probe
    b.record_failure()
    assert b.state == "open"
    t[0] = 15.9
    assert not b.allow()        # only 4.9s since the re-trip at 11.0
    t[0] = 16.0
    assert b.allow() and b.state == "half_open"


def test_breaker_clock_mode_validation():
    with pytest.raises(ValueError, match="come together"):
        CircuitBreaker(clock=lambda: 0.0)
    with pytest.raises(ValueError, match="come together"):
        CircuitBreaker(cooldown_s=1.0)
    with pytest.raises(ValueError, match="> 0"):
        CircuitBreaker(clock=lambda: 0.0, cooldown_s=0.0)


def test_breaker_call_count_mode_unchanged_by_default():
    """The default breaker stays clock-free: no clock attribute use,
    cooldown counted in denied calls exactly as before."""
    b = CircuitBreaker(window=8, min_calls=4, failure_threshold=0.5,
                       cooldown=3)
    assert b.clock is None and b.cooldown_s is None
    for _ in range(4):
        b.record_failure()
    assert not b.allow() and not b.allow()
    assert b.allow() and b.state == "half_open"


def test_breaker_random_walk_invariants_deterministic():
    """State-machine property test: under a seeded random call
    sequence the breaker (a) only ever occupies its three states,
    (b) never lets a call through while open pre-cooldown, and (c)
    replays bit-identically."""

    def walk(seed):
        rng = np.random.default_rng(seed)
        b = CircuitBreaker(window=8, min_calls=4, failure_threshold=0.5,
                           cooldown=3)
        trace = []
        for _ in range(300):
            allowed = b.allow()
            if allowed:
                (b.record_failure if rng.random() < 0.4
                 else b.record_success)()
            trace.append((allowed, b.state))
            assert b.state in ("closed", "open", "half_open")
            if not allowed:
                assert b.state == "open" or b.state == "half_open"
        return trace, b.n_trips, b.n_denied

    t1 = walk(3)
    assert t1 == walk(3)
    assert t1[1] > 0            # the walk actually exercised trips


# --- retriever seams: breaker + fallback + cache guard ----------------------


class FlakyIndex:
    """Index stub whose topk raises while ``broken``."""

    def __init__(self, texts):
        self.texts = texts
        self.broken = False
        self.calls = 0

    def topk(self, query, k):
        self.calls += 1
        if self.broken:
            raise TransientFaultError(f"flaky down ({query!r})")
        ids = np.arange(min(k, len(self.texts)))
        return ids, np.ones(len(ids), np.float32)


def _suite(cache_size=8, **breaker_kw):
    texts = [f"passage {i}" for i in range(6)]
    flaky = FlakyIndex(texts)
    retrievers = {"bm25": IndexRetriever("bm25", FlakyIndex(texts)),
                  "dense": IndexRetriever("dense", flaky)}
    wrapped, cache = resolve_retrievers(
        retrievers, None, cache_size=cache_size,
        breaker_kw=dict(window=4, min_calls=2, failure_threshold=0.5,
                        cooldown=2, **breaker_kw))
    return wrapped, cache, flaky


def test_fallback_degrades_and_trips_breaker():
    wrapped, cache, flaky = _suite()
    flaky.broken = True
    # min_calls=2, threshold 0.5: the second failure trips the breaker
    for i in range(2):
        ps, degraded = retrieve_with_fallback(wrapped, "dense",
                                              f"q{i}", 2)
        assert degraded and len(ps) == 2
    brk = collect_breakers(wrapped)["dense"]
    assert brk.state == "open" and brk.n_trips == 1
    # while open (pre-cooldown) lookups degrade WITHOUT touching the
    # dead service
    calls_before = flaky.calls
    _, degraded = retrieve_with_fallback(wrapped, "dense", "q-open", 2)
    assert degraded and flaky.calls == calls_before
    assert brk.n_denied >= 1


def test_failed_lookup_never_cached_fallback_under_own_key():
    """The cache-poisoning regression: a failed primary lookup must not
    land in the cache under the primary's key, and the fallback result
    is cached under the FALLBACK's key only."""
    wrapped, cache, flaky = _suite()
    flaky.broken = True
    retrieve_with_fallback(wrapped, "dense", "q0", 2)
    keys = list(cache._d)
    assert all(k[1] != "dense" for k in keys), keys
    assert any(k[1] == "bm25" for k in keys)
    # recovery: the service heals and healthy results are cached under
    # dense's own key again
    flaky.broken = False
    for i in range(8):
        retrieve_with_fallback(wrapped, "dense", f"r{i}", 2)
    assert collect_breakers(wrapped)["dense"].state == "closed"
    ps, degraded = retrieve_with_fallback(wrapped, "dense", "fresh", 2)
    assert not degraded
    assert any(k[1] == "dense" for k in cache._d)


def test_fallback_missing_or_self_raises_transient():
    wrapped, _, flaky = _suite(cache_size=0)
    flaky.broken = True
    with pytest.raises(TransientFaultError):
        retrieve_with_fallback(wrapped, "dense", "q", 2, fallback="dense")
    with pytest.raises(TransientFaultError):
        retrieve_with_fallback(wrapped, "dense", "q", 2, fallback="nope")


def test_retrieval_cache_hits_bypass_open_breaker():
    """A cached result stays servable while the breaker underneath is
    open — the cache fronts the breaker by construction."""
    wrapped, cache, flaky = _suite()
    ps0 = wrapped["dense"].passages("warm", 2)      # healthy, cached
    flaky.broken = True
    for i in range(3):                               # trip the breaker
        with pytest.raises(Exception):
            wrapped["dense"].passages(f"cold{i}", 2)
    assert collect_breakers(wrapped)["dense"].state == "open"
    assert wrapped["dense"].passages("warm", 2) == ps0


# --- scheduler: chaos seams, quarantine, watchdog, deadlines ---------------


class FaultableFake(FakeExecutor):
    """FakeExecutor + the optional health extensions the scheduler
    drives (deactivate so cancelled slots actually stop)."""

    def deactivate(self, slots):
        for s in slots:
            self._active[s] = False


def chaos_engine(plan, gen_fn=arith_gen, *, clock=None, **kw):
    inj = ChaosInjector(plan, clock=clock)
    eng_kw = {k: kw.pop(k) for k in ("watchdog_syncs", "max_requeues")
              if k in kw}
    fake = FaultableFake(gen_fn, **kw)
    return ContinuousEngine(executor=fake, chaos=inj, clock=clock,
                            **eng_kw), fake, inj


def test_nan_quarantine_peers_token_identical():
    """A NaN-poisoned slot is quarantined and ONLY its request fails;
    the surviving peers' tokens are bit-identical to a no-fault run."""
    prompts = _prompts([3, 4, 5, 6])

    def run(plan):
        eng, fake, inj = chaos_engine(plan, num_slots=4, sync_every=2)
        rids = [eng.reserve_rid() for _ in prompts]
        for rid, p in zip(rids, prompts):
            eng.submit(rid, p, 8)
        done = eng.run()
        return eng, [done[r] for r in rids]

    plan = FaultPlan(specs=(FaultSpec(site="executor.decode", kind="nan",
                                      start=1, count=1, slots=(2,)),))
    eng, outs = run(plan)
    _, clean = run(FaultPlan())
    assert outs[2].failed and outs[2].transient
    assert eng.stats.n_nan_trips == 1 and eng.stats.n_quarantined == 1
    assert eng.quarantined_slots == {2}
    for i in (0, 1, 3):
        assert list(outs[i].tokens) == list(clean[i].tokens)


def test_quarantined_slot_never_readmitted_until_reset():
    plan = FaultPlan(specs=(FaultSpec(site="executor.decode", kind="nan",
                                      start=0, count=1, slots=(0,)),))
    eng, fake, inj = chaos_engine(plan, num_slots=2, sync_every=2)
    outs = eng.generate_many(_prompts([3, 4, 5, 6]), max_new_tokens=8)
    assert eng.quarantined_slots == {0}
    # everything after the trip serves on slot 1 alone
    assert all(not o.failed for o in outs[1:])
    # more traffic: the quarantined slot stays out of the pool, so
    # every admission after the trip sees at most one live request
    eng.generate_many(_prompts([4, 4], seed=3), max_new_tokens=4)
    assert eng.quarantined_slots == {0}
    assert all(c <= 1 for c in list(eng.stats.concurrency_trace)[2:])
    # reset returns it to service: two slots run concurrently again
    assert eng.reset_quarantine() == [0]
    assert eng.quarantined_slots == set()
    eng.generate_many(_prompts([4, 4], seed=4), max_new_tokens=4)
    assert list(eng.stats.concurrency_trace)[-1] == 2


def test_watchdog_quarantines_stalled_slot():
    plan = FaultPlan(specs=(FaultSpec(site="executor.decode",
                                      kind="stall", start=0, count=-1),))
    eng, fake, inj = chaos_engine(plan, num_slots=1, sync_every=2,
                                  watchdog_syncs=3)
    rid = eng.reserve_rid()
    eng.submit(rid, _prompts([4])[0], 8)
    done = eng.run()
    assert done[rid].failed.startswith("watchdog")
    assert done[rid].transient
    assert eng.stats.n_watchdog_trips == 1


def test_all_slots_quarantined_fails_queue_not_hangs():
    """The deadlock guard: with every slot quarantined, queued work is
    failed transiently instead of spinning run() forever."""
    plan = FaultPlan(specs=(FaultSpec(site="executor.decode",
                                      kind="stall", start=0, count=-1),))
    eng, fake, inj = chaos_engine(plan, num_slots=1, sync_every=2,
                                  watchdog_syncs=2)
    r0, r1 = eng.reserve_rid(), eng.reserve_rid()
    eng.submit(r0, _prompts([4])[0], 8)
    eng.submit(r1, _prompts([5])[0], 8)
    done = eng.run()                      # must terminate
    assert done[r0].failed.startswith("watchdog")
    assert done[r1].failed == "all slots quarantined"
    assert done[r1].transient


def test_decode_fault_requeues_then_succeeds():
    plan = FaultPlan(specs=(FaultSpec(site="executor.decode",
                                      kind="raise", start=0, count=1),))
    eng, fake, inj = chaos_engine(plan, num_slots=2, sync_every=2,
                                  max_requeues=1)
    prompts = _prompts([4, 5])
    outs = eng.generate_many(prompts, max_new_tokens=8)
    assert eng.stats.n_exec_faults == 1 and eng.stats.n_requeued == 2
    for p, o in zip(prompts, outs):
        assert not o.failed
        assert list(o.tokens) == expected(arith_gen(p), 8)


def test_decode_fault_without_requeue_fails_transient():
    plan = FaultPlan(specs=(FaultSpec(site="executor.decode",
                                      kind="raise", start=0, count=1),))
    eng, fake, inj = chaos_engine(plan, num_slots=2, sync_every=2)
    outs = eng.generate_many(_prompts([4]), max_new_tokens=8)
    assert outs[0].failed and outs[0].transient


def test_admit_fault_requeues_and_stream_survives():
    plan = FaultPlan(specs=(FaultSpec(site="executor.admit",
                                      kind="raise", start=0, count=1),))
    eng, fake, inj = chaos_engine(plan, num_slots=2, sync_every=2,
                                  max_requeues=1)
    prompts = _prompts([4, 5, 6])
    outs = eng.generate_many(prompts, max_new_tokens=6)
    assert eng.stats.n_exec_faults == 1
    for p, o in zip(prompts, outs):
        assert not o.failed, o
        assert list(o.tokens) == expected(arith_gen(p), 6)


def test_random_chaos_every_request_resolves():
    """Liveness property: under seeded random fault plans every
    submitted request reaches a terminal state (served, transient,
    timed out...) — run() always returns with a full result set."""
    rng = np.random.default_rng(0)
    sites = ["executor.decode", "executor.admit"]
    kinds = ["raise", "stall", "nan"]
    for trial in range(6):
        specs = tuple(
            FaultSpec(site=sites[int(rng.integers(len(sites)))],
                      kind=(k := kinds[int(rng.integers(len(kinds)))]),
                      start=int(rng.integers(0, 4)),
                      count=int(rng.integers(1, 3)),
                      slots=(0,) if k == "nan" else None)
            for _ in range(int(rng.integers(1, 3))))
        # nan/stall only make sense at the decode site
        specs = tuple(s if s.kind == "raise"
                      else FaultSpec(site="executor.decode", kind=s.kind,
                                     start=s.start, count=s.count,
                                     slots=s.slots)
                      for s in specs)
        eng, fake, inj = chaos_engine(
            FaultPlan(specs=specs, seed=trial), num_slots=2,
            sync_every=2, watchdog_syncs=2, max_requeues=1)
        prompts = _prompts([3, 4, 5, 6, 4], seed=trial)
        rids = [eng.reserve_rid() for _ in prompts]
        for rid, p in zip(rids, prompts):
            eng.submit(rid, p, 6)
        done = eng.run()
        assert set(done) == set(rids), (trial, specs)


def test_deadline_cancels_resident_mid_stream():
    t = [0.0]
    eng, fake, _ = chaos_engine(FaultPlan(), clock=lambda: t[0],
                                num_slots=2, sync_every=2)
    r0, r1 = eng.reserve_rid(), eng.reserve_rid()
    p0, p1 = _prompts([4, 5])
    eng.submit(r0, p0, 16, deadline_at=0.5)   # will expire mid-decode
    eng.submit(r1, p1, 4)                     # no deadline
    done = {}
    for _ in range(64):
        if not eng.has_work:
            break
        done.update(eng.poll())
        t[0] += 0.2                           # 3 polls pass the deadline
    done.update(eng.poll())
    assert done[r0].timed_out and done[r0].failed == "deadline exceeded"
    assert not done[r0].transient
    assert not done[r1].failed
    assert list(done[r1].tokens) == expected(arith_gen(p1), 4)
    assert eng.stats.n_timed_out == 1
    # the freed slot serves new work
    out = eng.generate_many([_prompts([3])[0]], max_new_tokens=2)
    assert not out[0].failed


def test_deadline_expires_queued_request():
    t = [0.0]
    eng, fake, _ = chaos_engine(FaultPlan(), clock=lambda: t[0],
                                num_slots=1, sync_every=2)
    r0, r1 = eng.reserve_rid(), eng.reserve_rid()
    p0, p1 = _prompts([4, 5])
    eng.submit(r0, p0, 8)
    eng.submit(r1, p1, 8, deadline_at=0.1)    # dies waiting for the slot
    t[0] = 0.2
    done = eng.run()
    assert not done[r0].failed
    assert done[r1].timed_out
    assert eng.stats.n_timed_out == 1


# --- AsyncGateway: retries, deadline-awareness, fatal-error hardening -------


class ScriptedStreamBackend:
    """Minimal streaming backend: stream_submit consumes a script of
    "ok" / "transient" / "boom" / "pend" entries; "ok" completes
    immediately, "pend" parks the request in flight forever."""

    def __init__(self, script):
        self.script = list(script)
        self.calls = 0
        self.poll_raise = False

    stream_backlog = 0

    def execute_batch(self, questions, action):
        raise NotImplementedError

    def stream_submit(self, question, action, *, deadline_at=0.0):
        self.calls += 1
        step = self.script.pop(0) if self.script else "ok"
        if step == "transient":
            raise TransientFaultError("scripted transient")
        if step == "boom":
            raise RuntimeError("scripted fatal")
        if step == "pend":
            return self.calls, None
        from repro.serving.pipeline import ActionOutcome
        return None, ActionOutcome(
            qid=question.qid, action=action.idx, correct=True,
            refused=False, hallucinated=False, cost_tokens=1.0,
            hit=True, answerable=True, answer="ok")

    def stream_poll(self):
        if self.poll_raise:
            raise RuntimeError("poll blew up")
        return []


def _mk_request(qid=0, deadline_ms=0.0):
    from repro.data.synthetic_squad import Question
    q = Question(qid=qid, text=f"q{qid}", answerable=True,
                 gold_answer="a", gold_pid=0)
    return Request(qid=qid, question=q, slo="quality_first",
                   deadline_ms=deadline_ms)


def _mk_gateway(backend, clock, **kw):
    kw.setdefault("retry", RetryPolicy(max_retries=1, backoff_s=0.05))
    return AsyncGateway(FixedPolicy(1), backend, state_fn=ZERO_STATE,
                        clock=clock.now, **kw)


def test_stream_retry_transient_then_success():
    clock = VirtualClock()
    be = ScriptedStreamBackend(["transient", "ok"])
    gw = _mk_gateway(be, clock)
    h = gw.submit_stream(_mk_request())
    gw.pump()                       # submit fails -> retry scheduled
    assert not h.done() and gw.in_flight == 1
    clock.advance(0.06)
    gw.pump()                       # backoff elapsed -> resubmitted
    assert h.done() and h.result().answer == "ok"
    assert h.retries == 1
    assert gw.stats.retries == 1 and gw.stats.faulted == 0


def test_stream_retry_exhausted_counts_faulted():
    clock = VirtualClock()
    be = ScriptedStreamBackend(["transient", "transient"])
    gw = _mk_gateway(be, clock)
    h = gw.submit_stream(_mk_request())
    gw.pump()
    clock.advance(0.06)
    gw.pump()
    assert h.done() and h.outcome.transient and h.outcome.refused
    assert gw.stats.retries == 1 and gw.stats.faulted == 1


def test_stream_retry_never_past_deadline():
    """A retry whose backoff alone would land past the request's
    deadline is not scheduled — the request fails immediately."""
    clock = VirtualClock()
    be = ScriptedStreamBackend(["transient", "ok"])
    gw = _mk_gateway(be, clock,
                     retry=RetryPolicy(max_retries=3, backoff_s=0.2))
    h = gw.submit_stream(_mk_request(deadline_ms=100.0))  # < backoff
    gw.pump()
    assert h.done() and h.outcome.transient
    assert gw.stats.retries == 0 and gw.stats.faulted == 1
    assert be.calls == 1


def test_async_gateway_submit_exception_fails_everything():
    """The silent-hang regression: a non-transient backend exception
    must reject every in-flight handle (result() raises, done() true)
    and make drain_stream return instead of spinning."""
    clock = VirtualClock()
    be = ScriptedStreamBackend(["boom"])
    gw = _mk_gateway(be, clock)
    h0 = gw.submit_stream(_mk_request(0))
    h1 = gw.submit_stream(_mk_request(1))
    with pytest.raises(RuntimeError, match="scripted fatal"):
        gw.pump()
    assert isinstance(gw.failed, RuntimeError)
    assert h0.done() and h1.done()
    for h in (h0, h1):
        with pytest.raises(RuntimeError, match="scripted fatal"):
            h.result(timeout=0)
    assert gw.in_flight == 0
    gw.drain_stream()               # returns immediately, no hang
    # post-mortem submissions are rejected immediately too
    h2 = gw.submit_stream(_mk_request(2))
    assert h2.done() and h2.error is gw.failed


def test_async_gateway_thread_death_stops_cleanly():
    """Background-thread variant: the serving thread dies on a poll
    exception; stop() must return, handles must be rejected."""
    import time as _time
    be = ScriptedStreamBackend(["pend"])     # stays in flight until
    be.poll_raise = True                     # the poll explosion
    gw = AsyncGateway(FixedPolicy(1), be, state_fn=ZERO_STATE)
    gw.start(idle_sleep_s=1e-4)
    h = gw.submit_stream(_mk_request())
    deadline = _time.monotonic() + 5.0
    while not h.done() and _time.monotonic() < deadline:
        _time.sleep(1e-3)
    gw.stop(timeout=5.0)            # must not block on the dead thread
    assert h.done()
    with pytest.raises(RuntimeError, match="poll blew up"):
        h.result(timeout=0)
    assert gw.failed is not None


def test_no_fault_parity_features_on_vs_off_simulator():
    """No-fault parity: with no faults armed, retries-enabled vs
    retries-disabled gateways produce identical outcomes and stats
    over the simulator service model."""
    from repro.core.config import RouterConfig, TestbedConfig
    from repro.core.offline_log import build_testbed
    from repro.routing import SimulatorBackend
    from repro.serving.traffic import (LoadGenerator, PoissonProcess,
                                       build_trace)

    cfg = TestbedConfig(n_train=20, n_eval=8, n_paragraphs=40,
                        router=RouterConfig(n_epochs=1))
    data, index, pipe, *_ = build_testbed(cfg)

    trace_qs = data.questions[:8]

    def run(retry):
        clock = VirtualClock()
        be = SimulatorBackend(pipe, stream_slots=4, service_polls=2,
                              clock=clock.now)
        gw = AsyncGateway(FixedPolicy(2), be, state_fn=ZERO_STATE,
                          clock=clock.now, deadline_ms=300.0,
                          retry=retry)
        trace = build_trace(trace_qs, PoissonProcess(80.0, seed=0), 32,
                            deadline_ms=300.0)
        gen = LoadGenerator(gw, trace)
        rep = gen.run_virtual(clock)
        outcomes = [(h.outcome.answer, h.outcome.correct,
                     h.outcome.refused, h.shed, h.latency_ms)
                    for h in gen.last_handles]
        return rep.as_dict(), outcomes, gw.stats.served, gw.stats.shed

    on = run(RetryPolicy(max_retries=2))
    off = run(None)
    assert on == off
    assert on[0]["degraded"] == 0 and on[0]["retries"] == 0
    assert on[0]["faulted"] == 0 and on[2] > 0


# --- real executor: device-side NaN detection -------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import build_model

    mcfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                               dtype="float32")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_real_executor_nan_detection_quarantines(smoke_model):
    """NaN params poison the decode logits; the executor's on-device
    detector must flag the slots and the scheduler must quarantine them
    (requests fail transiently, nothing hangs)."""
    import jax

    model, params = smoke_model
    bad_params = jax.tree_util.tree_map(lambda x: x * np.nan, params)
    eng = ContinuousEngine(model, bad_params, num_slots=2, max_len=32,
                           max_new_cap=8, sync_every=2)
    rids = [eng.reserve_rid() for _ in range(2)]
    for rid in rids:
        eng.submit(rid, [5, 6, 7], 8)
    done = eng.run()
    assert set(done) == set(rids)
    assert all(done[r].failed and done[r].transient for r in rids)
    assert eng.stats.n_nan_trips == 2
    assert eng.quarantined_slots == {0, 1}


def test_real_executor_health_checks_off_no_quarantine(smoke_model):
    """health_checks=False disables the detector: NaN logits decode to
    garbage but nothing is quarantined (the parity escape hatch)."""
    import jax

    from repro.serving.executor import SingleDeviceExecutor

    model, params = smoke_model
    bad_params = jax.tree_util.tree_map(lambda x: x * np.nan, params)
    ex = SingleDeviceExecutor(model, bad_params, num_slots=2, max_len=32,
                              max_new_cap=8, sync_every=2,
                              health_checks=False)
    eng = ContinuousEngine(executor=ex)
    outs = eng.generate_many([[5, 6, 7]], max_new_tokens=4)
    assert not outs[0].failed
    assert eng.stats.n_nan_trips == 0 and eng.quarantined_slots == set()


def test_real_executor_healthy_run_parity_with_health_checks(smoke_model):
    """On a healthy model the NaN detector must be a pure observer:
    greedy tokens with health_checks on == off, bit for bit."""
    from repro.serving.executor import SingleDeviceExecutor

    model, params = smoke_model
    prompts = [[5, 6, 7], [9, 4, 11, 2]]

    def run(flag):
        ex = SingleDeviceExecutor(model, params, num_slots=2, max_len=32,
                                  max_new_cap=8, sync_every=2,
                                  health_checks=flag)
        eng = ContinuousEngine(executor=ex)
        return [list(o.tokens)
                for o in eng.generate_many(prompts, max_new_tokens=6)]

    assert run(True) == run(False)


def test_no_fault_parity_continuous_backend(smoke_model):
    """Acceptance: with no FaultPlan armed, the hardened open-loop
    stack over the REAL continuous engine (health checks on, breakers
    armed, retry policy installed) is outcome- and report-identical to
    a features-off run (health_checks=False executor, retry=None)."""
    import dataclasses

    from repro.configs import get_config
    from repro.core.config import RetrievalConfig
    from repro.data.synthetic_squad import SyntheticSquad
    from repro.data.tokenizer import HashTokenizer
    from repro.retrieval.bm25 import BM25Index
    from repro.routing import FixedPolicy
    from repro.routing.engine_backend import ContinuousEngineBackend
    from repro.serving.executor import SingleDeviceExecutor
    from repro.serving.streaming import AsyncGateway
    from repro.serving.traffic import (LoadGenerator, PoissonProcess,
                                       VirtualClock, build_trace)

    model, params = smoke_model
    mcfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                               dtype="float32")
    data = SyntheticSquad(n_paragraphs=40, n_questions=8, seed=0)
    index = BM25Index.build([p.text for p in data.paragraphs],
                            RetrievalConfig(vocab_hash_dim=1024))

    def run(hardened):
        clock = VirtualClock()
        executor = None
        if not hardened:
            executor = SingleDeviceExecutor(
                model, params, num_slots=2, max_len=48 + 4,
                max_new_cap=4, sync_every=2, prefill_batch=2,
                health_checks=False)
        backend = ContinuousEngineBackend.create(
            model, params, HashTokenizer(mcfg.vocab_size), index,
            executor=executor, num_slots=2, max_prompt_len=48,
            max_new_tokens=4, sync_every=2, clock=clock.now)
        gw = AsyncGateway(
            FixedPolicy(2), backend,
            state_fn=lambda qs: np.zeros((len(qs), 1)),
            clock=clock.now, deadline_ms=500.0,
            retry=RetryPolicy(max_retries=2, backoff_s=0.02)
            if hardened else None)
        trace = build_trace(data.questions, PoissonProcess(60.0, seed=0),
                            12, deadline_ms=500.0)
        gen = LoadGenerator(gw, trace)
        rep = gen.run_virtual(clock, service_quantum_s=0.01)
        outs = [(h.outcome.answer, h.outcome.correct, h.outcome.refused,
                 getattr(h.outcome, "degraded", False), h.shed)
                for h in gen.last_handles]
        return rep.as_dict(), outs

    rep_on, outs_on = run(True)
    rep_off, outs_off = run(False)
    assert outs_on == outs_off
    assert rep_on == rep_off
    assert rep_on["degraded"] == rep_on["retries"] == rep_on["faulted"] == 0
