"""RPL004 fixture: VMEM budget, unbound dims, masked tails.

Parsed, never executed — the names only need to typecheck as AST.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masked_kernel(x_ref, o_ref, *, block: int, n: int):
    pos = jax.lax.broadcasted_iota(jnp.int32, (8, block), 1)
    o_ref[...] = jnp.where(pos < n, x_ref[...], 0.0)


def _outer_kernel(x_ref, o_ref, *, block: int, n: int):
    # the mask lives one call down — requires transitive following
    _masked_kernel(x_ref, o_ref, block=block, n=n)


def _unmasked_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def ok_small_masked(x):
    # ~16 KiB working set; kernel masks its tail via iota
    return pl.pallas_call(
        _masked_kernel,
        grid=(x.shape[1] // 128,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, i))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)


def ok_transitive_mask(x):
    # iota is inside a helper the kernel calls
    return pl.pallas_call(
        _outer_kernel,
        grid=(x.shape[1] // 128,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (0, i))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)


def ok_divisibility_assert(x):
    assert x.shape[0] % 4096 == 0
    return pl.pallas_call(
        _unmasked_kernel,
        grid=(x.shape[0] // 4096,),
        in_specs=[pl.BlockSpec((128, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((128, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)


def bad_over_budget_and_unmasked(x):
    # (4096*1024 in + 4096*1024 out) * 4 B * 2 buffers = 64 MiB >> 16;
    # AND the kernel has no iota mask, the wrapper no divisibility
    # assert -> two findings on this call
    return pl.pallas_call(
        _unmasked_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((4096, 1024), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((4096, 1024), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((16384, 1024), jnp.float32),
    )(x)


def bad_unbound_dim(x, mystery_dim):
    return pl.pallas_call(
        _masked_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((mystery_dim, 128), lambda i: (0, i))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
    )(x)
