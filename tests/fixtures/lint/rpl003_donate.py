"""RPL003 fixture: use-after-donate and mesh/out_shardings cases."""
import jax


def train_step(params, opt_state, batch):
    return params, opt_state, {}


def bad_use_after_donate(params, opt_state, batch):
    step = jax.jit(train_step, donate_argnums=(0, 1))
    new_params, new_opt = step(params, opt_state, batch)
    return params.mean()             # finding: params was donated


def good_rebind(params, opt_state, batch):
    step = jax.jit(train_step, donate_argnums=(0, 1))
    params, opt_state = step(params, opt_state, batch)
    return params                    # rebound at the call site: fine


def good_store_between(params, opt_state, batch):
    step = jax.jit(train_step, donate_argnums=(0, 1))
    out, _ = step(params, opt_state, batch), None
    params = out[0]
    return params                    # reassigned before the read: fine


class BadExecutor:
    """Donated-callable registry crosses methods; mesh without
    out_shardings."""

    def __init__(self, mesh, decode_fn):
        self.mesh = mesh
        # finding (out_shardings): class owns self.mesh, jit unpinned
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))

    def decode(self, params, cache):
        out, cache2 = self._decode(params, cache)
        return cache.pos             # finding: cache was donated


class GoodExecutor:
    def __init__(self, mesh, decode_fn, shardings):
        self.mesh = mesh
        self._decode = jax.jit(decode_fn, donate_argnums=(1,),
                               out_shardings=shardings)

    def decode(self, params, cache):
        out, cache = self._decode(params, cache)
        return cache.pos             # rebound: fine
