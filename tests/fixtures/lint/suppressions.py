"""Suppression-parsing fixture: valid, multi-rule, and bare allows."""
import random
import time


def bare_allow_is_reported():
    time.sleep(0.1)  # repro: allow[RPL001]


def multi_rule_allow():
    # repro: allow[RPL001,RPL002] fixture: one comment, two rules
    return time.time() + random.random()


def wrong_rule_does_not_suppress():
    time.sleep(0.1)  # repro: allow[RPL006] wrong id: RPL001 still fires
