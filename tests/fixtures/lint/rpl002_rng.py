"""RPL002 fixture: unseeded global RNG vs seeded generators."""
import random

import numpy as np


def bad_global_rng():
    a = random.random()              # finding: stdlib global RNG
    b = np.random.rand(3)            # finding: numpy legacy global
    np.random.seed(0)                # finding: global seeding IS the bug
    rng = np.random.default_rng()    # finding: entropy-seeded
    return a, b, rng


def good_seeded(seed):
    rng = np.random.default_rng(seed)
    return rng.normal(size=3)        # instance method: fine
