"""RPL005 fixture: cross-thread writes with and without the lock."""
import threading


class BadWorker:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.done = False

    def start(self):
        def loop():
            while not self.done:
                self.count += 1      # finding: unlocked, shared
        self._t = threading.Thread(target=loop)
        self._t.start()

    def bump(self):
        self.count += 1              # finding: unlocked, shared

    def stop(self):
        self.done = True             # thread only READS done: fine


class GoodWorker:
    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def _run(self):
        with self._lock:
            self.count += 1

    def start(self):
        self._t = threading.Thread(target=self._run)
        self._t.start()

    def bump(self):
        with self._lock:
            self.count += 1
