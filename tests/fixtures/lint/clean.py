"""A module no rule should flag."""
import time


def measure(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0
