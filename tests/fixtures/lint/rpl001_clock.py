"""RPL001 fixture: wall-clock calls vs the injectable seam."""
import time
from datetime import datetime


def bad_wall_clock():
    t0 = time.time()            # finding: wall-clock timestamp
    time.sleep(0.1)             # finding: wall-clock sleep
    now = datetime.now()        # finding: wall-clock timestamp
    return t0, now


def good_interval():
    t0 = time.perf_counter()
    return time.perf_counter() - t0


def good_seam(sleep=None):
    # referencing time.sleep without calling it IS the seam
    do_sleep = sleep or time.sleep
    return do_sleep


def suppressed_ok():
    # repro: allow[RPL001] fixture: preceding-line suppression
    time.sleep(0.001)
    time.sleep(0.002)  # repro: allow[RPL001] fixture: inline suppression
