"""RPL006 fixture: broad handlers, compliant and not."""


def risky(x):
    return x


def bad_swallow(x):
    try:
        return risky(x)
    except Exception:                # finding: silently swallowed
        return None


def bad_bare(x):
    try:
        return risky(x)
    except:                          # noqa: E722  finding: bare except
        pass


def good_reraise(x):
    try:
        return risky(x)
    except Exception as exc:
        raise RuntimeError("mapped into the taxonomy") from exc


def good_counter(stats, x):
    try:
        return risky(x)
    except Exception:
        stats.errors += 1            # counted: fine
        return None


def good_record(log, x):
    try:
        return risky(x)
    except Exception as exc:
        record_failure(log, exc)     # recorded: fine
        return None


def record_failure(log, exc):
    log.append(exc)


def good_narrow(x):
    try:
        return risky(x)
    except KeyError:                 # narrow catch is intent: fine
        return None
