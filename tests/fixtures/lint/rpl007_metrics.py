"""RPL007 fixture: metric names, duplicate registration, clock injection."""
from repro.obs import MetricsRegistry, NullTracer, Tracer


def good_binding(registry):
    served = registry.counter("gateway_served_total", "requests served")
    registry.gauge("queue_depth", "pending requests")
    registry.histogram("request_latency_ms", "per-request latency")
    return served


def bad_name_case(registry):
    return registry.counter("GatewayServed", "camel case")   # finding


def bad_name_dash(registry):
    return registry.gauge("queue-depth", "kebab case")       # finding


def bad_duplicate(registry):
    registry.counter("served_total", "first registration")
    registry.counter("served_total", "second: would raise")  # finding


def good_two_registries(reg_a, reg_b):
    # same name on DIFFERENT registries is fine
    reg_a.counter("served_total", "a's view")
    reg_b.counter("served_total", "b's view")


def good_dynamic_name(registry, breaker_name):
    # f-string names are validated at runtime by the registry
    return registry.counter(f"breaker_{breaker_name}_trips_total", "trips")


def good_clocked(clock):
    tracer = Tracer(clock)
    registry = MetricsRegistry(clock, prefix="repro_")
    return tracer, registry


def good_clock_kwarg(clock):
    return Tracer(clock=clock)


def good_null_tracer():
    return NullTracer()          # no-op tracer never reads a clock


def bad_clockless_tracer():
    return Tracer()              # finding


def bad_clockless_registry():
    return MetricsRegistry()     # finding
