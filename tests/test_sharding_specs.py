"""specs_for_schema on a 2-axis (dp×mp) serve mesh.

Spec resolution only reads the mesh's axis names and shape, so these
tests run on a 1-CPU host against a stub mesh object — no forced
devices needed.  They pin the dp×mp serving contract:

* param leaves with head/FFN/vocab logical axes land on ``model``;
* slot-cache leaves land on ``data`` (batch dim) AND ``model``
  (kv-head dim) — the decode chunk combines both axes;
* nothing that CAN shard on the model axis silently replicates.
"""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model
from repro.models.schema import ParamSpec
from repro.sharding import (leaf_name, model_axis_fallbacks, resolve_spec,
                            specs_for_schema)


def stub_mesh(dp: int, mp: int):
    """Duck-typed mesh: resolve_spec only touches axis_names and
    devices.shape."""
    return SimpleNamespace(axis_names=("data", "model"),
                           devices=np.empty((dp, mp), object))


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                              dtype="float32")
    return cfg, build_model(cfg)


def _leaf_specs(schema, mesh, **kw):
    """{path: (ParamSpec, PartitionSpec)} over a schema tree."""
    import jax
    out = {}
    jax.tree_util.tree_map_with_path(
        lambda path, ps: out.setdefault(leaf_name(path), ps),
        schema, is_leaf=lambda x: isinstance(x, ParamSpec))
    specs = specs_for_schema(schema, mesh, **kw)
    flat = {}
    jax.tree_util.tree_map_with_path(
        lambda path, spec: flat.setdefault(leaf_name(path),
                                           (out[leaf_name(path)], spec)),
        specs, is_leaf=lambda x: isinstance(x, P))
    return flat


def test_param_leaves_land_on_model_axis(qwen):
    cfg, model = qwen
    mesh = stub_mesh(4, 2)
    flat = _leaf_specs(model.schema, mesh, fsdp=False)
    # attention + MLP + embed: the model-capable dims partition on mp=2
    assert "model" in flat["blocks/p0/attn/wq"][1]     # heads
    assert "model" in flat["blocks/p0/attn/wk"][1]     # kv_heads
    assert "model" in flat["blocks/p0/mlp/w_gate"][1]  # d_ff
    assert "model" in flat["blocks/p0/mlp/w_down"][1]  # d_ff
    assert "model" in flat["embed"][1]                 # vocab
    # norms have no model-capable axis: replicated, by design
    assert flat["final_norm"][1] == resolve_spec(
        flat["final_norm"][0], mesh, fsdp=False)
    assert all(e is None for e in flat["final_norm"][1])
    # fsdp=False (serving): no data-axis entries on any weight leaf
    for name, (ps, spec) in flat.items():
        assert not any(e == "data" for e in spec), (name, spec)


def test_nothing_model_capable_silently_replicates(qwen):
    cfg, model = qwen
    sharded, fallbacks = model_axis_fallbacks(model.schema, stub_mesh(4, 2))
    assert not fallbacks, fallbacks
    assert any("attn/wq" in n for n in sharded)
    # a head count whose head_dim fallback is also indivisible IS
    # reported (heads=3 and head_dim=63 both odd on mp=2)
    bad_cfg = dataclasses.replace(cfg, n_heads=3, n_kv_heads=3,
                                  head_dim=63, d_ff=510, vocab_size=500,
                                  vocab_pad_multiple=1)
    bad = build_model(bad_cfg)
    _, bad_fb = model_axis_fallbacks(bad.schema, stub_mesh(4, 2))
    assert any("attn/wq" in n for n in bad_fb), bad_fb


def test_slot_cache_leaves_combine_data_and_model(qwen):
    cfg, model = qwen
    mesh = stub_mesh(4, 2)
    flat = _leaf_specs(model.cache_schema(8, 64), mesh)
    pos_ps, pos_spec = flat["pos"]
    assert tuple(pos_spec) == ("data",)
    k_ps, k_spec = flat["blocks/p0/k"]
    # (layers, batch, seq, kv_heads, head_dim): slots on data, kv heads
    # on model — the dp×mp decode-chunk cache layout
    assert k_ps.axes == ("layers", "batch", "seq", "kv_heads", "head_dim")
    assert tuple(k_spec) == (None, "data", None, "model", None)


def test_indivisible_slots_replicate_gracefully(qwen):
    """5 slots on dp=4: the batch entry falls back to replicated
    rather than erroring — the executor layer is what enforces
    divisibility for the serving slot pool."""
    cfg, model = qwen
    flat = _leaf_specs(model.cache_schema(5, 64), stub_mesh(4, 2))
    assert flat["pos"][1] == resolve_spec(flat["pos"][0], stub_mesh(4, 2))
    assert all(e is None for e in flat["pos"][1])
    # kv heads still ride the model axis even when slots replicate
    assert tuple(flat["blocks/p0/k"][1])[3] == "model"
