"""Serving layer: scheduler (Gateway-backed), error budgets, the
generation engine's termination logic, int8 KV cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import RouterConfig, TestbedConfig
from repro.core.offline_log import build_testbed
from repro.core.policy import train_policy
from repro.core.serving_types import RequestOutcome
from repro.serving.scheduler import Request, Scheduler
from repro.serving.slo_budget import (DEFAULT_TARGETS, SLOBudgetTracker,
                                      SLOTarget)


# --- error budgets ----------------------------------------------------------


def _outcome(**kw):
    base = dict(qid=0, action=0, correct=True, refused=False,
                hallucinated=False, cost_tokens=100.0, answerable=True)
    base.update(kw)
    return RequestOutcome(**base)


def test_budget_burn_and_health():
    t = SLOTarget("refusal", "refusal", 0.0, objective=0.9, window=100)
    tr = SLOBudgetTracker([t])
    for _ in range(95):
        tr.record(_outcome())
    assert tr.states["refusal"].healthy
    for _ in range(20):  # wrong refusals burn the budget
        tr.record(_outcome(refused=True, answerable=True, correct=False))
    rep = tr.report()["refusal"]
    assert not rep.healthy
    assert rep.budget_consumed > 1.0


def test_budget_backpressure_tightens_cap():
    tr = SLOBudgetTracker(DEFAULT_TARGETS)
    base = 0.6
    assert tr.refusal_cap_adjustment(base) == base
    for _ in range(50):
        tr.record(_outcome(refused=True, answerable=True, correct=False))
    assert tr.refusal_cap_adjustment(base) < base


def test_cost_budget_threshold():
    t = SLOTarget("cost", "cost_tokens", 500.0, objective=0.5, window=10)
    tr = SLOBudgetTracker([t])
    for c in (100, 200, 900, 1000):
        tr.record(_outcome(cost_tokens=c))
    assert tr.states["cost"].violation_rate == pytest.approx(0.5)


# --- scheduler --------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    cfg = TestbedConfig(n_train=200, n_eval=80, n_paragraphs=200,
                        router=RouterConfig(n_epochs=10))
    data, index, pipe, train_log, eval_log = build_testbed(cfg)
    from repro.core.actions import SLO_PROFILES
    tr = train_policy(train_log, train_log.rewards(SLO_PROFILES["cheap"]),
                      cfg.router, objective="argmax_ce")
    reqs = [Request(qid=q.qid, question=q, slo="cheap")
            for q in data.questions[-80:]]
    sched = Scheduler(pipe, tr.params, cfg.router, max_batch=16,
                      adaptive_refusal=True, base_refusal_share=0.5)
    sched.submit(reqs)
    stats = sched.drain()
    return sched, stats


def test_scheduler_serves_all(served):
    sched, stats = served
    assert stats.served == 80
    assert sum(stats.action_counts.values()) == 80


def test_scheduler_caps_refusals(served):
    """Adaptive back-pressure keeps refusal share at/below the cap even
    for a collapse-prone cheap policy."""
    sched, stats = served
    ref_share = stats.action_counts.get(4, 0) / stats.served
    assert ref_share <= 0.55 + 1e-9, ref_share
    assert np.isfinite(stats.avg_reward)


def test_budget_report_shapes(served):
    sched, _ = served
    rep = sched.budget.report()
    assert set(rep) == {"refusal", "hallucination", "cost", "error"}


# --- engine termination -----------------------------------------------------


class _ConstModel:
    """Stub model emitting a constant next token (prefill vs decode)."""

    def __init__(self, prefill_tok, decode_tok, vocab=16):
        self.prefill_tok, self.decode_tok, self.vocab = \
            prefill_tok, decode_tok, vocab

    def init_cache(self, B, L):
        return jnp.zeros((1,))

    def _logits(self, tokens, tok):
        B, T = tokens.shape
        return jax.nn.one_hot(jnp.full((B, T), tok), self.vocab)

    def prefill(self, params, batch, cache, moe_fn=None, mla_absorb=False):
        return self._logits(batch["tokens"], self.prefill_tok), cache

    def decode(self, params, batch, cache, moe_fn=None, mla_absorb=False):
        return self._logits(batch["tokens"], self.decode_tok), cache


def test_engine_stops_once_every_sequence_emitted_eos():
    """Regression: the old check required EVERY emitted token to be EOS,
    so generation never early-exited; per-sequence tracking must stop as
    soon as all sequences have emitted EOS at least once — even if the
    model would emit non-EOS tokens afterwards."""
    from repro.data.tokenizer import EOS
    from repro.serving.engine import Engine
    eng = Engine(_ConstModel(prefill_tok=EOS, decode_tok=5), params={})
    res = eng.generate([[4, 5], [6]], max_new_tokens=6)
    assert res.n_steps == 1
    assert res.tokens.shape == (2, 1)
    assert (res.tokens[:, 0] == EOS).all()


def test_engine_runs_full_length_without_eos():
    from repro.serving.engine import Engine
    eng = Engine(_ConstModel(prefill_tok=7, decode_tok=5), params={})
    res = eng.generate([[4, 5], [6]], max_new_tokens=6)
    assert res.n_steps == 6
    assert res.tokens.shape == (2, 6)


# --- int8 KV cache ----------------------------------------------------------


def test_kv_quant_roundtrip_accuracy():
    from repro.serving.kv_quant import dequantize, quantize
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 128)) * 3.0
    q, s = quantize(x)
    y = dequantize(q, s, jnp.float32)
    rel = float(jnp.abs(y - x).max() / jnp.abs(x).max())
    assert rel < 0.02
    assert q.dtype == jnp.int8 and s.dtype == jnp.float16


def test_kv_quant_attention_fidelity():
    """Attention over an int8 cache ≈ attention over the bf16 cache."""
    from repro.models.layers import attention
    from repro.serving.kv_quant import dequantize, quantize
    key = jax.random.PRNGKey(1)
    B, S, H, D = 2, 64, 4, 32
    q = jax.random.normal(key, (B, 1, H, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(3), (B, S, H, D))
    pos = jnp.full((B, 1), S - 1, jnp.int32)
    o_ref = attention(q, k, v, q_pos=pos, causal=False)
    kq, ks = quantize(k)
    vq, vs = quantize(v)
    o_q = attention(q, dequantize(kq, ks, jnp.float32),
                    dequantize(vq, vs, jnp.float32), q_pos=pos, causal=False)
    err = float(jnp.abs(o_q - o_ref).max())
    assert err < 0.05, err


def test_kv_quant_halves_bytes():
    from repro.serving.kv_quant import cache_bytes
    full = cache_bytes(128, 32768, 8, 128, quantized=False)
    quant = cache_bytes(128, 32768, 8, 128, quantized=True)
    assert quant < 0.53 * full
