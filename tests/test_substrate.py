"""Substrate units: retrieval, data, optimizer, checkpoint, OPE, engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import RetrievalConfig
from repro.data.synthetic_squad import SyntheticSquad
from repro.data.tokenizer import HashTokenizer
from repro.retrieval.bm25 import BM25Index


@pytest.fixture(scope="module")
def corpus():
    data = SyntheticSquad(n_paragraphs=200, n_questions=200, seed=2)
    idx = BM25Index.build([p.text for p in data.paragraphs],
                          RetrievalConfig(vocab_hash_dim=2048))
    return data, idx


# --- data invariants -------------------------------------------------------


def test_answerable_gold_in_gold_paragraph(corpus):
    data, _ = corpus
    for q in data.questions:
        if q.answerable:
            assert q.gold_answer in data.paragraphs[q.gold_pid].text


def test_unanswerable_has_no_answer_sentence(corpus):
    data, _ = corpus
    for q in data.questions[:100]:
        if not q.answerable:
            subj = q.text.split(" of ")[1].rstrip(" ?")
            attr = q.text.split("what is the ")[1].split(" of ")[0]
            for p in data.paragraphs:
                if p.subject == subj:
                    assert f"the {attr} of" not in p.text


def test_corpus_deterministic():
    a = SyntheticSquad(n_paragraphs=50, n_questions=20, seed=7)
    b = SyntheticSquad(n_paragraphs=50, n_questions=20, seed=7)
    assert [p.text for p in a.paragraphs] == [p.text for p in b.paragraphs]
    assert [q.text for q in a.questions] == [q.text for q in b.questions]


# --- retrieval -------------------------------------------------------------


def test_bm25_jnp_matches_numpy(corpus):
    _, idx = corpus
    q = "what is the length of river0001 ?"
    qv = idx.query_vector(q)
    s_np = idx.scores_np(qv)
    s_j = np.asarray(idx.scores_batch(jnp.asarray(qv[None])))[0]
    np.testing.assert_allclose(s_np, s_j, rtol=1e-5, atol=1e-5)


def test_bm25_topk_sorted_and_consistent(corpus):
    _, idx = corpus
    ids, scores = idx.topk("what is the origin of empire0002 ?", 10)
    assert len(ids) == 10
    assert all(scores[i] >= scores[i + 1] for i in range(9))
    full = idx.scores_np(idx.query_vector("what is the origin of empire0002 ?"))
    assert scores[0] == pytest.approx(full.max())


def test_bm25_retrieves_gold_more_than_chance(corpus):
    data, idx = corpus
    hits = n = 0
    for q in data.questions:
        if q.answerable:
            ids, _ = idx.topk(q.text, 5)
            texts = [idx.texts[i] for i in ids]
            hits += any(q.gold_answer in t for t in texts)
            n += 1
    assert hits / n > 0.5, hits / n


# --- tokenizer -------------------------------------------------------------


def test_tokenizer_stable_and_bounded():
    tok = HashTokenizer(1000)
    ids = tok.encode("The Length of River0001 is VAL123 .")
    assert ids == tok.encode("the length of river0001 is val123 .")
    assert all(4 <= i < 1000 for i in ids)


# --- optimizer -------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    from repro.training.optimizer import OptConfig, adamw_update
    cfg = OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                    weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = {"m": {"w": jnp.zeros(2)}, "v": {"w": jnp.zeros(2)},
           "step": jnp.zeros((), jnp.int32)}
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_lr_schedule_warmup_and_decay():
    from repro.training.optimizer import OptConfig, lr_at
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] < lrs[1] <= cfg.lr + 1e-9
    assert lrs[-1] == pytest.approx(cfg.lr * cfg.min_lr_frac, rel=1e-2)


# --- checkpoint ------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.configs import get_config
    from repro.models import build_model
    from repro.training.checkpoint import load_checkpoint, save_checkpoint
    cfg = get_config("mamba2-130m", "smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path / "ck", 5, params)
    step, loaded, _ = load_checkpoint(tmp_path / "ck", params)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- OPE -------------------------------------------------------------------


def test_ope_estimators_recover_truth():
    from repro.core.ope import estimator_suite
    rng = np.random.default_rng(0)
    n, d = 2000, 8
    states = rng.standard_normal((n, d))
    base = states @ rng.standard_normal((d, 5)) * 0.3
    rewards = base + rng.standard_normal((n, 5)) * 0.1
    target = rewards.argmax(axis=1)       # strong target policy
    out = estimator_suite(rewards, states, target, seeds=10)
    assert abs(out["snips"]["bias"]) < 0.1
    assert out["dr"]["rmse"] <= out["ips"]["rmse"] * 1.5
    assert abs(out["dr"]["bias"]) < 0.1


# --- serving engine --------------------------------------------------------


def test_engine_generates():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving.engine import Engine
    cfg = get_config("qwen1.5-32b", "smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params)
    res = eng.generate([[5, 6, 7, 8], [9, 10, 11, 12]], max_new_tokens=4)
    assert res.tokens.shape == (2, 4)
    assert res.tokens.dtype == np.int32


# --- sequence packing --------------------------------------------------------


def test_packing_occupancy_and_masks():
    from repro.data.packing import pack_documents
    from repro.data.tokenizer import EOS, PAD
    rng = np.random.default_rng(0)
    docs = [list(rng.integers(10, 90, size=rng.integers(5, 60)))
            for _ in range(200)]
    batches = list(pack_documents(docs, seq_len=64, batch_size=4))
    assert batches, "no batches produced"
    occ = np.mean([b.occupancy for b in batches[:-1]])
    assert occ > 0.99  # full rows except possibly the tail
    for b in batches:
        # labels never predict across document boundaries
        cross = (b.segments[:, :-1] != b.segments[:, 1:]) & \
                (b.labels[:, :-1] != -1)
        assert not cross.any()
        # labels equal next token where unmasked
        m = b.labels[:, :-1] != -1
        np.testing.assert_array_equal(b.labels[:, :-1][m],
                                      b.tokens[:, 1:][m])


def test_packing_vs_padding_flop_savings():
    """Packing should beat naive one-doc-per-row padding occupancy."""
    from repro.data.packing import pack_documents
    from repro.data.tokenizer import PAD
    rng = np.random.default_rng(1)
    docs = [list(rng.integers(10, 90, size=rng.integers(5, 40)))
            for _ in range(100)]
    packed = list(pack_documents(docs, seq_len=64, batch_size=4))
    occ_packed = np.mean([b.occupancy for b in packed])
    occ_padded = np.mean([min(len(d) + 1, 64) / 64 for d in docs])
    assert occ_packed > occ_padded + 0.2
