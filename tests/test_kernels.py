"""Per-kernel shape/dtype sweeps: Pallas (interpret on CPU) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bm25_scores, flash_attention, ssd_chunk_scan
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _key(i):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# BM25
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Q,D,V", [(8, 128, 512), (16, 256, 1024),
                                   (8, 64, 512), (1, 128, 512)])
def test_bm25_matches_ref(Q, D, V):
    qtf = (jax.random.uniform(_key(0), (Q, V)) < 0.02).astype(jnp.float32)
    tf = jnp.round(jax.random.uniform(_key(1), (D, V)) * 4)
    dl = tf.sum(1)
    idf = jax.random.uniform(_key(2), (V,)) + 0.1
    got = bm25_scores(qtf, tf, dl, idf)
    k1, b = 1.2, 0.75
    norm = (k1 * (1 - b + b * dl / (dl.mean() + 1e-6)))[:, None]
    want = ref.bm25_ref(qtf * idf[None], tf, norm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_bm25_matches_index_oracle():
    """Kernel path == BM25Index numpy scoring on the real corpus."""
    from repro.core.config import RetrievalConfig
    from repro.data.synthetic_squad import SyntheticSquad
    from repro.retrieval.bm25 import BM25Index

    data = SyntheticSquad(n_paragraphs=128, n_questions=8, seed=1)
    idx = BM25Index.build([p.text for p in data.paragraphs],
                          RetrievalConfig(vocab_hash_dim=1024))
    queries = [q.text for q in data.questions]
    qv = np.stack([idx.query_vector(q) for q in queries])
    got = np.asarray(bm25_scores(jnp.asarray(qv), jnp.asarray(idx.tf),
                                 jnp.asarray(idx.doc_len),
                                 jnp.asarray(idx.idf)))
    want = np.stack([idx.scores_np(v) for v in qv])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,Dh", [
    (2, 128, 128, 4, 4, 64),
    (1, 256, 256, 4, 2, 32),
    (2, 64, 64, 8, 1, 128),
])
def test_flash_attention_matches_ref(B, Sq, Skv, H, Hkv, Dh, dtype):
    q = jax.random.normal(_key(3), (B, Sq, H, Dh), dtype)
    k = jax.random.normal(_key(4), (B, Skv, Hkv, Dh), dtype)
    v = jax.random.normal(_key(5), (B, Skv, Hkv, Dh), dtype)
    got = flash_attention(q, k, v, block_q=64, block_kv=64)
    G = H // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dh)
    kf = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * H, Skv, Dh)
    vf = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * H, Skv, Dh)
    want = ref.flash_attention_ref(qf, kf, vf).reshape(B, H, Sq, Dh) \
        .transpose(0, 2, 1, 3)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_non_causal():
    B, S, D = 2, 128, 64
    q = jax.random.normal(_key(6), (B, S, D))
    k = jax.random.normal(_key(7), (B, S, D))
    v = jax.random.normal(_key(8), (B, S, D))
    got = flash_attention_pallas(q, k, v, causal=False, block_q=64,
                                 block_kv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_causality_property():
    """Changing future kv must not change past outputs."""
    B, S, D = 1, 128, 32
    q = jax.random.normal(_key(9), (B, S, D))
    k = jax.random.normal(_key(10), (B, S, D))
    v = jax.random.normal(_key(11), (B, S, D))
    o1 = flash_attention_pallas(q, k, v, interpret=True, block_q=64,
                                block_kv=64)
    k2 = k.at[:, 100:].set(7.0)
    v2 = v.at[:, 100:].set(-3.0)
    o2 = flash_attention_pallas(q, k2, v2, interpret=True, block_q=64,
                                block_kv=64)
    np.testing.assert_allclose(np.asarray(o1[:, :100]),
                               np.asarray(o2[:, :100]), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# SSD chunk scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [32, 64, 128])
@pytest.mark.parametrize("B,S,H,hd,G,N", [
    (2, 256, 4, 32, 2, 16),
    (1, 128, 2, 64, 1, 32),
])
def test_ssd_matches_sequential_ref(B, S, H, hd, G, N, chunk):
    x = jax.random.normal(_key(12), (B, S, H, hd))
    B_ = jax.random.normal(_key(13), (B, S, G, N)) * 0.5
    C_ = jax.random.normal(_key(14), (B, S, G, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(_key(15), (B, S, H)))
    A_log = jnp.zeros(H)
    got = ssd_chunk_scan(x, B_, C_, dt, A_log, chunk=chunk)
    a = -jnp.exp(A_log)
    rep = H // G
    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    Bf = jnp.repeat(B_, rep, 2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    Cf = jnp.repeat(C_, rep, 2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    da = (dt * a).transpose(0, 2, 1).reshape(B * H, S)
    want = ref.ssd_scan_ref(xdt, Bf, Cf, da).reshape(B, H, S, hd) \
        .transpose(0, 2, 1, 3)
    denom = float(jnp.abs(want).max()) + 1e-9
    err = float(jnp.abs(got - want).max()) / denom
    assert err < 5e-5, err


def test_ssd_chunk_invariance():
    """Same result regardless of chunk size (associativity of the scan)."""
    B, S, H, hd, G, N = 1, 256, 2, 32, 1, 16
    x = jax.random.normal(_key(16), (B, S, H, hd))
    B_ = jax.random.normal(_key(17), (B, S, G, N)) * 0.5
    C_ = jax.random.normal(_key(18), (B, S, G, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(_key(19), (B, S, H)))
    A_log = jnp.zeros(H)
    y32 = ssd_chunk_scan(x, B_, C_, dt, A_log, chunk=32)
    y256 = ssd_chunk_scan(x, B_, C_, dt, A_log, chunk=256)
    np.testing.assert_allclose(np.asarray(y32), np.asarray(y256),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_vs_model_chunked_path():
    """Pallas kernel == the model's jnp ssd_chunked implementation."""
    from repro.models.ssm import ssd_chunked
    B, S, H, hd, G, N = 2, 128, 4, 32, 2, 16
    x = jax.random.normal(_key(20), (B, S, H, hd))
    B_ = jax.random.normal(_key(21), (B, S, G, N)) * 0.5
    C_ = jax.random.normal(_key(22), (B, S, G, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(_key(23), (B, S, H)))
    A_log = jnp.zeros(H)
    y_model, _ = ssd_chunked(x, B_, C_, dt, A_log, 64)
    y_kernel = ssd_chunk_scan(x, B_, C_, dt, A_log, chunk=64)
    np.testing.assert_allclose(np.asarray(y_model, np.float32),
                               np.asarray(y_kernel, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_model_integration_pallas_paths():
    """Model forward with use_pallas_{attention,ssd} == jnp paths."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import build_model

    for arch, flag in [("command-r-35b", "use_pallas_attention"),
                       ("mamba2-130m", "use_pallas_ssd")]:
        cfg = dataclasses.replace(get_config(arch, "smoke"),
                                  dtype="float32")
        cfg_k = dataclasses.replace(cfg, **{flag: True})
        model = build_model(cfg)
        model_k = build_model(cfg_k)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                                  cfg.vocab_size)
        l0, _ = model.train_logits(params, {"tokens": toks})
        l1, _ = model_k.train_logits(params, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   rtol=5e-3, atol=5e-3,
                                   err_msg=f"{arch} pallas path diverges")
