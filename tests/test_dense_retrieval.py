"""Dense + hybrid retrieval subsystem.

* the fused Pallas ``dense_topk`` kernel vs the full-matrix oracle
  (interpret-mode shape/block sweeps incl. non-divisible corpus sizes);
* the deterministic hashed n-gram encoder and ``DenseIndex``;
* hybrid fusion determinism (weighted + RRF);
* the bounded LRU retrieval cache and its Gateway stat counters;
* ``hybrid9`` served end-to-end through the Gateway (simulator AND the
  real continuous engine backend);
* a paper5 bit-for-bit regression guard for the ``Action.retriever``
  threading;
* sharded dense retrieval id-identical to single-device on the
  forced-8-device mesh (``-m multidevice``).
"""
import dataclasses
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import RetrievalConfig, RouterConfig, TestbedConfig
from repro.data.synthetic_squad import SyntheticSquad
from repro.kernels import dense_topk
from repro.kernels.dense_topk import dense_topk_pallas
from repro.kernels.ref import dense_topk_ref
from repro.retrieval import (BM25Index, CachedRetriever, DenseIndex,
                             HybridRetriever, IndexRetriever,
                             RetrievalCache, build_retriever_suite,
                             distributed_topk, embed_text,
                             resolve_retrievers)

RCFG = RetrievalConfig(vocab_hash_dim=1024, dense_embed_dim=128)


def _key(i):
    return jax.random.PRNGKey(i)


@pytest.fixture(scope="module")
def corpus():
    data = SyntheticSquad(n_paragraphs=128, n_questions=16, seed=2)
    texts = [p.text for p in data.paragraphs]
    return data, texts, BM25Index.build(texts, RCFG), \
        DenseIndex.build(texts, RCFG)


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Q,D,E,k", [
    (8, 256, 128, 10),
    (8, 200, 128, 10),     # D not a block multiple -> padded tail masked
    (4, 64, 32, 5),
    (1, 37, 64, 3),        # D < block and not a multiple of anything
    (5, 96, 32, 4),        # Q not a block multiple -> padded query rows
    (16, 512, 256, 1),
])
def test_dense_topk_matches_ref(Q, D, E, k):
    q = jax.random.normal(_key(0), (Q, E))
    d = jax.random.normal(_key(1), (D, E))
    gs, gi = dense_topk(q, d, k=k)
    ws, wi = dense_topk_ref(q, d, k)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


@pytest.mark.parametrize("block_q,block_d", [(1, 32), (2, 64), (4, 128),
                                             (8, 256)])
def test_dense_topk_block_invariance(block_q, block_d):
    """The online partial-top-k merge must be invariant to how the doc
    axis is tiled — same ids and scores for every block shape."""
    Q, D, E, k = 8, 256, 64, 7
    q = jax.random.normal(_key(2), (Q, E))
    d = jax.random.normal(_key(3), (D, E))
    gs, gi = dense_topk_pallas(q, d, k=k, block_q=block_q,
                               block_d=block_d, interpret=True)
    ws, wi = dense_topk_ref(q, d, k)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_dense_topk_tie_breaking():
    """Duplicate doc rows produce exact score ties; the kernel's merge
    must resolve them to the lower doc id, like lax.top_k."""
    E = 32
    base = jax.random.normal(_key(4), (8, E))
    d = jnp.concatenate([base, base], axis=0)          # every doc twice
    q = jax.random.normal(_key(5), (1, E))
    gs, gi = dense_topk(q, d, k=4)
    ws, wi = dense_topk_ref(q, d, 4)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_dense_index_topk_boundary_ties():
    """Exact-score ties straddling the k boundary (duplicate docs) must
    resolve to the LOWER doc ids — lax.top_k semantics — not whichever
    tie members a partition happens to keep."""
    doc = "the length of river0001 is val11111"
    idx = DenseIndex.build([doc] * 6 + ["unrelated treaty text"], RCFG)
    ids, scores = idx.topk("length of river0001", 3)
    assert ids.tolist() == [0, 1, 2], ids
    assert scores[0] == scores[1] == scores[2]
    ws, wi = dense_topk_ref(jnp.asarray(idx.encode("length of river0001")
                                        )[None], jnp.asarray(idx.emb), 3)
    np.testing.assert_array_equal(np.asarray(wi)[0], ids)


def test_dense_index_kernel_path_matches_numpy(corpus):
    """DenseIndex.topk_batch (Pallas) == DenseIndex.topk (numpy) on the
    real synthetic corpus."""
    data, texts, _, dense = corpus
    queries = [q.text for q in data.questions]
    ids, scores = dense.topk_batch(queries, k=10)
    for qi, qtext in enumerate(queries):
        want_ids, want_s = dense.topk(qtext, 10)
        np.testing.assert_array_equal(ids[qi], want_ids)
        np.testing.assert_allclose(scores[qi], want_s, rtol=1e-5,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# Encoder + index
# ---------------------------------------------------------------------------


def test_encoder_deterministic_and_normalized():
    v1 = embed_text("the length of river0001 is val123", 128)
    v2 = embed_text("the length of river0001 is val123", 128)
    np.testing.assert_array_equal(v1, v2)
    assert abs(np.linalg.norm(v1) - 1.0) < 1e-6
    # word order matters through the bigram channel
    v3 = embed_text("river0001 of the length val123 is", 128)
    assert not np.allclose(v1, v3)
    assert embed_text("", 128).sum() == 0.0


def test_dense_and_bm25_rank_differently(corpus):
    """Retriever choice is only a real action if the two views rank
    differently somewhere (while both still retrieve the gold doc for
    most answerable questions)."""
    data, texts, bm25, dense = corpus
    diff = 0
    for q in data.questions:
        b, _ = bm25.topk(q.text, 5)
        d, _ = dense.topk(q.text, 5)
        diff += int(set(b.tolist()) != set(d.tolist()))
    assert diff > 0, "dense and bm25 retrieval are identical"


# ---------------------------------------------------------------------------
# Hybrid fusion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["rrf", "weighted"])
def test_hybrid_fusion_deterministic(corpus, method):
    data, texts, bm25, dense = corpus
    hyb = HybridRetriever(
        [IndexRetriever("bm25", bm25), IndexRetriever("dense", dense)],
        texts, method=method)
    for q in data.questions[:8]:
        i1, s1 = hyb.topk(q.text, 5)
        i2, s2 = hyb.topk(q.text, 5)
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(s1, s2)
        assert len(set(i1.tolist())) == len(i1)          # unique docs
        assert (np.diff(s1) <= 1e-9).all()               # descending
        assert hyb.passages(q.text, 5) == [texts[i] for i in i1]


def test_hybrid_fuses_both_views(corpus):
    """A fused top-k draws from the union of the two candidate sets and
    ranks docs both retrievers agree on above single-view docs (RRF)."""
    data, texts, bm25, dense = corpus
    hyb = HybridRetriever(
        [IndexRetriever("bm25", bm25), IndexRetriever("dense", dense)],
        texts, method="rrf")
    q = data.questions[0].text
    # fusion draws from each view's top-(k * candidate_mult) candidates
    b, _ = bm25.topk(q, 10 * hyb.candidate_mult)
    d, _ = dense.topk(q, 10 * hyb.candidate_mult)
    h, _ = hyb.topk(q, 10)
    assert set(h.tolist()) <= set(b.tolist()) | set(d.tolist())
    b, d = b[:3], d[:3]
    both = set(b[:3].tolist()) & set(d[:3].tolist())
    if both:  # docs top-ranked by BOTH views must survive fusion
        assert both <= set(h.tolist())


# ---------------------------------------------------------------------------
# LRU cache
# ---------------------------------------------------------------------------


def test_retrieval_cache_lru_bounded_and_counted(corpus):
    _, texts, bm25, _ = corpus
    cache = RetrievalCache(maxsize=2)
    r = CachedRetriever(IndexRetriever("bm25", bm25), cache)
    p1 = r.passages("the length of the river", 3)
    assert cache.lookups == 1 and cache.hits == 0
    assert r.passages("the length of the river", 3) == p1
    assert cache.hits == 1
    # distinct (query, k) keys; maxsize=2 evicts the LRU entry
    r.passages("the founder of the empire", 3)
    r.passages("the founder of the empire", 5)          # evicts river@3
    assert len(cache) == 2
    r.passages("the length of the river", 3)            # miss again
    assert cache.lookups == 5 and cache.hits == 1


def test_resolve_retrievers_shares_one_cache(corpus):
    _, texts, bm25, dense = corpus
    suite = build_retriever_suite(bm25, dense)
    assert set(suite) == {"bm25", "dense", "hybrid"}
    wrapped, cache = resolve_retrievers(suite, bm25, cache_size=8)
    assert cache is not None
    wrapped["bm25"].passages("the river", 2)
    wrapped["dense"].passages("the river", 2)
    wrapped["hybrid"].passages("the river", 2)
    # same query, three different retriever names: three distinct keys
    assert cache.lookups == 3 and cache.hits == 0
    wrapped["dense"].passages("the river", 2)
    assert cache.hits == 1


# ---------------------------------------------------------------------------
# paper5 bit-for-bit regression guard
# ---------------------------------------------------------------------------


def test_paper5_registry_unchanged():
    from repro.core.actions import ACTIONS, N_ACTIONS, REFUSE_ACTION
    assert N_ACTIONS == 5 and REFUSE_ACTION == 4
    assert [(a.idx, a.k, a.mode) for a in ACTIONS] == [
        (0, 2, "guarded"), (1, 5, "guarded"), (2, 10, "guarded"),
        (3, 5, "auto"), (4, 0, "refuse")]
    # the retriever field defaults every paper action to bm25
    assert all(a.retriever == "bm25" for a in ACTIONS)


def test_paper5_pipeline_bit_for_bit(corpus):
    """The retriever-protocol pipeline must reproduce the seed's inline
    bm25 topk->texts path exactly: same passages, same outcomes."""
    from repro.data.tokenizer import HashTokenizer
    from repro.generation.simulator import SimulatedGenerator
    from repro.serving.pipeline import RAGPipeline

    data, texts, bm25, _ = corpus
    gen = SimulatedGenerator(HashTokenizer(32768), seed=0)
    pipe = RAGPipeline(bm25, gen)                       # default: bm25 only
    for q in data.questions[:6]:
        for out in pipe.sweep(q):
            a = out.action
            from repro.core.actions import ACTIONS
            action = ACTIONS[a]
            if action.mode == "refuse":
                legacy = gen.refuse(q.qid, q.text)
                assert out.refused and out.cost_tokens == legacy.cost_tokens
                continue
            # the seed implementation, inlined
            idx, _ = bm25.topk(q.text, action.k)
            passages = [bm25.texts[i] for i in idx]
            legacy = gen.generate(q.qid, a, action.mode, q.text, passages,
                                  answerable=q.answerable,
                                  gold_answer=q.gold_answer)
            assert out.correct == legacy.correct
            assert out.refused == legacy.refused
            assert out.hallucinated == legacy.hallucinated
            assert out.cost_tokens == legacy.cost_tokens
            assert out.hit == (bool(q.gold_answer) and any(
                q.gold_answer in p for p in passages))


def test_offline_log_save_load_roundtrip(tmp_path, corpus):
    from repro.core.offline_log import OfflineLog, generate_log
    from repro.data.tokenizer import HashTokenizer
    from repro.generation.simulator import SimulatedGenerator
    from repro.routing import get_action_space
    from repro.serving.pipeline import RAGPipeline

    data, texts, bm25, dense = corpus
    pipe = RAGPipeline(bm25, SimulatedGenerator(HashTokenizer(32768)),
                       build_retriever_suite(bm25, dense))
    space = get_action_space("hybrid9")
    log = generate_log(data.questions[:4], pipe, bm25,
                       RouterConfig(n_actions=9), space)
    assert log.n_actions == 9 and log.refuse_action == 8
    p = tmp_path / "log.npz"
    log.save(p)
    back = OfflineLog.load(p)
    assert back.refuse_action == 8
    np.testing.assert_array_equal(back.cost, log.cost)
    profile = list(__import__("repro.core.actions",
                              fromlist=["SLO_PROFILES"]).SLO_PROFILES
                   .values())[0]
    np.testing.assert_array_equal(back.rewards(profile),
                                  log.rewards(profile))
    # a space WITHOUT a refuse action must round-trip None (not
    # resurrect the paper's index 4 and mis-scale eq. 1)
    log2 = dataclasses.replace(log, refuse_action=None)
    p2 = tmp_path / "log2.npz"
    log2.save(p2)
    assert OfflineLog.load(p2).refuse_action is None


# ---------------------------------------------------------------------------
# hybrid9 end-to-end through the Gateway
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hybrid9_testbed():
    from repro.core.offline_log import build_testbed
    from repro.routing import get_action_space
    space = get_action_space("hybrid9")
    cfg = TestbedConfig(n_train=60, n_eval=20, n_paragraphs=100,
                        retrieval=RCFG,
                        router=RouterConfig(n_actions=9, n_epochs=3))
    return cfg, space, build_testbed(cfg, space)


def test_hybrid9_gateway_simulator_end_to_end(hybrid9_testbed):
    from repro.routing import Gateway, MLPPolicy, Request, SimulatorBackend
    from repro.core.actions import SLO_PROFILES

    cfg, space, (data, index, pipe, train_log, eval_log) = hybrid9_testbed
    policy = MLPPolicy.train(
        train_log, train_log.rewards(SLO_PROFILES["quality_first"]),
        cfg.router)
    gw = Gateway(policy, SimulatorBackend(pipe), router_cfg=cfg.router,
                 index=index, action_space=space)
    stats = gw.serve([Request(qid=q.qid, question=q, slo="quality_first")
                      for q in data.questions[-20:]])
    assert stats.served == 20
    assert all(0 <= a < 9 for a in stats.action_counts)
    # a trained policy must route through the NEW retriever actions
    # somewhere on the eval stream OR refuse — either way the serve
    # loop executed 9-action decisions without raising


def test_hybrid9_constrained_policy_caps_correct_logit(hybrid9_testbed):
    """The Lagrangian must watch hybrid9's refuse action (index 8, not
    the paper's 4): under a tight cap the dual must activate and push
    p(a8) BELOW the uncapped policy's — if the penalty still hit index
    4, p(a8) would be untouched."""
    from repro.core.actions import SLO_PROFILES
    from repro.core.metrics import evaluate_actions
    from repro.routing import ConstrainedPolicy, MLPPolicy

    cfg, space, (data, index, pipe, train_log, eval_log) = hybrid9_testbed
    profile = SLO_PROFILES["cheap"]
    rw = train_log.rewards(profile)
    rcfg = dataclasses.replace(cfg.router, n_epochs=10)

    def mean_p(policy, a):
        z = policy.logits(eval_log.states)
        p = np.exp(z - z.max(1, keepdims=True))
        p /= p.sum(1, keepdims=True)
        return float(p[:, a].mean())

    con = ConstrainedPolicy.train(train_log, rw, rcfg, refusal_cap=0.02)
    ce = MLPPolicy.train(train_log, rw, rcfg, objective="argmax_ce")
    assert con.lagrange > 0.0            # the dual activated
    assert mean_p(con, 8) < mean_p(ce, 8) - 1e-3
    rep = evaluate_actions(eval_log, con.actions(eval_log.states),
                           profile, "constrained")
    assert len(rep.action_dist) == 9


def test_refuse_free_space_trains_without_refusal_term(corpus):
    """A registered space with NO refuse action must train every
    objective with the refusal machinery disabled — not crash on (or
    silently penalize) the paper's index 4."""
    from repro.core.actions import SLO_PROFILES
    from repro.core.offline_log import generate_log
    from repro.core.policy import train_policy
    from repro.data.tokenizer import HashTokenizer
    from repro.generation.simulator import SimulatedGenerator
    from repro.routing.registry import Action, ActionSpace
    from repro.serving.pipeline import RAGPipeline

    data, texts, bm25, dense = corpus
    space = ActionSpace("norefuse3", (Action(0, 2, "guarded"),
                                      Action(1, 5, "guarded", "dense"),
                                      Action(2, 5, "auto")))
    assert space.refuse_action is None
    pipe = RAGPipeline(bm25, SimulatedGenerator(HashTokenizer(32768)),
                       build_retriever_suite(bm25, dense))
    rcfg = RouterConfig(n_actions=3, n_epochs=2)
    log = generate_log(data.questions[:8], pipe, bm25, rcfg, space)
    assert log.refuse_action is None
    for obj in ("argmax_ce", "soft_reward", "constrained"):
        tr = train_policy(log, log.rewards(SLO_PROFILES["cheap"]), rcfg,
                          objective=obj)
        assert tr.history[-1]["p_refuse"] == 0.0
        assert tr.lagrange == 0.0


def test_hybrid9_gateway_engine_backend(hybrid9_testbed):
    """hybrid9 through the REAL continuous engine: per-action retriever
    choice feeds prompt construction, mixed buckets share one decode
    stream, and the retrieval cache counts hits on repeats."""
    from repro.configs import get_config
    from repro.data.tokenizer import HashTokenizer
    from repro.models import build_model
    from repro.routing import (ContinuousEngineBackend, FixedPolicy,
                               Gateway, Request)
    from repro.retrieval.hybrid import build_retriever_suite

    cfg, space, (data, index, pipe, train_log, eval_log) = hybrid9_testbed
    mcfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                               dtype="float32")
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(0))
    dense = DenseIndex.build([p.text for p in data.paragraphs], RCFG)
    backend = ContinuousEngineBackend.create(
        model, params, HashTokenizer(mcfg.vocab_size), index,
        num_slots=4, max_prompt_len=64, max_new_tokens=4,
        retrievers=build_retriever_suite(index, dense),
        retrieval_cache_size=32)
    # rotate policies over a dense action, a hybrid action and refuse
    for action_idx in (3, 7, 8):
        gw = Gateway(FixedPolicy(action_idx), backend,
                     router_cfg=cfg.router, index=index,
                     action_space=space)
        qs = data.questions[:3] * 2          # repeats -> cache hits
        stats = gw.serve([Request(qid=q.qid, question=q) for q in qs])
        assert stats.served == 6
        assert stats.action_counts[action_idx] == 6
    assert backend.retrieval_cache.hits > 0


# ---------------------------------------------------------------------------
# sharded dense retrieval (forced-8-device mesh)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from jax.sharding import Mesh

from repro.core.config import RetrievalConfig
from repro.data.synthetic_squad import SyntheticSquad
from repro.retrieval.dense import DenseIndex
from repro.retrieval.distributed import DistributedDenseIndex

cfg = RetrievalConfig(vocab_hash_dim=1024, dense_embed_dim=128)
data = SyntheticSquad(n_paragraphs=256, n_questions=16, seed=3)
idx = DenseIndex.build([p.text for p in data.paragraphs], cfg)
mesh = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
dist = DistributedDenseIndex(mesh, idx.emb)

qe = np.stack([idx.encode(q.text) for q in data.questions])
ids, scores = dist.topk(qe, k=10)
for qi, q in enumerate(data.questions):
    ref_ids, ref_scores = idx.topk(q.text, 10)
    # acceptance: id-IDENTICAL to the single-device oracle
    assert ids[qi].tolist() == ref_ids.tolist(), (qi, ids[qi], ref_ids)
    np.testing.assert_allclose(scores[qi], ref_scores, rtol=1e-4,
                               atol=1e-5)
print("DIST-DENSE-OK")
"""


@pytest.mark.multidevice
def test_sharded_dense_id_identical_to_single_device():
    root = Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT],
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"},
        capture_output=True, text=True, timeout=500)
    assert "DIST-DENSE-OK" in out.stdout, out.stderr[-2000:]


def test_distributed_exports():
    """Satellite: the package docstring advertises the distributed
    scorers — they must actually be importable from the package."""
    from repro.retrieval import (DistributedBM25, DistributedDenseIndex,
                                 distributed_bm25_topk,
                                 distributed_dense_topk, distributed_topk)
    assert callable(distributed_topk)
