"""Distributed BM25 on a real 8-device host mesh == single-index oracle."""
import subprocess
import sys
from pathlib import Path

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.config import RetrievalConfig
from repro.data.synthetic_squad import SyntheticSquad
from repro.retrieval.bm25 import BM25Index
from repro.retrieval.distributed import DistributedBM25

data = SyntheticSquad(n_paragraphs=256, n_questions=16, seed=3)
idx = BM25Index.build([p.text for p in data.paragraphs],
                      RetrievalConfig(vocab_hash_dim=1024))
mesh = Mesh(np.array(jax.devices()).reshape(8, 1), ("data", "model"))
dist = DistributedBM25(mesh, idx.tf, idx.doc_len, idx.idf)

qv = np.stack([idx.query_vector(q.text) for q in data.questions])
ids, scores = dist.topk(qv, k=10)
for qi, q in enumerate(data.questions):
    ref_ids, ref_scores = idx.topk(q.text, 10)
    got, want = set(ids[qi].tolist()), set(ref_ids.tolist())
    # allow tie reordering at the boundary: compare score multisets
    np.testing.assert_allclose(np.sort(scores[qi]), np.sort(ref_scores),
                               rtol=1e-4, atol=1e-4)
    assert len(got & want) >= 9, (qi, got, want)
print("DIST-RETRIEVAL-OK")
"""


def test_distributed_bm25_matches_oracle():
    root = Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"},
        capture_output=True, text=True, timeout=500)
    assert "DIST-RETRIEVAL-OK" in out.stdout, out.stderr[-2000:]
