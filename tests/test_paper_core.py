"""Paper-core behaviour: testbed, policies, objectives, failure modes."""
import numpy as np
import pytest

from repro.core.actions import ACTIONS, SLO_PROFILES, REFUSE_ACTION
from repro.core.config import RouterConfig, TestbedConfig
from repro.core.metrics import best_fixed_action, evaluate_actions
from repro.core.offline_log import build_testbed
from repro.core.policy import policy_actions, train_policy


@pytest.fixture(scope="module")
def testbed():
    cfg = TestbedConfig(n_train=300, n_eval=100, n_paragraphs=300,
                        router=RouterConfig(n_epochs=15))
    return cfg, build_testbed(cfg)


def test_log_shapes_and_determinism(testbed):
    cfg, (data, index, pipe, train_log, eval_log) = testbed
    assert train_log.states.shape == (300, cfg.router.state_dim)
    assert eval_log.n == 100
    # re-running one sweep reproduces identical outcomes (frozen log)
    q = data.questions[0]
    s1 = [o.to_row() for o in pipe.sweep(q)]
    s2 = [o.to_row() for o in pipe.sweep(q)]
    assert s1 == s2


def test_retrieval_depth_monotone_hit(testbed):
    _, (_, _, _, train_log, eval_log) = testbed
    ans = train_log.answerable
    h2 = train_log.hit[ans, 0].mean()
    h5 = train_log.hit[ans, 1].mean()
    h10 = train_log.hit[ans, 2].mean()
    assert h2 <= h5 + 1e-9 <= h10 + 2e-9
    assert 0.4 < h2 < 0.95 and h10 < 1.0


def test_cost_monotone_in_k(testbed):
    _, (_, _, _, train_log, _) = testbed
    c = train_log.cost.mean(axis=0)
    assert c[0] < c[1] < c[2]          # k=2 < k=5 < k=10
    assert c[4] < c[0]                 # refusal cheapest
    assert train_log.refused[:, 4].all()


def test_refusal_collapse_under_cheap(testbed):
    """Paper §6.2: cheap SLO argmax-CE collapses to refusal."""
    cfg, (_, _, _, train_log, eval_log) = testbed
    profile = SLO_PROFILES["cheap"]
    tr = train_policy(train_log, train_log.rewards(profile), cfg.router,
                      objective="argmax_ce")
    acts = policy_actions(tr.params, eval_log.states, cfg.router)
    rep = evaluate_actions(eval_log, acts, profile, "ce")
    _, bf = best_fixed_action(eval_log, profile)
    assert rep.refusal_rate > 0.5, rep
    assert rep.acc < 0.2
    assert rep.reward < bf.reward      # collapse is harmful

def test_quality_first_learned_policy_competitive(testbed):
    cfg, (_, _, _, train_log, eval_log) = testbed
    profile = SLO_PROFILES["quality_first"]
    tr = train_policy(train_log, train_log.rewards(profile), cfg.router,
                      objective="argmax_ce")
    acts = policy_actions(tr.params, eval_log.states, cfg.router)
    rep = evaluate_actions(eval_log, acts, profile, "ce")
    _, bf = best_fixed_action(eval_log, profile)
    # competitive with the strong fixed baseline on this reduced testbed
    # (the full-scale N=800 claim is exercised by benchmarks/table1)
    assert rep.reward > bf.reward - 0.1
    assert rep.refusal_rate < 0.8


def test_constrained_objective_caps_refusal(testbed):
    """Beyond-paper mitigation: Lagrangian refusal cap under cheap."""
    cfg, (_, _, _, train_log, eval_log) = testbed
    profile = SLO_PROFILES["cheap"]
    rewards = train_log.rewards(profile)
    un = train_policy(train_log, rewards, cfg.router, objective="argmax_ce")
    con = train_policy(train_log, rewards, cfg.router,
                       objective="constrained", refusal_cap=0.3)
    a_un = policy_actions(un.params, eval_log.states, cfg.router)
    a_con = policy_actions(con.params, eval_log.states, cfg.router)
    r_un = evaluate_actions(eval_log, a_un, profile, "ce")
    r_con = evaluate_actions(eval_log, a_con, profile, "con")
    assert r_con.action_dist[REFUSE_ACTION] < r_un.action_dist[REFUSE_ACTION]
    assert r_con.acc > r_un.acc


def test_rewards_match_manual_equation(testbed):
    _, (_, _, _, train_log, _) = testbed
    p = SLO_PROFILES["quality_first"]
    r = train_log.rewards(p)
    i, a = 3, 1
    expect = (p.w_acc * train_log.correct[i, a]
              - p.w_cost * train_log.cost[i, a] / p.cost_scale
              - p.w_hall * train_log.hallucinated[i, a])
    if train_log.refused[i, a]:
        expect += (p.w_ref if not train_log.answerable[i]
                   else -p.w_ref_wrong)
    assert r[i, a] == pytest.approx(expect, abs=1e-5)


def test_log_save_load_roundtrip(tmp_path, testbed):
    _, (_, _, _, train_log, _) = testbed
    from repro.core.offline_log import OfflineLog
    p = tmp_path / "log.npz"
    train_log.save(p)
    log2 = OfflineLog.load(p)
    np.testing.assert_array_equal(train_log.states, log2.states)
    np.testing.assert_array_equal(train_log.cost, log2.cost)
