"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep: pip install hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.actions import SLO_PROFILES, reward
from repro.core.config import SLOProfile
from repro.models.schema import ParamSpec

profiles = st.sampled_from(list(SLO_PROFILES.values()))


@settings(max_examples=200, deadline=None)
@given(profiles, st.floats(0, 5000), st.floats(0, 5000),
       st.booleans(), st.booleans())
def test_reward_monotone_in_cost(p, c1, c2, correct, answerable):
    """More tokens never increases reward, all else equal."""
    lo, hi = sorted([c1, c2])
    r_lo = reward(p, correct=correct, cost_tokens=lo, hallucinated=False,
                  refused=False, answerable=answerable)
    r_hi = reward(p, correct=correct, cost_tokens=hi, hallucinated=False,
                  refused=False, answerable=answerable)
    assert r_hi <= r_lo + 1e-9


@settings(max_examples=200, deadline=None)
@given(profiles, st.floats(0, 2000), st.booleans())
def test_hallucination_never_helps(p, cost, answerable):
    r_h = reward(p, correct=False, cost_tokens=cost, hallucinated=True,
                 refused=False, answerable=answerable)
    r_n = reward(p, correct=False, cost_tokens=cost, hallucinated=False,
                 refused=False, answerable=answerable)
    assert r_h <= r_n


@settings(max_examples=200, deadline=None)
@given(profiles, st.floats(0, 2000))
def test_refusal_credit_sign(p, cost):
    """Correct refusal ≥ incorrect refusal; pre-retrieval credit scaled."""
    r_good = reward(p, correct=False, cost_tokens=cost, hallucinated=False,
                    refused=True, answerable=False)
    r_bad = reward(p, correct=False, cost_tokens=cost, hallucinated=False,
                   refused=True, answerable=True)
    assert r_good >= r_bad
    r_pre = reward(p, correct=False, cost_tokens=cost, hallucinated=False,
                   refused=True, answerable=False, pre_retrieval=True)
    assert r_pre <= r_good + 1e-9


@settings(max_examples=100, deadline=None)
@given(st.floats(0.01, 3.0), st.floats(0, 1000), st.booleans())
def test_correct_beats_incorrect(w_acc, cost, answerable):
    p = SLOProfile(name="t", w_acc=w_acc, w_cost=0.1, w_hall=0.3, w_ref=0.1)
    r_c = reward(p, correct=True, cost_tokens=cost, hallucinated=False,
                 refused=False, answerable=answerable)
    r_w = reward(p, correct=False, cost_tokens=cost, hallucinated=True,
                 refused=False, answerable=answerable)
    assert r_c > r_w


# ---------------------------------------------------------------------------
# Sharding resolver properties
# ---------------------------------------------------------------------------


def _mesh(shape=(4, 2), axes=("data", "model")):
    import jax
    n = int(np.prod(shape))
    devs = np.array(jax.devices() * n)[:n].reshape(shape)
    from jax.sharding import Mesh
    return Mesh(devs, axes)


@settings(max_examples=200, deadline=None)
@given(
    st.lists(st.sampled_from(["d_model", "d_ff", "heads", "kv_heads",
                              "head_dim", "vocab", "", "batch", "seq"]),
             min_size=1, max_size=4),
    st.lists(st.integers(1, 9), min_size=4, max_size=4),
)
def test_resolver_only_shards_divisible_dims(axes, dim_seeds):
    from repro.sharding import resolve_spec, mesh_axis_sizes
    mesh = _mesh()
    sizes = mesh_axis_sizes(mesh)
    shape = tuple(d * 2 for d in dim_seeds[:len(axes)])
    ps = ParamSpec(shape, tuple(axes))
    spec = resolve_spec(ps, mesh)
    used = []
    for entry, dim in zip(spec, ps.shape):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        prod = int(np.prod([sizes[n] for n in names]))
        assert dim % prod == 0, (spec, ps)
        used.extend(names)
    assert len(used) == len(set(used)), f"mesh axis reused: {spec}"


def test_resolver_head_fallback():
    """40 heads on a 16-way model axis must fall back, not crash."""
    import jax
    from jax.sharding import Mesh
    from repro.sharding import resolve_spec
    devs = np.array(jax.devices() * 16)[:16].reshape(1, 16)
    mesh = Mesh(devs, ("data", "model"))
    ps = ParamSpec((512, 40, 128), ("d_model", "heads", "head_dim"))
    spec = resolve_spec(ps, mesh)
    assert spec[1] is None          # 40 % 16 != 0
    assert spec[2] == "model"       # head_dim fallback
