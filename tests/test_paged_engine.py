"""Paged KV cache end-to-end: dense vs paged greedy token parity
(single-device and dp×mp sharded, with and without int8 KV quant),
prefix sharing across admission waves, pool-exhaustion back-pressure,
and the smaller-than-dense page budget serving full slot concurrency."""
import dataclasses
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import trim_at_eos as _trim
from repro.models import build_model
from repro.serving.continuous import ContinuousEngine


@pytest.fixture(scope="module")
def qwen():
    cfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def qwen_int8():
    cfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                              dtype="float32", kv_quant_int8=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mixed_prompts(cfg, seed=0):
    """Mixed lengths (slot reuse: more prompts than slots) plus three
    shared-16-token-prefix RAG-style prompts of equal length."""
    rng = np.random.default_rng(seed)
    mixed = [list(rng.integers(4, cfg.vocab_size, size=n))
             for n in (10, 7, 10, 5)]
    base = list(rng.integers(4, cfg.vocab_size, size=16))
    shared = [base + list(rng.integers(4, cfg.vocab_size, size=4))
              for _ in range(3)]
    return mixed + shared


@pytest.mark.parametrize("prefill_batch", [1, 3])
def test_paged_token_parity(qwen, prefill_batch):
    """Greedy outputs are token-identical dense vs paged, across two
    waves (the second wave re-serves the same prompts cache-hot, so
    parity also covers the shared-page gather + CoW prefill path)."""
    cfg, model, params = qwen
    prompts = _mixed_prompts(cfg)
    kw = dict(num_slots=3, max_len=64, max_new_cap=16, sync_every=4,
              prefill_batch=prefill_batch)
    dense = ContinuousEngine(model, params, **kw)
    paged = ContinuousEngine(model, params, paged=True, page_size=8, **kw)
    for wave in range(2):
        a = dense.generate_many(prompts, max_new_tokens=12)
        b = paged.generate_many(prompts, max_new_tokens=12)
        for i, (x, y) in enumerate(zip(a, b)):
            assert _trim(x.tokens) == _trim(y.tokens), (wave, i)
    # the second wave's prompts hit the prefix cache: part of their
    # prompt tokens never went through the prefill program
    assert paged.stats.prefill_tokens_avoided > 0
    assert paged.stats.prompt_tokens_total > 0
    assert paged.stats.n_deferred_admissions == 0
    assert paged.stats.cache_allocations == 2
    assert dense.stats.prefill_tokens_avoided == 0


def test_paged_token_parity_int8(qwen_int8):
    """Same parity contract with the int8-quantized KV cache: the paged
    pool stores the same quantized pages the dense rows would hold."""
    cfg, model, params = qwen_int8
    prompts = _mixed_prompts(cfg, seed=1)
    kw = dict(num_slots=3, max_len=64, max_new_cap=16, sync_every=4,
              prefill_batch=2)
    dense = ContinuousEngine(model, params, **kw)
    paged = ContinuousEngine(model, params, paged=True, page_size=8, **kw)
    for wave in range(2):
        a = dense.generate_many(prompts, max_new_tokens=10)
        b = paged.generate_many(prompts, max_new_tokens=10)
        for i, (x, y) in enumerate(zip(a, b)):
            assert _trim(x.tokens) == _trim(y.tokens), (wave, i)
    assert paged.stats.prefill_tokens_avoided > 0


def test_pool_exhaustion_defers_and_recovers(qwen):
    """A pool too small for two concurrent requests defers admissions
    (no crash, no OOM) and serves everything once decode frees pages."""
    cfg, model, params = qwen
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(4, cfg.vocab_size, size=24))
               for _ in range(3)]
    # max_len=64 / page_size=8 -> max_blocks=9; num_pages=9 admits one
    # 24-token+16-gen request (6 blocks) at a time
    eng = ContinuousEngine(model, params, num_slots=2, max_len=64,
                           max_new_cap=16, sync_every=4, prefill_batch=1,
                           paged=True, page_size=8, num_pages=9,
                           prefix_sharing=False)
    outs = eng.generate_many(prompts, max_new_tokens=16)
    assert all(o.failed == "" and o.n_steps > 0 for o in outs)
    assert eng.stats.n_deferred_admissions > 0
    assert eng.stats.n_completed == 3


def test_paged_serves_full_concurrency_under_smaller_budget(qwen):
    """Prefix sharing lets a pool with FEWER KV positions than the
    dense cache (num_pages * page_size < num_slots * max_len) still
    keep every slot busy on a repeated-passage workload — the
    slots-per-byte win the bench quantifies."""
    cfg, model, params = qwen
    rng = np.random.default_rng(3)
    base = list(rng.integers(4, cfg.vocab_size, size=16))
    prompts = [base + list(rng.integers(4, cfg.vocab_size, size=8))
               for _ in range(8)]
    S, ML, ps, NP = 4, 64, 8, 28
    assert NP * ps < S * ML  # strictly below the dense budget
    eng = ContinuousEngine(model, params, num_slots=S, max_len=ML,
                           max_new_cap=16, sync_every=2, prefill_batch=1,
                           paged=True, page_size=ps, num_pages=NP)
    outs = eng.generate_many(prompts, max_new_tokens=8)
    assert all(o.failed == "" for o in outs)
    assert eng.stats.max_concurrent == S
    assert eng.stats.prefill_tokens_avoided > 0
    assert eng.stats.n_deferred_admissions == 0


def test_paged_flash_decode_smoke(qwen):
    """The paged flash-decode kernel path (use_flash_decode=True)
    serves a full wave: finite outputs of the expected lengths."""
    cfg, model, params = qwen
    cfg_fd = dataclasses.replace(cfg, use_flash_decode=True)
    model_fd = build_model(cfg_fd)
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(4, cfg.vocab_size, size=n))
               for n in (10, 7, 12)]
    eng = ContinuousEngine(model_fd, params, num_slots=2, max_len=64,
                           max_new_cap=8, sync_every=4, prefill_batch=1,
                           paged=True, page_size=16)
    outs = eng.generate_many(prompts, max_new_tokens=6)
    for o in outs:
        assert o.failed == "" and 0 < o.n_steps <= 6
        assert (o.tokens >= 0).all() and (o.tokens < cfg.vocab_size).all()


def test_paged_config_validation(qwen):
    cfg, model, params = qwen
    from repro.serving.executor import SingleDeviceExecutor
    with pytest.raises(ValueError, match="multiple"):
        SingleDeviceExecutor(model, params, num_slots=2, max_len=60,
                             paged=True, page_size=16)
    with pytest.raises(ValueError, match="pages per partition"):
        SingleDeviceExecutor(model, params, num_slots=2, max_len=64,
                             paged=True, page_size=16, num_pages=3)


SCRIPT_PAGED_SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, numpy as np

from repro.configs import get_config
from repro.data.tokenizer import trim_at_eos as trim
from repro.launch.mesh import make_serving_mesh
from repro.models import build_model
from repro.serving.continuous import ContinuousEngine

for quant in (False, True):
    cfg = dataclasses.replace(get_config("qwen1.5-32b", "smoke"),
                              dtype="float32", kv_quant_int8=quant)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    base = list(rng.integers(4, cfg.vocab_size, size=16))
    prompts = [list(rng.integers(4, cfg.vocab_size, size=n))
               for n in (10, 7, 10, 5)]
    prompts += [base + list(rng.integers(4, cfg.vocab_size, size=4))
                for _ in range(4)]

    dense = ContinuousEngine(model, params, num_slots=4, max_len=64,
                             max_new_cap=16, sync_every=4, prefill_batch=2)
    mesh = make_serving_mesh("dp=4,mp=2", model_cfg=cfg)
    paged = ContinuousEngine(model, params, num_slots=4, max_len=64,
                             max_new_cap=16, sync_every=4, prefill_batch=2,
                             mesh=mesh, paged=True, page_size=8)
    for wave in range(2):
        a = dense.generate_many(prompts, max_new_tokens=12)
        b = paged.generate_many(prompts, max_new_tokens=12)
        for i, (x, y) in enumerate(zip(a, b)):
            assert trim(x.tokens) == trim(y.tokens), (quant, wave, i)
    assert paged.stats.prefill_tokens_avoided > 0, quant
    assert paged.stats.cache_allocations == 2

    # the page pool is REALLY sharded: page dim over data (each device
    # owns num_pages/4 pages), kv-head dim over model
    ex = paged.executor
    key = "k_q" if quant else "k"
    pool = ex._cache["blocks"]["p0"][key]  # (layers, NP, ps, Hkv, Dh)
    NP = ex.num_pages
    assert pool.shape[1] == NP
    shard_shapes = {s.data.shape for s in pool.addressable_shards}
    assert all(sh[1] == NP // 4 and sh[3] == 2 for sh in shard_shapes), (
        quant, shard_shapes)
    tbl = ex._cache["table"]
    assert {s.data.shape for s in tbl.addressable_shards} == \
        {(1, tbl.shape[1])}, tbl.sharding.spec
    # host allocator partitions follow the device layout
    assert paged._pages.partitions == 4

print("PAGED-SHARDED-PARITY-OK")
"""


@pytest.mark.multidevice
def test_paged_sharded_dp4_mp2_token_parity():
    """dp=4,mp=2 paged engine: token parity with the dense
    single-device engine (both KV dtypes), sharded pool layout, and
    partitioned host allocator."""
    root = Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT_PAGED_SHARDED],
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/tmp"},
        capture_output=True, text=True, timeout=900)
    assert "PAGED-SHARDED-PARITY-OK" in out.stdout, out.stderr[-2000:]
