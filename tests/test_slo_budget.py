"""Focused tests for the SLO error-budget tracker: window eviction,
budget edge cases, burn-rate actuation thresholds, refusal-cap
monotonicity, and the latency reservoir."""
import math

import numpy as np
import pytest

from repro.core.serving_types import RequestOutcome
from repro.serving.slo_budget import (BudgetReport, LatencyReservoir,
                                      SLOBudgetTracker, SLOTarget,
                                      latency_target)


def _outcome(qid=0, *, refused=False, answerable=True, hallucinated=False,
             correct=True, cost=10.0, latency_ms=5.0):
    return RequestOutcome(qid=qid, action=0, correct=correct,
                          refused=refused, hallucinated=hallucinated,
                          cost_tokens=cost, answerable=answerable,
                          latency_ms=latency_ms)


def _tracker(*, window=4, objective=0.5, metric="refusal", threshold=0.0,
             **kw):
    return SLOBudgetTracker(
        [SLOTarget("t", metric, threshold, objective=objective,
                   window=window)], **kw)


# --- window eviction --------------------------------------------------------


def test_window_evicts_oldest_events():
    tr = _tracker(window=4)
    # 4 violations fill the window...
    for i in range(4):
        tr.record(_outcome(i, refused=True, answerable=True))
    assert tr.states["t"].violation_rate == 1.0
    # ...then 4 clean events evict them completely
    for i in range(4):
        tr.record(_outcome(i, refused=False))
    s = tr.states["t"]
    assert len(s.events) == 4
    assert s.violation_rate == 0.0
    assert s.healthy


def test_window_never_exceeds_target_window():
    tr = _tracker(window=3)
    for i in range(50):
        tr.record(_outcome(i, refused=bool(i % 2), answerable=True))
    assert len(tr.states["t"].events) == 3


# --- budget_consumed edge cases ---------------------------------------------


def test_budget_consumed_empty_window_is_zero():
    tr = _tracker()
    s = tr.states["t"]
    assert s.violation_rate == 0.0
    assert s.budget_consumed == 0.0
    assert s.burn_rate() == 0.0
    assert s.healthy


def test_budget_consumed_zero_error_budget_is_inf():
    # objective=1.0 -> error budget 0: any violation is infinite burn
    tr = _tracker(objective=1.0)
    tr.record(_outcome(refused=True, answerable=True))
    s = tr.states["t"]
    assert math.isinf(s.budget_consumed)
    assert math.isinf(s.burn_rate())
    assert not s.healthy


def test_budget_consumed_exactly_at_budget_is_healthy():
    # objective 0.5 => budget 0.5; 2/4 violations = exactly consumed
    tr = _tracker(window=4, objective=0.5)
    for i in range(4):
        tr.record(_outcome(i, refused=(i < 2), answerable=True))
    s = tr.states["t"]
    assert s.budget_consumed == pytest.approx(1.0)
    assert s.healthy          # <=1.0 is healthy; breach means >1.0


def test_latency_metric_counts_over_threshold():
    tr = SLOBudgetTracker([latency_target(100.0, objective=0.5, window=10)])
    tr.record(_outcome(latency_ms=50.0))
    tr.record(_outcome(latency_ms=150.0))
    assert tr.states["latency"].violation_rate == pytest.approx(0.5)


# --- burn rate: the actuation signal ----------------------------------------


def test_burn_rate_sees_recent_violations_before_full_window():
    """A 500-event window dilutes a violation storm; the short-window
    burn rate is the fast signal that reacts first."""
    tr = _tracker(window=500, objective=0.9, burn_window=10)
    for i in range(200):
        tr.record(_outcome(i, refused=False))
    # now a storm: 10 straight violations
    for i in range(10):
        tr.record(_outcome(i, refused=True, answerable=True))
    s = tr.states["t"]
    # long-window: 10/210 ~ 4.8% of a 10% budget -> under half consumed
    assert s.budget_consumed < 0.5
    # short-window: 10/10 violations against a 10% budget -> 10x burn
    assert s.burn_rate(10) == pytest.approx(10.0)
    assert tr.burn_rate("t") == pytest.approx(10.0)


def test_burn_rate_unknown_target_is_zero():
    tr = _tracker()
    assert tr.burn_rate("nonexistent") == 0.0


def test_burn_rate_window_zero_is_zero():
    tr = _tracker()
    tr.record(_outcome(refused=True, answerable=True))
    assert tr.states["t"].burn_rate(0) == 0.0


# --- typed report -----------------------------------------------------------


def test_report_returns_typed_rows():
    tr = _tracker(window=4, objective=0.5)
    tr.record(_outcome(refused=True, answerable=True))
    rep = tr.report()["t"]
    assert isinstance(rep, BudgetReport)
    assert rep.violation_rate == 1.0
    assert rep.window_n == 1
    assert isinstance(rep.healthy, bool)
    d = tr.report_dict()["t"]
    assert set(d) == {"violation_rate", "budget_consumed", "burn_rate",
                      "window_n", "healthy"}
    assert isinstance(d["healthy"], bool)       # bools stay bools, typed


# --- refusal cap adjustment -------------------------------------------------


def _refusal_tracker(violation_rate, *, n=100, objective=0.9, **kw):
    tr = SLOBudgetTracker([SLOTarget("refusal", "refusal", 0.0,
                                     objective=objective, window=n)], **kw)
    n_bad = int(round(violation_rate * n))
    for i in range(n):
        tr.record(_outcome(i, refused=(i < n_bad), answerable=True))
    return tr


def test_refusal_cap_untouched_below_knee():
    # burn 0.4 <= knee 0.5: no adjustment
    tr = _refusal_tracker(0.04)       # 4% of a 10% budget = 0.4 burn
    assert tr.refusal_cap_adjustment(0.6) == pytest.approx(0.6)


def test_refusal_cap_monotone_nonincreasing_in_burn():
    caps = [_refusal_tracker(v).refusal_cap_adjustment(0.6)
            for v in (0.0, 0.05, 0.10, 0.15, 0.20, 0.30, 0.50)]
    assert all(a >= b - 1e-12 for a, b in zip(caps, caps[1:]))
    assert caps[0] > caps[-1]         # actually tightens somewhere


def test_refusal_cap_floor_and_clip():
    # 100% violations: burn clips at 2.0 -> scale 1 - 0.5*1.5 = 0.25
    tr = _refusal_tracker(1.0)
    assert tr.refusal_cap_adjustment(0.6) == pytest.approx(0.15)
    # a tiny base cap can't go below the floor
    assert tr.refusal_cap_adjustment(0.08) == pytest.approx(0.05)


def test_refusal_cap_constants_configurable():
    tr = _refusal_tracker(1.0, refusal_cap_floor=0.2, burn_slope=1.0,
                          burn_knee=0.0, burn_clip=1.0)
    # scale = 1 - 1.0 * (1.0 - 0.0) = 0 -> floored at 0.2
    assert tr.refusal_cap_adjustment(0.6) == pytest.approx(0.2)


def test_refusal_cap_no_events_passthrough():
    tr = _tracker(metric="refusal")
    tr.states["refusal"] = tr.states.pop("t")
    assert tr.refusal_cap_adjustment(0.42) == 0.42


# --- latency reservoir ------------------------------------------------------


def test_reservoir_exact_below_capacity():
    r = LatencyReservoir(capacity=100)
    vals = list(range(1, 51))
    r.extend(vals)
    assert len(r) == 50 and r.count == 50
    assert r.percentile(50) == pytest.approx(np.percentile(vals, 50))
    p = r.percentiles()
    assert p["n"] == 50
    assert p["p99_ms"] <= p["max_ms"] == 50.0


def test_reservoir_bounded_and_representative_over_capacity():
    r = LatencyReservoir(capacity=256, seed=0)
    rng = np.random.default_rng(1)
    vals = rng.exponential(10.0, size=20_000)
    r.extend(vals)
    assert len(r) == 256 and r.count == 20_000
    # sampled p50 within a loose band of the true p50
    true = float(np.percentile(vals, 50))
    assert abs(r.percentile(50) - true) < 0.35 * true


def test_reservoir_deterministic():
    a, b = LatencyReservoir(capacity=64), LatencyReservoir(capacity=64)
    vals = np.linspace(0, 100, 1000)
    a.extend(vals)
    b.extend(vals)
    assert a.percentiles() == b.percentiles()


def test_reservoir_empty_percentiles_are_nan():
    p = LatencyReservoir().percentiles()
    assert p["n"] == 0 and math.isnan(p["p50_ms"])
